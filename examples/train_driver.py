"""End-to-end training driver (deliverable b): train a ~100M-parameter LM
for a few hundred steps with checkpointing, preemption handling, and
resume — the full fault-tolerant loop at laptop scale.

  PYTHONPATH=src python examples/train_driver.py             # quick (~15M)
  PYTHONPATH=src python examples/train_driver.py --full      # 125M, slower
"""
import argparse
import os
import tempfile

from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataPipeline, SyntheticCorpus
from repro.models.registry import build_model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="use the full 125M libra-proxy model")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    cfg = (get_config("libra-proxy-125m") if args.full
           else get_reduced("libra-proxy-125m"))
    steps = args.steps or (200 if args.full else 120)
    model = build_model(cfg)
    print(f"training {cfg.name}: {model.param_count()/1e6:.1f}M params, "
          f"{steps} steps")

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_driver")
    pipe = DataPipeline(SyntheticCorpus(cfg.vocab_size, seed=0),
                        batch=2 if args.full else 8,
                        seq_len=64 if args.full else 48)
    opt = AdamWConfig(lr=1e-3 if args.full else 3e-3,
                      warmup_steps=steps // 10, total_steps=steps,
                      schedule=cfg.lr_schedule)

    trainer = Trainer(model, opt, pipe, checkpoint_dir=ckpt_dir,
                      checkpoint_every=25)
    trainer.install_signal_handlers()
    resumed = trainer.resume()
    print("resumed from checkpoint" if resumed else "fresh start")

    # phase 1: train to ~60%, then simulate a preemption
    phase1 = int(steps * 0.6) - trainer.step
    if phase1 > 0:
        trainer.train(phase1)
        print(f"[phase 1] step {trainer.step}, "
              f"loss {trainer.history[-1]['loss']:.3f}")
        trainer._preempted = True   # simulated SIGTERM
        trainer.train(1)            # triggers the final checkpoint
        print(f"[preempted] checkpoint at step {trainer.ckpt.latest_step()}")

    # phase 2: a "new job" resumes and finishes
    trainer2 = Trainer(model, opt, pipe, checkpoint_dir=ckpt_dir,
                       checkpoint_every=25)
    assert trainer2.resume()
    print(f"[phase 2] resumed at step {trainer2.step}")
    trainer2.train(steps - trainer2.step)
    hist = trainer2.history
    print(f"[done] step {trainer2.step}  loss "
          f"{hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}  "
          f"stragglers flagged: {trainer2.straggler_events}")


if __name__ == "__main__":
    main()
