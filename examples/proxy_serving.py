"""The paper's scenario, end to end: an L7-proxy-style router in front of
backend models, with zero-copy payload forwarding.

A router inspects ONLY each request's header tokens (selective copy) to
pick a backend; the bulk payload context is anchored once and handed to
the chosen backend by VPI — no payload bytes move, no re-prefill. The
standard proxy re-processes (re-prefills) the payload at the backend.

  PYTHONPATH=src python examples/proxy_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.parser import TokenStreamParser
from repro.models.registry import build_model
from repro.serving.engine import LibraEngine

HEADER = 4   # routing prefix tokens (the HTTP-header analogue)


def main() -> None:
    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    parser = TokenStreamParser(header_len=HEADER)

    # one engine instance = one shared anchored pool serving two logical
    # backends (route 0 / route 1) behind the router
    eng = LibraEngine(model, params, max_batch=4, max_len=96, page_size=8,
                      parser=parser)
    rng = np.random.default_rng(0)

    n_req, fwd_bytes, hdr_bytes = 8, 0, 0
    for i in range(n_req):
        route_tag = i % 2
        header = np.full(HEADER, 100 + route_tag)
        payload = rng.integers(1, cfg.vocab_size - 1, 40)
        prompt = np.concatenate([header, payload])

        # --- router: reads ONLY the header (selective copy) ---
        decision = int(header[0]) - 100
        hdr_bytes += header.nbytes

        # --- ingress: prefill anchors the payload KV, returns a handle ---
        r = eng.submit(prompt, max_new_tokens=6)
        while r.handle is None:   # admission may wait for a free slot
            eng.step()

        # --- zero-copy forwarding: backend takes ownership via VPI ---
        if not r.done:
            h = eng.forward_handle(r)
            fwd_bytes += h.seq_len * eng._kv_bytes_per_token()
            eng.pool.release(h)  # backend done with the shared context
        print(f"req {r.rid}: route={decision} header={header[:2]}... "
              f"anchored {len(r.handle.pages) if r.handle else 0} pages "
              f"(vpi={r.handle.vpi & 0xffff:#x}...)" if r.handle else "")
    eng.run()

    s = eng.stats
    print("\n--- proxy summary ---")
    print(f"requests routed: {n_req}; header bytes inspected: {hdr_bytes}")
    print(f"payload KV forwarded zero-copy: {s.zero_copy_bytes/1e6:.2f} MB")
    print(f"payload bytes moved through the router: 0 (VPI handoff)")
    print(f"standard proxy would re-prefill {s.anchored_bytes/1e6:.2f} MB "
          f"of context at the backend")


if __name__ == "__main__":
    main()
