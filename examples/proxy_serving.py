"""The paper's scenario, end to end: an L7 proxy in front of backends, with
zero-copy payload forwarding — written the way an unmodified proxy would be.

Part 1 (stream level): one ``LibraStack`` multiplexes several client↔backend
flows with *different* protocol parsers through the event-driven
``ProxyRuntime``. The router policy inspects ONLY header tokens; payloads
stay anchored in the "kernel" pool and move to the egress socket by VPI
ownership transfer. Note there is no pool/registry/counter plumbing at any
call-site — just sockets.

Part 2 (serving level): the same stack design carried into the LLM serving
engine — a router reads request headers, prefill anchors the payload KV,
and the chosen backend takes ownership via VPI with zero payload movement.

  PYTHONPATH=src python examples/proxy_serving.py
"""
import numpy as np

from repro.core import (
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
)

HEADER = 4   # routing prefix tokens (the HTTP-header analogue)


def stream_proxy() -> None:
    rng = np.random.default_rng(0)
    stack = LibraStack(n_shards=4, pages_per_shard=256, page_size=16)
    rt = ProxyRuntime(stack, scheduler="round-robin", tick_every=8)

    # three protocols behind one proxy; the framed protocols route each
    # message to one of two backends by its first *header* token (the L7
    # policy — past the framing: [MAGIC, mlen, plen, hdr...] vs [hdr...]);
    # the chunked flow has no routing tag and uses a single backend
    flows = []
    route_tok = {"length-prefixed": 3, "delimiter": 0}
    for proto, build in (("length-prefixed", build_message),
                         ("delimiter", build_delimited_message),
                         ("chunked", None)):
        client = stack.socket(proto)
        n_backends = 2 if proto in route_tok else 1
        backends = [stack.socket(proto) for _ in range(n_backends)]
        router = None
        if proto in route_tok:
            router = (lambda buf, n, b=backends, i=route_tok[proto]:
                      b[int(buf[i]) % 2])
        rt.channel(client, backends, router=router, budget=64, name=proto)
        flows.append((proto, build, client, backends))

    n_msgs, payload_tokens = 8, 96
    for proto, build, client, _ in flows:
        for i in range(n_msgs):
            meta = np.full(HEADER, 100 + (i % 2))
            payload = rng.integers(1000, 2000, payload_tokens)
            if build is None:
                client.deliver(build_chunked_message(
                    [payload[:48], payload[48:]]))
            else:
                client.deliver(build(meta, payload))

    forwarded = rt.run()
    c = stack.counters
    print("--- stream proxy (3 protocols, 5 backends, one stack) ---")
    for ch in rt.channels:
        print(f"  {ch.name:16s} messages={ch.stats.messages:3d} "
              f"logical={ch.stats.logical_bytes} "
              f"partial_sends={ch.stats.partial_sends}")
    print(f"messages forwarded: {forwarded}")
    print(f"user-boundary copies: meta={c.meta_copied} full={c.full_copied} "
          f"tokens (payload stayed in the pool)")
    print(f"payload anchored once: {c.anchored} tokens; "
          f"ownership-transferred: {c.zero_copied} tokens")
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages


def serving_proxy() -> None:
    import jax

    from repro.configs import get_reduced
    from repro.core.parser import TokenStreamParser
    from repro.models.registry import build_model
    from repro.serving.engine import LibraEngine

    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    parser = TokenStreamParser(header_len=HEADER)

    # one engine instance = one LibraStack serving two logical backends
    # (route 0 / route 1) behind the router
    eng = LibraEngine(model, params, max_batch=4, max_len=96, page_size=8,
                      parser=parser)
    rng = np.random.default_rng(0)

    n_req, hdr_bytes = 8, 0
    for i in range(n_req):
        route_tag = i % 2
        header = np.full(HEADER, 100 + route_tag)
        payload = rng.integers(1, cfg.vocab_size - 1, 40)
        prompt = np.concatenate([header, payload])

        # --- router: reads ONLY the header (selective copy) ---
        decision = int(header[0]) - 100
        hdr_bytes += header.nbytes

        # --- ingress: prefill anchors the payload KV, returns a handle ---
        r = eng.submit(prompt, max_new_tokens=6)
        while r.handle is None:   # admission may wait for a free slot
            eng.step()

        # --- zero-copy forwarding: backend takes ownership via VPI ---
        if not r.done:
            h = eng.forward_handle(r)
            eng.release_handle(h)  # backend done with the shared context
        print(f"req {r.rid}: route={decision} header={header[:2]}... "
              f"anchored {len(r.handle.pages) if r.handle else 0} pages "
              f"(vpi={r.handle.vpi & 0xffff:#x}...)" if r.handle else "")
    eng.run()

    s = eng.stats
    print("\n--- serving proxy summary ---")
    print(f"requests routed: {n_req}; header bytes inspected: {hdr_bytes}")
    print(f"payload KV forwarded zero-copy: {s.zero_copy_bytes/1e6:.2f} MB")
    print(f"payload bytes moved through the router: 0 (VPI handoff)")
    print(f"standard proxy would re-prefill {s.anchored_bytes/1e6:.2f} MB "
          f"of context at the backend")
    print(f"stack counters (tokens): {eng.stack.counters}")


def main() -> None:
    stream_proxy()
    serving_proxy()


if __name__ == "__main__":
    main()
