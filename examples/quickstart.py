"""Quickstart: the Libra socket API in five lines, then a tiny LM served
through the Libra engine.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import LibraStack, build_message
from repro.core.parser import TokenStreamParser
from repro.data.pipeline import DataPipeline, SyntheticCorpus
from repro.models.registry import build_model
from repro.serving.engine import LibraEngine, StandardEngine
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer


def socket_quickstart() -> None:
    # ---- 0. the POSIX-shaped core API ---------------------------------------
    # one stack = one Libra "kernel"; sockets hide all pool/registry plumbing
    stack = LibraStack()
    client, backend = stack.socket_pair("length-prefixed")
    msg = build_message(np.arange(4), np.arange(1000, 1064))  # 4 meta + 64 payload
    client.deliver(msg)                       # network hands bytes to the NIC
    buf, n = client.recv(1 << 16)             # proxy sees [meta..., VPI]
    client.forward(backend, buf)              # payload moves by ownership, not copy
    c = stack.counters
    print(f"socket demo: recv'd {n} logical tokens via a {len(buf)}-token "
          f"buffer; user-boundary copies={c.total_user_copies()} "
          f"zero-copied={c.zero_copied}")


def main() -> None:
    socket_quickstart()

    # ---- 1. build a model from a config ------------------------------------
    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=8)
    print(f"model: {cfg.name} ({model.param_count()/1e6:.2f}M params)")

    # ---- 2. train briefly ---------------------------------------------------
    pipe = DataPipeline(SyntheticCorpus(cfg.vocab_size, seed=0), batch=4,
                        seq_len=32)
    trainer = Trainer(model, AdamWConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=60), pipe)
    hist = trainer.train(60)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    # ---- 3. serve with selective copy ---------------------------------------
    # the parser policy marks the first 4 tokens as routing metadata; the
    # rest of each prompt is opaque payload whose KV is anchored on device.
    parser = TokenStreamParser(header_len=4)
    eng = LibraEngine(model, trainer.params, max_batch=4, max_len=64,
                      page_size=8, parser=parser)
    rng = np.random.default_rng(0)
    for _ in range(6):
        eng.submit(rng.integers(1, cfg.vocab_size - 1, 20), max_new_tokens=8)
    done = eng.run()
    print(f"served {len(done)} requests; example output: {done[0].output}")
    s = eng.stats
    print(f"host-boundary traffic: {s.d2h_bytes} B down "
          f"({s.d2h_calls} transfers), {s.h2d_bytes} B up")
    print(f"payload anchored on device: {s.anchored_bytes/1e6:.2f} MB "
          f"(copied across the boundary: 0 MB)")
    print(f"engine stack counters (tokens): {eng.stack.counters}")

    # the standard stack for contrast
    std = StandardEngine(model, trainer.params, max_batch=4, max_len=64)
    for _ in range(6):
        std.submit(rng.integers(1, cfg.vocab_size - 1, 20), max_new_tokens=8)
    std.run()
    print(f"standard stack: {std.stats.d2h_bytes} B down, "
          f"{std.stats.payload_copy_bytes/1e6:.2f} MB payload copies")


if __name__ == "__main__":
    main()
