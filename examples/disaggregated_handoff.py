"""Disaggregated prefill/decode with zero-copy KV handoff.

Production serving increasingly splits prefill and decode onto separate
workers. With Libra's anchored pool, the handoff is a VPI ownership
transfer (block-table metadata, O(pages) ints) — the KV payload itself
never moves. This example runs prefill on one engine "worker", transfers
the handles, and decodes on a second worker sharing the pool, verifying
tokens match a monolithic engine bit-for-bit.

  PYTHONPATH=src python examples/disaggregated_handoff.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core import LibraStack
from repro.core.parser import TokenStreamParser
from repro.models.registry import build_model
from repro.serving.engine import LibraEngine


def main() -> None:
    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size - 1, 24) for _ in range(3)]

    # ---- monolithic reference -----------------------------------------------
    mono = LibraEngine(model, params, max_batch=3, max_len=64, page_size=8)
    refs = [mono.submit(p, max_new_tokens=6) for p in prompts]
    mono.run()

    # ---- disaggregated: both workers share one LibraStack (one anchored
    # pool, one VPI registry, one tick clock) — the handoff stays in-kernel
    stack = LibraStack(n_shards=1, pages_per_shard=3 * (64 // 8 + 2) + 4,
                       page_size=8)
    prefill_worker = LibraEngine(model, params, max_batch=3, max_len=64,
                                 page_size=8, stack=stack)
    reqs = [prefill_worker.submit(p, max_new_tokens=6) for p in prompts]
    prefill_worker.step()   # prefill + first token; payload KV now anchored

    # ---- handoff: VPIs + pool ownership move; payload bytes do not -----------
    meta_moved = 0
    decode_worker = LibraEngine.__new__(LibraEngine)
    decode_worker.__dict__.update(prefill_worker.__dict__)  # shared pool HBM
    for r in reqs:
        h = prefill_worker.forward_handle(r)
        meta_moved += len(h.pages) * 12  # (shard, pid, base) int32 triplets
        prefill_worker.release_handle(h)  # decode worker holds the other ref

    # ---- decode worker finishes the streams ----------------------------------
    decode_worker.run()

    for r, ref in zip(reqs, refs):
        assert r.output == ref.output, (r.output, ref.output)
    kv_bytes = prefill_worker.stats.anchored_bytes
    print(f"handoff verified: outputs bit-identical to monolithic serving")
    print(f"KV anchored: {kv_bytes/1e6:.2f} MB; handoff metadata moved: "
          f"{meta_moved} B ({kv_bytes/max(meta_moved,1):.0f}x reduction vs "
          f"moving the payload)")
    print(f"zero-copy forwarded: "
          f"{prefill_worker.stats.zero_copy_bytes/1e6:.2f} MB")


if __name__ == "__main__":
    main()
