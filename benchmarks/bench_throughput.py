"""Paper Fig. 6a/6b: throughput and P99 latency vs payload size for the four
stacks (Libra / Standard / Copier / Static-aka-F-Stack).

Payload size maps to context length; the Static engine gets a fixed memory
budget so its attainable concurrency collapses as payloads grow (the
paper's F-Stack large-payload inversion)."""
from __future__ import annotations

from benchmarks.common import csv, prompts_for, proxy_model, run_engine
from repro.serving.engine import (
    CopierEngine,
    LibraEngine,
    StandardEngine,
    StaticEngine,
)

CTX_SIZES = (16, 64, 160, 320)
N_REQ = 8
GEN = 8
BUDGET = 26_000_000  # bytes: fits ~8 slots at ctx 64 but ~1 at ctx 320


def main() -> None:
    cfg, model, params = proxy_model()
    for ctx in CTX_SIZES:
        max_len = ctx + GEN + 8
        prompts = prompts_for(cfg.vocab_size, N_REQ, ctx)
        rows = {}
        for name, cls, kw in (
            ("libra", LibraEngine, dict(max_batch=8, page_size=8)),
            ("standard", StandardEngine, dict(max_batch=8)),
            ("copier", CopierEngine, dict(max_batch=8)),
            ("static", StaticEngine, dict(memory_budget=BUDGET)),
        ):
            eng, dt = run_engine(cls, model, params, prompts, GEN,
                                 max_len=max_len, **kw)
            rows[name] = (eng.throughput_tokens() / dt, eng.p99_latency(),
                          eng.max_batch)
        base = rows["standard"][0]
        for name, (tput, p99, b) in rows.items():
            csv(f"fig6_ctx{ctx}_{name}", 1e6 / max(tput, 1e-9),
                f"tok/s={tput:.1f} speedup={tput/base:.2f} "
                f"p99_ms={p99*1000:.1f} batch={b}")


if __name__ == "__main__":
    main()
