"""Paper Fig. 6a/6b: throughput and P99 latency vs payload size for the four
stacks (Libra / Standard / Copier / Static-aka-F-Stack).

Payload size maps to context length; the Static engine gets a fixed memory
budget so its attainable concurrency collapses as payloads grow (the
paper's F-Stack large-payload inversion).

A stream-level preamble reports the same sweep through the socket facade
(LibraStack/LibraSocket/ProxyRuntime) with one proxied flow per request —
the pure selective-copy throughput with no model compute in the loop."""
from __future__ import annotations

from benchmarks.common import (
    csv,
    is_smoke,
    prompts_for,
    proxy_model,
    run_engine,
    run_stream,
)
from repro.serving.engine import (
    CopierEngine,
    LibraEngine,
    StandardEngine,
    StaticEngine,
)

CTX_SIZES = (16, 64, 160, 320)
N_REQ = 8
GEN = 8
BUDGET = 26_000_000  # bytes: fits ~8 slots at ctx 64 but ~1 at ctx 320


def stream_preamble() -> None:
    for ctx in CTX_SIZES:
        rows = {}
        for name, selective in (("libra", True), ("fullcopy", False)):
            stack, rt, msgs, dt = run_stream(
                n_conns=N_REQ, n_msgs=4, payload=ctx * 8,
                selective=selective)
            rows[name] = (msgs / max(dt, 1e-9),
                          stack.counters.total_user_copies())
        (tput, cp), (_, cp_full) = rows["libra"], rows["fullcopy"]
        csv(f"fig6a_stream_ctx{ctx}", 1e6 / max(tput, 1e-9),
            f"msgs_per_s={tput:.0f} "
            f"boundary_tokens={cp} vs_fullcopy={cp_full} "
            f"copy_reduction={cp_full/max(cp,1):.1f}x")


def main() -> None:
    stream_preamble()
    if is_smoke():
        return
    cfg, model, params = proxy_model()
    for ctx in CTX_SIZES:
        max_len = ctx + GEN + 8
        prompts = prompts_for(cfg.vocab_size, N_REQ, ctx)
        rows = {}
        for name, cls, kw in (
            ("libra", LibraEngine, dict(max_batch=8, page_size=8)),
            ("standard", StandardEngine, dict(max_batch=8)),
            ("copier", CopierEngine, dict(max_batch=8)),
            ("static", StaticEngine, dict(memory_budget=BUDGET)),
        ):
            eng, dt = run_engine(cls, model, params, prompts, GEN,
                                 max_len=max_len, **kw)
            rows[name] = (eng.throughput_tokens() / dt, eng.p99_latency(),
                          eng.max_batch)
        base = rows["standard"][0]
        for name, (tput, p99, b) in rows.items():
            csv(f"fig6_ctx{ctx}_{name}", 1e6 / max(tput, 1e-9),
                f"tok/s={tput:.1f} speedup={tput/base:.2f} "
                f"p99_ms={p99*1000:.1f} batch={b}")


if __name__ == "__main__":
    main()
