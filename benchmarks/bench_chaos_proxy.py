"""Throughput and tail latency under chaos vs steady state.

The fault-tolerance layer's bet: failures should cost *recovery work*
(bounded retries, one migration, one table recompile), never liveness or
correctness. This bench runs the standard chaos scenario against a
3-worker cluster proxy —

  * 1 backend reset (dead) at t = 25% of the steady-state round count,
    re-routed by the HealthTable + the rule's declared failover backend;
  * 1 worker killed at t = 50% (drain, in-flight flow migration over the
    grant protocol, dead-owner grant copy-out);
  * every live policy table hot-swapped (equivalent rules, epoch bump)
    at t = 75%, under traffic —

and compares delivered msgs/s and P99 quantum latency against the
fault-free run of the identical workload. Correctness rides along: every
non-dropped message must be byte-identical to the fault-free run, every
loss a counted drop, and every pool leak-free at shutdown.

Expected shape: >= 70% of steady-state msgs/s under chaos (asserted,
including in --smoke: this is the acceptance gate scripts/verify.sh
leans on), with P99 within a small integer multiple of steady state.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv, is_smoke, record
from repro.core import (
    ClusterRuntime,
    FaultPlan,
    HealthTable,
    LibraCluster,
    PolicyTable,
    build_message,
    eq,
    forward,
    rule,
)

PAGE = 16

#: app metadata starts after the [MAGIC, len_meta, len_payload] header
TAG = 3

N_WORKERS = 3
KILLED_WORKER = 2


def _table(health=None) -> PolicyTable:
    return PolicyTable([rule(forward(0, failover=1), eq(TAG, 7))],
                       health=health)


def _frames_of(wire) -> list:
    w = np.asarray(wire)
    out, pos = [], 0
    while pos < len(w):
        span = 3 + int(w[pos + 1]) + int(w[pos + 2])
        out.append(tuple(int(x) for x in w[pos:pos + span]))
        pos += span
    return out


def run_scenario(*, chaos: bool, n_chans: int, n_msgs: int, payload: int,
                 steady_rounds: int = 0, seed: int = 0) -> dict:
    """One full run of the workload; ``chaos=True`` arms the standard
    scenario with event times derived from ``steady_rounds`` (the
    fault-free run's round count)."""
    cl = LibraCluster(N_WORKERS, n_shards=4, pages_per_shard=2048,
                      page_size=PAGE, secret=b"chaos-bench")
    health = HealthTable(2, fail_threshold=2)
    plan = FaultPlan(seed=13)
    crt = ClusterRuntime(cl, policy=_table(health), fault_plan=plan)
    if chaos:
        plan.reset(0, at=max(steady_rounds // 4, 1))
        plan.kill_worker(KILLED_WORKER, at=max(steady_rounds // 2, 2))

        def swap_all(rt):
            for t in rt.policies:
                if t is not None:
                    t.swap([rule(forward(0, failover=1), eq(TAG, 7))])
        plan.at(max(3 * steady_rounds // 4, 3), swap_all)

    rng = np.random.default_rng(seed)
    chans, dst_pairs, sent = [], [], []
    for i in range(n_chans):
        src = cl.socket(worker=i % N_WORKERS)
        pair = [cl.socket(worker=(i + 1) % N_WORKERS) for _ in range(2)]
        chans.append(crt.channel(src, pair))
        dst_pairs.append(pair)
        frames = [build_message(
            np.concatenate([[7], rng.integers(100, 200, 3)]),
            rng.integers(1000, 2000, payload)) for _ in range(n_msgs)]
        sent.append([tuple(int(x) for x in f) for f in frames])
        src.deliver(np.concatenate(frames))

    t0 = time.perf_counter()
    crt.run()
    dt = time.perf_counter() - t0

    delivered = [sorted(_frames_of(d0.tx_wire()) + _frames_of(d1.tx_wire()))
                 for d0, d1 in dst_pairs]
    drops = sum(c.stats.timeouts + c.stats.drops for c in chans)
    p99s = [s["p99"] for s in crt.latency_summary().values()
            if s.get("count", 0)]
    res = {
        "msgs": crt.messages_forwarded(),
        "dt": dt,
        "rounds": crt.rounds,
        "drops": drops,
        "retries": sum(c.stats.retries for c in chans),
        "failovers": sum(c.stats.failovers for c in chans),
        "p99_us": 1e6 * max(p99s) if p99s else 0.0,
        "delivered": delivered,
        "sent": [sorted(s) for s in sent],
        "cluster_stats": dict(cl.stats),
        "fault_summary": plan.summary(),
    }
    crt.shutdown()          # asserts zero leaked pages/grants on every pool
    return res


def check_identity(chaos: dict, steady: dict) -> None:
    """Every chaos-delivered message is byte-identical to one the steady
    run delivered (exactly once), and every missing one is a counted
    drop."""
    lost = 0
    for got, exp in zip(chaos["delivered"], chaos["sent"]):
        assert len(got) == len(set(got)), "duplicate delivery under chaos"
        assert set(got) <= set(exp), "foreign bytes delivered under chaos"
        lost += len(exp) - len(got)
    assert lost == chaos["drops"], \
        f"{lost - chaos['drops']} messages lost without a counted drop"
    assert steady["drops"] == 0
    for got, exp in zip(steady["delivered"], steady["sent"]):
        assert got == exp


def _pair(n_chans: int, n_msgs: int, payload: int, reps: int):
    """Best-of-k steady + chaos runs of the SAME workload (event times
    pinned to the first steady run's round count), with the identity
    checks chaos must not break."""
    steady = None
    for r in range(reps):
        got = run_scenario(chaos=False, n_chans=n_chans, n_msgs=n_msgs,
                           payload=payload)
        if steady is None or got["dt"] < steady["dt"]:
            steady = got
    chaos = None
    for r in range(reps):
        got = run_scenario(chaos=True, n_chans=n_chans, n_msgs=n_msgs,
                           payload=payload, steady_rounds=steady["rounds"])
        if chaos is None or got["dt"] < chaos["dt"]:
            chaos = got
    check_identity(chaos, steady)
    assert chaos["cluster_stats"]["worker_kills"] == 1
    assert chaos["failovers"] + chaos["retries"] > 0
    return steady, chaos


def main() -> None:
    smoke = is_smoke()
    n_chans = 9 if smoke else 24
    n_msgs = 12 if smoke else 32
    payload = 32 if smoke else 64
    reps = 2 if smoke else 3

    steady, chaos = _pair(n_chans, n_msgs, payload, reps)
    s_t = steady["msgs"] / max(steady["dt"], 1e-9)
    c_t = chaos["msgs"] / max(chaos["dt"], 1e-9)
    ratio = c_t / max(s_t, 1e-9)
    cs = chaos["cluster_stats"]

    csv("chaos_proxy_steady", 1e6 / max(s_t, 1e-9),
        f"msgs_per_s={s_t:.0f} p99_us={steady['p99_us']:.1f} "
        f"msgs={steady['msgs']} drops={steady['drops']}")
    csv("chaos_proxy_storm", 1e6 / max(c_t, 1e-9),
        f"msgs_per_s={c_t:.0f} p99_us={chaos['p99_us']:.1f} "
        f"msgs={chaos['msgs']} drops={chaos['drops']} "
        f"retries={chaos['retries']} failovers={chaos['failovers']} "
        f"migrated={cs['migrated_flows']} "
        f"dead_grants_copied={cs['dead_grants_copied']}")
    csv("chaos_proxy_recovery_ratio", 0.0,
        f"chaos_over_steady={ratio:.2f}x identity=OK leaks=0")
    record("chaos_proxy_detail", ratio=float(ratio),
           steady_msgs_per_s=float(s_t), chaos_msgs_per_s=float(c_t),
           steady_p99_us=float(steady["p99_us"]),
           chaos_p99_us=float(chaos["p99_us"]),
           drops=int(chaos["drops"]), retries=int(chaos["retries"]),
           failovers=int(chaos["failovers"]),
           migrated_flows=int(cs["migrated_flows"]),
           fault_hits=chaos["fault_summary"]["hits_by_kind"])

    # the acceptance gate — holds in smoke mode too
    assert ratio >= 0.7, \
        f"recovery throughput {ratio:.2f}x < 0.7x of steady state"


if __name__ == "__main__":
    main()
