"""Paper Fig. 6c/6d at STACK level: the kTLS-analogue encrypted datapath
through the socket facade.

``bench_ktls_analogue`` models the same result as an isolated attention
microbenchmark; this benchmark runs it through the real thing: one shared
``LibraStack`` drives an L7 proxy (``ProxyRuntime(batched=True)``, N
client↔backend flows) **and** a ``LibraEngine`` serving handle, in three
regimes:

  * ``plaintext`` — the PR-2 batched datapath, unencrypted.
  * ``sw``        — software kTLS: a separate decrypt pass before anchoring
                    and an encrypt-and-copy pass after gathering, per
                    message; sw sockets are not admissible to the fused
                    batch (the record layer must run between queue and
                    pool), so the batched-datapath speedup is forfeited.
  * ``hw``        — NIC-inline kTLS: the cipher is fused into the
                    selective-copy scatter/gather (host) or shipped as the
                    fused kernel's ``keystream`` operand (device), with the
                    whole round's keystream generated in one vectorized
                    sweep — zero extra passes.
  * ``hw_fused``  — the hw regime served by the **one-kernel scheduling
                    round** (``batch_impl='fused-round:ref'``): anchor +
                    RX keystream XOR + speculative TX-encrypted egress
                    gather in a single launch per round.

The hw:sw throughput ratio is recorded as a first-class artifact row
(``*_ratio``) so the bench-trend gate tracks it against the paper's ~2.0x
Fig. 6c/6d headline, alongside whether the fused round narrows the gap.

Expected shape (paper Fig. 6c/6d): sw collapses toward the scalar
baseline; hw recovers the batched speedup — ≥ 1.5× sw throughput at
N = 64 — while every regime forwards byte-identical plaintext (checked by
decrypting the backend wires).

The engine rounds interleave with the proxy rounds on the same stack (one
pool, one VPI registry, one tick clock, one counter block) — the serving
engine and the socket datapath are the same kernel instance.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import csv, is_smoke, record
from repro.core import LibraStack, ProxyRuntime, build_message, open_stream

PAGE = 16


def _load(stack: LibraStack, rt: ProxyRuntime, tls: Optional[str], *,
          n_conns: int, n_msgs: int, payload: int, meta: int = 8,
          seed: int = 0):
    rng = np.random.default_rng(seed)
    dsts, wants = [], []
    for i in range(n_conns):
        src = stack.socket("length-prefixed", tls=tls)
        dst = stack.socket("length-prefixed", tls=tls)
        rt.channel(src, dst, name=f"ch{i}")
        dsts.append(dst)
        frames = [build_message(rng.integers(100, 200, meta),
                                rng.integers(1000, 2000, payload))
                  for _ in range(n_msgs)]
        wants.append(np.concatenate(frames))
        wire = (src.tls.seal_frames(frames, src.parser.inner) if tls
                else np.concatenate(frames))
        src.deliver(wire)
    return dsts, wants


def _make_engine(stack: LibraStack, model, params, *, max_new: int):
    from repro.serving.engine import LibraEngine

    eng = LibraEngine(model, params, max_batch=2, max_len=48,
                      page_size=PAGE, stack=stack)
    rng = np.random.default_rng(7)
    for p in [rng.integers(1, 255, 16) for _ in range(2)]:
        eng.submit(p, max_new_tokens=max_new)
    return eng


def run_regime(tls: Optional[str], *, n_conns: int, n_msgs: int,
               payload: int, model_bundle=None, max_new: int = 4,
               seed: int = 0, batch_impl: str = "host"):
    """One shared stack, proxy + engine, one regime. Returns a result dict
    (proxy timing excludes the interleaved engine steps and vice versa)."""
    stack = LibraStack(n_shards=1, pages_per_shard=8192, page_size=PAGE,
                       secret=b"ktls-proxy")
    rt = ProxyRuntime(stack, tick_every=32, batched=True,
                      batch_impl=batch_impl)
    dsts, wants = _load(stack, rt, tls, n_conns=n_conns, n_msgs=n_msgs,
                        payload=payload, seed=seed)
    eng = None
    if model_bundle is not None:
        _, model, params = model_bundle
        eng = _make_engine(stack, model, params, max_new=max_new)

    proxy_dt = engine_dt = 0.0
    while True:
        t0 = time.perf_counter()
        progressed = rt.step()
        proxy_dt += time.perf_counter() - t0
        if eng is not None and (eng.waiting or eng.active):
            t1 = time.perf_counter()
            eng.step()          # same pool/registry/clock as the proxy round
            engine_dt += time.perf_counter() - t1
        if progressed == 0 and not (eng is not None
                                    and (eng.waiting or eng.active)):
            break

    plains = [open_stream(d.tls.tx_key, d.tx_wire()) if tls else d.tx_wire()
              for d in dsts]
    res = {
        "msgs": rt.messages_forwarded(),
        "proxy_dt": proxy_dt,
        "plains": plains,
        "wants": wants,
        "crypto_copied": stack.counters.crypto_copied,
        "snapshot": stack.counters.snapshot(),
        "engine_tokens": eng.throughput_tokens() if eng is not None else 0,
        "engine_dt": engine_dt,
    }
    rt.shutdown()
    return res


def main() -> None:
    smoke = is_smoke()
    n_conns = 64
    n_msgs = 8 if smoke else 32
    payload = 96
    reps = 2 if smoke else 3
    max_new = 2 if smoke else 6

    # one model serves every regime's engine handle (the engine is tls-
    # independent; what is measured is coexistence on the shared stack)
    from benchmarks.common import proxy_model
    model_bundle = proxy_model(page_size=PAGE)

    # hw_fused: the hw regime served by the one-kernel scheduling round
    # (anchor + keystream XOR + egress gather in ONE launch, speculative
    # TX) instead of the multi-pass batched datapath
    regimes = ((None, "plaintext", "host"), ("sw", "sw", "host"),
               ("hw", "hw", "host"), ("hw", "hw_fused", "fused-round:ref"))
    best = {}
    for tls, name, impl in regimes:
        for r in range(reps):     # interleaved best-of-k, same workload
            got = run_regime(tls, n_conns=n_conns, n_msgs=n_msgs,
                             payload=payload,
                             model_bundle=(model_bundle if r == 0 else None),
                             max_new=max_new, batch_impl=impl)
            if r == 0:
                best[name] = got
            elif got["proxy_dt"] < best[name]["proxy_dt"]:
                got["engine_tokens"] = best[name]["engine_tokens"]
                got["engine_dt"] = best[name]["engine_dt"]
                best[name] = got

    # byte-identical forwarded plaintext across all three regimes
    identical = all(
        np.array_equal(p, w)
        for r in best.values() for p, w in zip(r["plains"], r["wants"]))
    for name, r in best.items():
        tput = r["msgs"] / max(r["proxy_dt"], 1e-9)
        e_tput = r["engine_tokens"] / max(r["engine_dt"], 1e-9)
        csv(f"fig6cd_ktls_proxy_c{n_conns}_{name}",
            1e6 / max(tput, 1e-9),
            f"msgs_per_s={tput:.0f} crypto_copied={r['crypto_copied']} "
            f"engine_toks_per_s={e_tput:.0f} "
            f"engine_tokens={r['engine_tokens']} shared_stack=True")
    hw_t = best["hw"]["msgs"] / max(best["hw"]["proxy_dt"], 1e-9)
    fu_t = best["hw_fused"]["msgs"] / max(best["hw_fused"]["proxy_dt"], 1e-9)
    sw_t = best["sw"]["msgs"] / max(best["sw"]["proxy_dt"], 1e-9)
    pl_t = best["plaintext"]["msgs"] / max(best["plaintext"]["proxy_dt"], 1e-9)
    csv(f"fig6cd_ktls_proxy_c{n_conns}_hw_over_sw", 0.0,
        f"hw_over_sw={hw_t / max(sw_t, 1e-9):.2f}x "
        f"hw_fused_over_sw={fu_t / max(sw_t, 1e-9):.2f}x "
        f"hw_over_plain={hw_t / max(pl_t, 1e-9):.2f}x "
        f"plaintext_identical={identical}")
    # the hw:sw throughput ratio as a first-class trajectory metric (the
    # paper's Fig. 6c/6d headline is ~2.0x): check_bench_trend.py gates
    # `hw_over_sw` like msgs_per_s, and `hw_fused_over_sw` records whether
    # the one-kernel round narrows the remaining gap to the paper figure
    record(f"fig6cd_ktls_proxy_c{n_conns}_ratio",
           hw_over_sw=hw_t / max(sw_t, 1e-9),
           hw_fused_over_sw=fu_t / max(sw_t, 1e-9),
           hw_over_plain=hw_t / max(pl_t, 1e-9),
           paper_target_hw_over_sw=2.0)
    assert identical, "regimes disagree on forwarded plaintext"


if __name__ == "__main__":
    main()
