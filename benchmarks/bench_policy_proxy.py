"""In-data-plane L7 policy offload vs per-message Python callbacks.

The policy engine's bet mirrors the paper's: routing consults only the
small metadata prefix, so the decision belongs in the fused data plane,
not in per-message Python. One shared table (a realistic mix: two
forward rules behind a weighted split, a tenant DROP, a header REWRITE,
and a no-match PUNT tail) routes the same workload two ways:

  * ``python``    — :class:`PythonPolicyRouter`: the table evaluated
                    message-by-message by the naive interpreter through
                    the classic ``rewrite``/``router`` callback slots.
  * ``offloaded`` — ``ProxyRuntime(policy=...)``: ONE vectorized
                    first-match pass per batched round, fused into
                    ``recv_batch``'s metadata sweep; Python only sees the
                    PUNT tail.

Series: batched plaintext at N ∈ {8, 64, 256} connections, plus an
hw-kTLS series at N = 64 where the offloaded match consumes ciphertext +
keystream (the kernel's fused decrypt-and-match) while the baseline
parses decrypted records in Python.

Expected shape: offloaded ≥ 1.3× python msgs/s at N = 64 batched, growing
with N (the match pass amortizes over the round while the callback cost
stays per-message) — with byte-identical backend wires and Fig. 9
counter identity in every pair.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from benchmarks.common import csv, is_smoke
from repro.core import (
    LibraStack,
    PolicyTable,
    ProxyRuntime,
    PythonPolicyRouter,
    between,
    build_message,
    drop,
    eq,
    forward,
    rewrite,
    rule,
)
from repro.core.crypto import REC_HEADER

PAGE = 16

#: app metadata starts after the [MAGIC, len_meta, len_payload] header
TAG = 3

#: ACL-scale table: one forward rule per tenant tag — the regime the
#: offload exists for. A per-message Python first-match scan is O(rules)
#: (~half the table on average); the fused pass is one vectorized sweep
#: over the whole round regardless of table size.
N_TENANTS = 240
TAG_DROP, TAG_REWRITE, TAG_PUNT = 245, 250, 251


def make_table(crypto: bool = False) -> PolicyTable:
    off = TAG + (REC_HEADER if crypto else 0)
    rules = [rule(forward(t % 2), eq(off, t), between(off + 1, 0, 255),
                  name=f"tenant{t}")
             for t in range(N_TENANTS)]
    rules.append(rule(drop(), eq(off, TAG_DROP), name="blocked"))
    rules.append(rule(rewrite(off + 1, 7777, backend=0), eq(off, TAG_REWRITE),
                      name="patch"))
    # everything else (TAG_PUNT) PUNTs to the callback tail
    return PolicyTable(rules)


def _load(stack: LibraStack, rt: ProxyRuntime, table: Optional[PolicyTable],
          tls: Optional[str], *, n_conns: int, n_msgs: int, payload: int,
          seed: int = 0):
    rng = np.random.default_rng(seed)
    dsts = []
    for i in range(n_conns):
        src = stack.socket("length-prefixed", tls=tls)
        pair = [stack.socket("length-prefixed", tls=tls) for _ in range(2)]
        if table is None:
            rt.channel(src, pair, name=f"ch{i}")          # offloaded
        else:
            pr = PythonPolicyRouter(table, pair, parser=src.parser,
                                    crypto=tls is not None, stack=stack)
            rt.channel(src, pair, rewrite=pr.rewrite, router=pr.router,
                       name=f"ch{i}")
        dsts.append(pair)
        tags = np.where(rng.random(n_msgs) < 0.85,
                        rng.integers(0, N_TENANTS, n_msgs),
                        rng.choice([TAG_DROP, TAG_REWRITE, TAG_PUNT], n_msgs))
        frames = [build_message(
            np.concatenate([[int(t)], rng.integers(100, 200, 7)]),
            rng.integers(1000, 2000, payload))
            for t in tags]
        wire = (src.tls.seal_frames(frames, src.parser.inner) if tls
                else np.concatenate(frames))
        src.deliver(wire)
    return dsts


def run_regime(mode: str, *, n_conns: int, n_msgs: int, payload: int,
               tls: Optional[str] = None, seed: int = 0):
    stack = LibraStack(n_shards=1, pages_per_shard=8192, page_size=PAGE,
                       secret=b"policy-proxy")
    table = make_table(crypto=tls is not None)
    rt = ProxyRuntime(stack, tick_every=32, batched=True,
                      policy=table if mode == "offloaded" else None)
    dsts = _load(stack, rt,
                 None if mode == "offloaded" else table, tls,
                 n_conns=n_conns, n_msgs=n_msgs, payload=payload, seed=seed)
    t0 = time.perf_counter()
    rt.run()
    dt = time.perf_counter() - t0
    plains = [np.concatenate([d.tls.open_wire(d.tx_wire()) if tls
                              else d.tx_wire() for d in pair])
              for pair in dsts]
    res = {
        "msgs": n_conns * n_msgs,
        "dt": dt,
        "plains": plains,
        "snapshot": stack.counters.snapshot(),
        "policy_hits": stack.counters.policy_hits,
        "policy_punts": stack.counters.policy_punts,
        "policy_drops": stack.counters.policy_drops,
        "table": table.summary(),
    }
    rt.shutdown()
    return res


def _pair(n_conns: int, n_msgs: int, payload: int, reps: int,
          tls: Optional[str] = None):
    """Best-of-k offloaded + python runs of the SAME workload, with the
    identity checks the offload must not break."""
    best = {}
    for mode in ("python", "offloaded"):
        for r in range(reps):
            got = run_regime(mode, n_conns=n_conns, n_msgs=n_msgs,
                             payload=payload, tls=tls)
            if r == 0 or got["dt"] < best[mode]["dt"]:
                best[mode] = got
    o, p = best["offloaded"], best["python"]
    assert o["snapshot"] == p["snapshot"], "Fig. 9 identity broken"
    assert all(np.array_equal(a, b)
               for a, b in zip(o["plains"], p["plains"])), \
        "offloaded routing diverged from the Python callbacks"
    assert o["policy_hits"] > 0 and p["policy_hits"] == 0
    return o, p


def main() -> None:
    smoke = is_smoke()
    n_msgs = 4 if smoke else 16
    payload = 24
    reps = 2 if smoke else 3
    series = [8, 64] if smoke else [8, 64, 256]

    ratios = {}
    for n_conns in series:
        o, p = _pair(n_conns, n_msgs, payload, reps)
        o_t = o["msgs"] / max(o["dt"], 1e-9)
        p_t = p["msgs"] / max(p["dt"], 1e-9)
        ratios[n_conns] = o_t / max(p_t, 1e-9)
        st = o["table"]
        csv(f"policy_proxy_c{n_conns}_python", 1e6 / max(p_t, 1e-9),
            f"msgs_per_s={p_t:.0f} mode=callbacks batched=True")
        csv(f"policy_proxy_c{n_conns}_offloaded", 1e6 / max(o_t, 1e-9),
            f"msgs_per_s={o_t:.0f} mode=offloaded batched=True "
            f"hits={o['policy_hits']} punts={o['policy_punts']} "
            f"drops={o['policy_drops']} matched={st['matched']}")
        csv(f"policy_proxy_c{n_conns}_speedup", 0.0,
            f"offloaded_over_python={ratios[n_conns]:.2f}x "
            f"identical=True")

    # hw-kTLS series: the match consumes ciphertext + keystream
    n_tls = 64
    o, p = _pair(n_tls, n_msgs, payload, reps, tls="hw")
    o_t = o["msgs"] / max(o["dt"], 1e-9)
    p_t = p["msgs"] / max(p["dt"], 1e-9)
    csv(f"policy_proxy_c{n_tls}_hw_ktls_python", 1e6 / max(p_t, 1e-9),
        f"msgs_per_s={p_t:.0f} mode=callbacks tls=hw")
    csv(f"policy_proxy_c{n_tls}_hw_ktls_offloaded", 1e6 / max(o_t, 1e-9),
        f"msgs_per_s={o_t:.0f} mode=offloaded tls=hw "
        f"hits={o['policy_hits']} drops={o['policy_drops']}")
    csv(f"policy_proxy_c{n_tls}_hw_ktls_speedup", 0.0,
        f"offloaded_over_python={o_t / max(p_t, 1e-9):.2f}x identical=True")

    if not smoke:
        assert ratios[64] >= 1.3, \
            f"offload under target at N=64: {ratios[64]:.2f}x < 1.3x"


if __name__ == "__main__":
    main()
