"""Benchmark harness: one module per paper table/figure, plus the roofline
summary derived from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV lines per benchmark, and writes
each module's structured rows to ``results/bench/BENCH_<name>.json`` (via
``benchmarks.common.record``/``flush_artifact``) so the perf trajectory —
msgs/s, copy-counter snapshots, impl, transfer telemetry — is machine-
readable across PRs. Committing the refreshed artifacts with a PR is the
intended convention (they ARE the trajectory); treat diffs in them as
perf data, not noise.

  PYTHONPATH=src python -m benchmarks.run [--only fig6]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

BENCHES = [
    ("fig1_copy_overhead", "benchmarks.bench_copy_overhead"),
    ("fig6_throughput_latency", "benchmarks.bench_throughput"),
    ("fig6_stream_proxy", "benchmarks.bench_proxy_runtime"),
    ("batched_datapath", "benchmarks.bench_batched_datapath"),
    ("dma_overlap", "benchmarks.bench_dma_overlap"),
    ("cluster_proxy", "benchmarks.bench_cluster_proxy"),
    ("fig6c_ktls", "benchmarks.bench_ktls_analogue"),
    ("fig6cd_ktls_proxy", "benchmarks.bench_ktls_proxy"),
    ("policy_proxy", "benchmarks.bench_policy_proxy"),
    ("chaos_proxy", "benchmarks.bench_chaos_proxy"),
    ("fig6e_single_stream", "benchmarks.bench_single_stream"),
    ("fig8_vs_copier", "benchmarks.bench_sota"),
    ("fig9_microarch", "benchmarks.bench_microarch"),
]

# --smoke: stream-level benches (socket facade) plus the encrypted-datapath
# gate — the one smoke entry that jit-compiles (a reduced LibraEngine
# sharing the proxy stack); still well under a minute end to end.
SMOKE_BENCHES = [
    ("fig1_copy_overhead", "benchmarks.bench_copy_overhead"),
    ("fig6_throughput_latency", "benchmarks.bench_throughput"),
    ("fig6_stream_proxy", "benchmarks.bench_proxy_runtime"),
    ("batched_datapath", "benchmarks.bench_batched_datapath"),
    ("dma_overlap", "benchmarks.bench_dma_overlap"),
    ("cluster_proxy", "benchmarks.bench_cluster_proxy"),
    ("fig6cd_ktls_proxy", "benchmarks.bench_ktls_proxy"),
    ("policy_proxy", "benchmarks.bench_policy_proxy"),
    ("chaos_proxy", "benchmarks.bench_chaos_proxy"),
    ("fig6e_single_stream", "benchmarks.bench_single_stream"),
]


def roofline_summary() -> None:
    """Collapse results/dryrun/*.json into the §Roofline table lines."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    files = sorted(glob.glob(os.path.join(here, "results", "dryrun", "*.json")))
    if not files:
        print("roofline_summary,0.0,no dryrun artifacts (run repro.launch.dryrun)")
        return
    for f in files:
        r = json.load(open(f))
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("skipped"):
            print(f"roofline_{cell},0.0,SKIP ({r['reason'][:60]})")
            continue
        if not r.get("ok"):
            print(f"roofline_{cell},0.0,FAIL {r.get('error','')[:80]}")
            continue
        t = r["roofline"]
        step_s = max(t["compute_s"], t["memory_s"], t["collective_s"])
        print(f"roofline_{cell},{step_s*1e6:.1f},"
              f"dom={t['dominant']} comp={t['compute_s']:.4f} "
              f"mem={t['memory_s']:.4f} coll={t['collective_s']:.4f} "
              f"useful={t['useful_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="fast stream-level subset (CI gate); implies "
                         "reduced sizes via LIBRA_BENCH_SMOKE=1")
    args = ap.parse_args()
    import importlib

    benches = BENCHES
    if args.smoke:
        os.environ["LIBRA_BENCH_SMOKE"] = "1"
        benches = SMOKE_BENCHES

    from benchmarks import common

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    artifact_dir = os.path.join(here, "results", "bench")

    failures = 0
    for name, mod in benches:
        if args.only and args.only not in name:
            continue
        print(f"# --- {name} ---", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(mod).main()
        except Exception as e:  # noqa: BLE001
            failures += 1
            common.record("ERROR", error=f"{type(e).__name__}: {e}")
            print(f"{name},0.0,ERROR {type(e).__name__}: {e}")
        path = common.flush_artifact(name, artifact_dir)
        took = time.time() - t0
        print(f"# {name} done in {took:.1f}s"
              + (f" -> {os.path.relpath(path, here)}" if path else ""),
              flush=True)
    if not args.smoke and (not args.only or "roofline" in (args.only or "")):
        print("# --- roofline (from dry-run artifacts) ---")
        roofline_summary()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) errored")


if __name__ == "__main__":
    main()
