"""Paper Fig. 6a analogue at the stream level: multi-connection proxy
throughput through the POSIX facade (LibraStack/LibraSocket/ProxyRuntime),
selective copy vs the native full-copy path, across payload sizes and
connection counts with mixed protocol parsers.

Everything here goes through sockets — no pool/registry/counter threading
at any call-site. Reported: messages/s, user-boundary copied tokens per
payload token (the copy tax), and the Fig. 9 counter breakdown.
"""
from __future__ import annotations

from benchmarks.common import csv, is_smoke, run_stream

MIXED = ["length-prefixed", "delimiter", "chunked"]


def run_once(*, n_conns: int, n_msgs: int, payload: int, selective: bool,
             budget=None, parsers=None):
    return run_stream(n_conns=n_conns, n_msgs=n_msgs, payload=payload,
                      parsers=parsers or MIXED, budget=budget,
                      selective=selective)


def main() -> None:
    smoke = is_smoke()
    payloads = (64,) if smoke else (64, 256, 1024)
    conn_counts = (4,) if smoke else (2, 8, 32)
    n_msgs = 4 if smoke else 16

    for payload in payloads:
        for n_conns in conn_counts:
            rows = {}
            for name, selective in (("libra", True), ("fullcopy", False)):
                stack, rt, msgs, dt = run_once(
                    n_conns=n_conns, n_msgs=n_msgs, payload=payload,
                    selective=selective)
                c = stack.counters
                useful = rt.logical_bytes()
                copy_tax = c.total_user_copies() / max(useful, 1)
                rows[name] = (msgs / max(dt, 1e-9), copy_tax, c)
            # copy_tax (user-boundary tokens per logical token) is the figure
            # of merit: wall clock in this host-level simulation reflects
            # python per-message overhead, not data movement.
            base = rows["fullcopy"][1]
            for name, (tput, tax, c) in rows.items():
                csv(f"stream_proxy_p{payload}_c{n_conns}_{name}",
                    1e6 / max(tput, 1e-9),
                    f"msgs_per_s={tput:.0f} copy_tax={tax:.3f} "
                    f"copy_reduction={base/max(tax,1e-9):.1f}x "
                    f"meta={c.meta_copied} full={c.full_copied} "
                    f"zerocopy={c.zero_copied}")

    # send-budget sensitivity: partial sends through the runtime
    for budget in (32, 256):
        stack, rt, msgs, dt = run_once(n_conns=4, n_msgs=n_msgs, payload=256,
                                       selective=True, budget=budget)
        partials = sum(ch.stats.partial_sends for ch in rt.channels)
        csv(f"stream_proxy_budget{budget}", dt * 1e6 / max(msgs, 1),
            f"msgs={msgs} partial_sends={partials} "
            f"rounds={rt.rounds}")


if __name__ == "__main__":
    main()
