"""Paper Fig. 6e: single-stream latency + host-work share.

Stream level (socket facade): one client↔backend flow per payload size;
Libra's user-boundary work is metadata-sized while the full-copy path
scales with the payload. Engine level: end-to-end latency is expected to
be comparable (dominated by model compute, the network-propagation
analogue); the win shows in host-boundary work per request.
"""
from __future__ import annotations

from benchmarks.common import (
    csv,
    is_smoke,
    prompts_for,
    proxy_model,
    run_engine,
    run_stream,
)


def stream_section() -> None:
    n_msgs = 8
    for payload in (64, 512, 4096):
        rows = {}
        for name, selective in (("libra", True), ("fullcopy", False)):
            stack, rt, msgs, dt = run_stream(
                pages=4096, n_conns=1, n_msgs=n_msgs, payload=payload,
                parsers=["length-prefixed"], selective=selective)
            rows[name] = (dt, stack.counters.total_user_copies())
        (t_l, cp_l), (t_s, cp_s) = rows["libra"], rows["fullcopy"]
        csv(f"fig6e_stream_p{payload}_latency", t_l * 1e6 / n_msgs,
            f"libra_s={t_l:.4f} fullcopy_s={t_s:.4f}")
        csv(f"fig6e_stream_p{payload}_boundary_tokens", 0.0,
            f"libra={cp_l} fullcopy={cp_s} ratio={cp_s/max(cp_l,1):.1f}x")


def engine_section() -> None:
    from repro.serving.engine import LibraEngine, StandardEngine

    cfg, model, params = proxy_model()
    for ctx in (32, 128, 320):
        prompts = prompts_for(cfg.vocab_size, 1, ctx)
        libra, t_l = run_engine(LibraEngine, model, params, prompts, 8,
                                max_batch=1, max_len=ctx + 16, page_size=8)
        std, t_s = run_engine(StandardEngine, model, params, prompts, 8,
                              max_batch=1, max_len=ctx + 16)
        csv(f"fig6e_ctx{ctx}_latency", t_l * 1e6,
            f"libra_s={t_l:.3f} std_s={t_s:.3f} ratio={t_l/t_s:.2f}")
        csv(f"fig6e_ctx{ctx}_boundary_bytes", 0.0,
            f"libra={libra.stats.d2h_bytes + libra.stats.h2d_bytes} "
            f"std={std.stats.d2h_bytes + std.stats.h2d_bytes}")


def main() -> None:
    stream_section()
    if not is_smoke():
        engine_section()


if __name__ == "__main__":
    main()
