"""Paper Fig. 6e: single-stream latency + host-work share.

End-to-end latency is expected to be comparable (dominated by model
compute, the network-propagation analogue); the win shows in host-boundary
work per request — Libra's is metadata-sized, the standard stack scales
with the payload."""
from __future__ import annotations

from benchmarks.common import csv, prompts_for, proxy_model, run_engine
from repro.serving.engine import LibraEngine, StandardEngine


def main() -> None:
    cfg, model, params = proxy_model()
    for ctx in (32, 128, 320):
        prompts = prompts_for(cfg.vocab_size, 1, ctx)
        libra, t_l = run_engine(LibraEngine, model, params, prompts, 8,
                                max_batch=1, max_len=ctx + 16, page_size=8)
        std, t_s = run_engine(StandardEngine, model, params, prompts, 8,
                              max_batch=1, max_len=ctx + 16)
        csv(f"fig6e_ctx{ctx}_latency", t_l * 1e6,
            f"libra_s={t_l:.3f} std_s={t_s:.3f} ratio={t_l/t_s:.2f}")
        csv(f"fig6e_ctx{ctx}_boundary_bytes", 0.0,
            f"libra={libra.stats.d2h_bytes + libra.stats.h2d_bytes} "
            f"std={std.stats.d2h_bytes + std.stats.h2d_bytes}")


if __name__ == "__main__":
    main()
