"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import (
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
)


def is_smoke() -> bool:
    """``benchmarks/run.py --smoke`` sets this: stream-level benches only,
    reduced sizes, no jit compiles — a seconds-long CI gate."""
    return os.environ.get("LIBRA_BENCH_SMOKE", "") == "1"


def proxy_model(page_size: int = 8):
    import jax

    from repro.configs import get_reduced
    from repro.models.registry import build_model

    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=page_size)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# -- stream-level (socket facade) workloads ----------------------------------

BUILDERS = {
    "length-prefixed": build_message,
    "delimiter": build_delimited_message,
    "chunked": lambda m, p: build_chunked_message(
        [p[i : i + 64] for i in range(0, len(p), 64)]),
}


def stream_stack(pages: int = 4096, page_size: int = 16,
                 device_pool: bool = True) -> LibraStack:
    return LibraStack(n_shards=4, pages_per_shard=pages // 4,
                      page_size=page_size, secret=b"bench",
                      device_pool=device_pool)


def run_stream(*, pages: int = 8192, page_size: int = 16,
               device_pool: bool = True,
               **load_kw) -> Tuple[LibraStack, ProxyRuntime, int, float]:
    """Build a stack, pre-load a proxy workload (see :func:`load_proxy`),
    time a full run, shut down, and assert the pool drained. The shared
    measurement loop for every stream-level benchmark.

    The returned message count is the *application* workload size
    (``n_conns * n_msgs``) so msgs/s is comparable across parser mixes;
    chunked flows forward several frames per application message
    (``rt.messages_forwarded()`` counts frames)."""
    stack = stream_stack(pages=pages, page_size=page_size,
                         device_pool=device_pool)
    rt = load_proxy(stack, **load_kw)
    t0 = time.perf_counter()
    rt.run()
    dt = time.perf_counter() - t0
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    return stack, rt, load_kw["n_conns"] * load_kw["n_msgs"], dt


def load_proxy(stack: LibraStack, *, n_conns: int, n_msgs: int,
               payload: int, meta: int = 8, parsers: Optional[List[str]] = None,
               budget: Optional[int] = None, selective: bool = True,
               seed: int = 0, batched: bool = False,
               batch_impl: str = "host") -> ProxyRuntime:
    """Build an N-connection proxy over ``stack`` with its ingress queues
    pre-loaded — entirely through the socket facade. ``selective=False``
    forces every message down the native full-copy path (the standard-stack
    baseline) via the admission threshold. ``batched=True`` services each
    scheduling round with one fused recv_batch/forward_batch pass."""
    rng = np.random.default_rng(seed)
    parsers = parsers or ["length-prefixed"]
    rt = ProxyRuntime(stack, tick_every=32, batched=batched,
                      batch_impl=batch_impl)
    min_payload = 8 if selective else 1 << 30
    for i in range(n_conns):
        proto = parsers[i % len(parsers)]
        src = stack.socket(proto, min_payload=min_payload)
        dst = stack.socket(proto, min_payload=min_payload)
        rt.channel(src, dst, budget=budget, name=f"{proto}-{i}")
        for _ in range(n_msgs):
            m = rng.integers(100, 200, meta)
            p = rng.integers(1000, 2000, payload)
            src.deliver(BUILDERS[proto](m, p))
    return rt


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters


def run_engine(cls, model, params, prompts, gen, **kw):
    eng = cls(model, params, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=gen)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return eng, dt


def prompts_for(vocab: int, n: int, length: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab - 1, length) for _ in range(n)]


# -- machine-readable trajectory artifacts (BENCH_<name>.json) ---------------

_ARTIFACT_ROWS: List[dict] = []


def record(name: str, **fields) -> None:
    """Add a structured result row for the running bench module.
    ``benchmarks/run.py`` collects the rows into a ``BENCH_<module>.json``
    artifact after the module finishes, so the perf trajectory (msgs/s,
    copy-counter snapshots, impl, transfer telemetry) stays machine-
    readable across PRs. Benches with richer data than the CSV line call
    this directly; every :func:`csv` line is recorded automatically."""
    _ARTIFACT_ROWS.append({"name": name, **fields})


def counters_fields(stack) -> Dict[str, int]:
    """The CopyCounters snapshot + pool transfer telemetry of a stack as
    flat JSON-friendly fields (for :func:`record`)."""
    c = stack.counters
    out = {"meta_copied": c.meta_copied, "full_copied": c.full_copied,
           "anchored": c.anchored, "zero_copied": c.zero_copied,
           "vpi_injected": c.vpi_injected, "allocs": c.allocs,
           "crypto_copied": c.crypto_copied,
           "device_fallbacks": c.device_fallbacks,
           "cross_worker_grants": c.cross_worker_grants,
           "cross_worker_copied": c.cross_worker_copied}
    out.update({f"xfer_{k}": v for k, v in stack.pool.xfer.items()})
    return out


def flush_artifact(bench: str, out_dir: str) -> Optional[str]:
    """Write (and clear) the collected rows as ``BENCH_<bench>.json``.
    Returns the path, or None when the module recorded nothing."""
    global _ARTIFACT_ROWS
    rows, _ARTIFACT_ROWS = _ARTIFACT_ROWS, []
    if not rows:
        return None
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, "unix_time": time.time(),
                   "smoke": is_smoke(), "rows": rows},
                  f, indent=1, default=str)
    return path


def csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
    record(name, us_per_call=float(us), derived=derived)
