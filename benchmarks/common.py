"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.parser import TokenStreamParser
from repro.models.registry import build_model


def proxy_model(page_size: int = 8):
    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=page_size)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args)
    return (time.perf_counter() - t0) / iters


def run_engine(cls, model, params, prompts, gen, **kw):
    eng = cls(model, params, **kw)
    for p in prompts:
        eng.submit(p, max_new_tokens=gen)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    return eng, dt


def prompts_for(vocab: int, n: int, length: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab - 1, length) for _ in range(n)]


def csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}")
