"""Batched vs scalar proxy datapath (the round-amortization experiment).

One ``ProxyRuntime.step()`` in batched mode gathers every ready channel's
admissible frame into a single ``LibraStack.recv_batch``/``forward_batch``
pair — one fused selective-copy pass (metadata compaction + payload
anchoring, then one fused payload gather on egress) for the whole round,
with scalar fallback for edge states. This is the XLB/MiddleNet-style
amortization applied to the socket facade.

Reported per connection count N ∈ {8, 64, 256}:

  * msgs/s scalar vs batched (best-of-k interleaved, same workload/seed),
  * per-round wall latency and per-quantum p50/p99 from the channel
    latency histograms (batched rounds charge the amortized share),
  * a CopyCounters identity check — the batched path must copy EXACTLY
    the tokens the scalar path copies (meta/full/zero-copy breakdown).

The batched data plane also runs once through the fused kernel oracle
(``batch_impl='ref'``) to confirm the device path produces the same wire
bytes (the kernel-driven mode; host mode is the allocation-free default).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import counters_fields, csv, is_smoke, record, run_stream

MIXED = ["length-prefixed", "delimiter", "chunked"]

FUSED_N = 64   # the acceptance point: one-kernel vs three-launch at N=64


def run_fused_once(impl: str, *, n_conns: int, n_msgs: int, payload: int,
                   seed: int = 3):
    """One policy-routed proxy run for the one-kernel series: an L7 table
    (metadata route + payload-prefix route + drop) makes the multi-pass
    path pay its full three launches per round (anchor + policy match +
    egress gather) while ``impl='fused-round:*'`` folds them into one,
    with the egress gather riding the round as a speculative TX against
    each channel's primary backend."""
    from repro.core import (LibraStack, PolicyTable, ProxyRuntime,
                            between, build_message, drop, forward, rule)
    from repro.core.policy import payload_at

    stack = LibraStack(n_shards=4, pages_per_shard=2048, page_size=16,
                       secret=b"bench", device_pool=True)
    table = PolicyTable([
        rule(drop(), between(0, 196, 199)),
        rule(forward(1), payload_at(0, 1950, 2000)),
        rule(forward(0), between(0, 100, 199)),
    ])
    rt = ProxyRuntime(stack, tick_every=32, policy=table, batched=True,
                      batch_impl=impl)
    rng = np.random.default_rng(seed)
    for i in range(n_conns):
        src = stack.socket("length-prefixed")
        dsts = [stack.socket("length-prefixed") for _ in range(2)]
        rt.channel(src, dsts, name=f"ch{i}")
        for _ in range(n_msgs):
            src.deliver(build_message(rng.integers(100, 200, 8),
                                      rng.integers(1000, 2000, payload)))
    t0 = time.perf_counter()
    rt.run()
    dt = time.perf_counter() - t0
    wires = tuple(d.tx_wire().tobytes()
                  for ch in rt.channels for d in ch.dsts)
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    return stack, rt, n_conns * n_msgs, dt, wires


def run_once(*, n_conns: int, n_msgs: int, payload: int, batched: bool,
             batch_impl: str = "host", parsers=None, device_pool=True):
    return run_stream(n_conns=n_conns, n_msgs=n_msgs, payload=payload,
                      parsers=parsers or MIXED, batched=batched,
                      batch_impl=batch_impl, device_pool=device_pool)


def _percentiles(rt) -> tuple:
    hists = [c.stats.latency for c in rt.channels]
    tot = sum(h.count for h in hists)
    if not tot:
        return 0.0, 0.0
    # channel-count-weighted medians are close enough for telemetry lines
    p50 = sorted(h.percentile(0.5) for h in hists)[len(hists) // 2]
    p99 = max(h.percentile(0.99) for h in hists)
    return p50, p99


def main() -> None:
    smoke = is_smoke()
    n_msgs = 4 if smoke else 16
    payload = 64 if smoke else 256
    reps = 2 if smoke else 3
    conn_counts = (8, 64, 256)

    for n_conns in conn_counts:
        rows = {}
        for name, kw in (("scalar", dict(batched=False)),
                         ("batched", dict(batched=True))):
            best = None
            for _ in range(reps):   # interleaving is per-config; best-of-k
                stack, rt, msgs, dt = run_once(
                    n_conns=n_conns, n_msgs=n_msgs, payload=payload, **kw)
                if best is None or dt < best[3]:
                    best = (stack, rt, msgs, dt)
            rows[name] = best
        sc, bc = rows["scalar"][0].counters, rows["batched"][0].counters
        counters_match = sc.snapshot() == bc.snapshot()
        for name, (stack, rt, msgs, dt) in rows.items():
            p50, p99 = _percentiles(rt)
            tput = msgs / max(dt, 1e-9)
            csv(f"batched_datapath_c{n_conns}_{name}",
                1e6 / max(tput, 1e-9),
                f"msgs_per_s={tput:.0f} rounds={rt.rounds} "
                f"round_us={dt * 1e6 / max(rt.rounds, 1):.1f} "
                f"q_p50_us={p50 * 1e6:.1f} q_p99_us={p99 * 1e6:.1f} "
                f"counters_match={counters_match}")
            record(f"batched_datapath_c{n_conns}_{name}_counters",
                   impl="host", n_conns=n_conns, msgs_per_s=tput,
                   counters_match=bool(counters_match),
                   **counters_fields(stack))
        s_tput = rows["scalar"][2] / max(rows["scalar"][3], 1e-9)
        b_tput = rows["batched"][2] / max(rows["batched"][3], 1e-9)
        csv(f"batched_datapath_c{n_conns}_speedup", 0.0,
            f"batched_over_scalar={b_tput / max(s_tput, 1e-9):.2f}x")

    # kernel-driven mode: the fused selective-copy kernel (oracle backend on
    # CPU, Pallas on TPU) services the batched rounds — wire-identical
    t0 = time.time()
    stack_h, rt_h, msgs_h, _ = run_once(n_conns=8, n_msgs=n_msgs,
                                        payload=payload, batched=True)
    stack_k, rt_k, msgs_k, _ = run_once(n_conns=8, n_msgs=n_msgs,
                                        payload=payload, batched=True,
                                        batch_impl="ref")
    same = (stack_h.counters.snapshot() == stack_k.counters.snapshot()
            and msgs_h == msgs_k)
    csv("batched_datapath_kernel_mode", (time.time() - t0) * 1e6,
        f"impl=ref counters_match={same} msgs={msgs_k}")
    record("batched_datapath_kernel_mode_counters", impl="ref",
           counters_match=bool(same), **counters_fields(stack_k))

    # resident vs host-sync device rounds (the ROADMAP "no host sync per
    # round" item): the SAME kernel-driven batched workload against (a) the
    # resident DevicePool — zero pool-sized boundary crossings per round —
    # and (b) the legacy host pool that re-uploads the whole pool and syncs
    # the touched rows back every round. rounds/s + the measured transfer
    # volumes make the residency win machine-readable across PRs.
    n_res = 8 if smoke else 32
    series = {}
    for name, device_pool in (("resident", True), ("host_sync", False)):
        best = None
        for _ in range(reps):
            stack, rt, msgs, dt = run_once(
                n_conns=n_res, n_msgs=n_msgs, payload=payload, batched=True,
                batch_impl="ref", parsers=["length-prefixed"],
                device_pool=device_pool)
            if best is None or dt < best[3]:
                best = (stack, rt, msgs, dt)
        series[name] = best
        stack, rt, msgs, dt = best
        x = stack.pool.xfer
        rounds_s = rt.rounds / max(dt, 1e-9)
        csv(f"batched_datapath_device_{name}", dt * 1e6 / max(rt.rounds, 1),
            f"rounds_per_s={rounds_s:.0f} msgs_per_s={msgs / max(dt, 1e-9):.0f} "
            f"pool_syncs={x['pool_syncs']} device_rounds={x['device_rounds']} "
            f"h2d_tokens={x['h2d_tokens']} d2h_tokens={x['d2h_tokens']}")
        record(f"batched_datapath_device_{name}_counters", impl="ref",
               n_conns=n_res, rounds_per_s=rounds_s,
               **counters_fields(stack))
    # the one-kernel scheduling round (tentpole series): anchor + kTLS
    # crypto + policy match + egress gather as ONE launch per round vs the
    # multi-pass three (anchor, match, gather), same policy-routed workload
    # at N=64. Identity is asserted per pair — wire bytes, the CopyCounters
    # snapshot, and forwarded message count must be EQUAL before the
    # speedup is reported.
    fused_msgs = 4 if smoke else 8
    fused = {}
    for _ in range(reps):       # interleaved best-of-k, same seed
        for impl in ("ref", "fused-round:ref"):
            got = run_fused_once(impl, n_conns=FUSED_N, n_msgs=fused_msgs,
                                 payload=96)
            if impl not in fused or got[3] < fused[impl][3]:
                fused[impl] = got
    multi, one = fused["ref"], fused["fused-round:ref"]
    assert multi[4] == one[4], "fused round: wire bytes differ"
    assert multi[0].counters.snapshot() == one[0].counters.snapshot(), \
        "fused round: copy counters differ"
    assert multi[1].messages_forwarded() == one[1].messages_forwarded()
    for name, (stack, rt, msgs, dt, _) in (("multi_pass", multi),
                                           ("fused", one)):
        x = stack.pool.xfer
        rounds_s = rt.rounds / max(dt, 1e-9)
        launches = x["device_rounds"] + x["policy_match_rounds"]
        csv(f"batched_datapath_fused_c{FUSED_N}_{name}",
            dt * 1e6 / max(rt.rounds, 1),
            f"rounds_per_s={rounds_s:.0f} msgs_per_s={msgs / max(dt, 1e-9):.0f} "
            f"launches={launches} fused_rounds={x['fused_rounds']} "
            f"tx_spec_hits={x['tx_spec_hits']}")
        record(f"batched_datapath_fused_c{FUSED_N}_{name}_series",
               impl="ref" if name == "multi_pass" else "fused-round:ref",
               n_conns=FUSED_N, rounds_per_s=rounds_s,
               msgs_per_s=msgs / max(dt, 1e-9), launches=launches,
               **counters_fields(stack))
    f_tput = one[1].rounds / max(one[3], 1e-9)
    m_tput = multi[1].rounds / max(multi[3], 1e-9)
    mx, ox = multi[0].pool.xfer, one[0].pool.xfer
    csv(f"batched_datapath_fused_c{FUSED_N}_speedup", 0.0,
        f"fused_over_multi={f_tput / max(m_tput, 1e-9):.2f}x "
        f"launches_{mx['device_rounds'] + mx['policy_match_rounds']}"
        f"_to_{ox['device_rounds'] + ox['policy_match_rounds']}")
    record(f"batched_datapath_fused_c{FUSED_N}_speedup_series",
           fused_over_multi=f_tput / max(m_tput, 1e-9),
           multi_launches=mx["device_rounds"] + mx["policy_match_rounds"],
           fused_launches=ox["device_rounds"] + ox["policy_match_rounds"])

    r_tput = series["resident"][1].rounds / max(series["resident"][3], 1e-9)
    h_tput = series["host_sync"][1].rounds / max(series["host_sync"][3], 1e-9)
    rx, hx = series["resident"][0].pool.xfer, series["host_sync"][0].pool.xfer
    crossed_r = rx["h2d_tokens"] + rx["d2h_tokens"]
    crossed_h = hx["h2d_tokens"] + hx["d2h_tokens"]
    # on real hardware the boundary-traffic reduction IS the win (PCIe is
    # the bottleneck the paper removes); the CPU repro emulates transfers
    # with memcpy, so rounds/s is reported but the token ratio is the
    # trajectory metric
    csv("batched_datapath_device_residency", 0.0,
        f"rounds_ratio={r_tput / max(h_tput, 1e-9):.2f}x "
        f"boundary_tokens_reduction="
        f"{crossed_h / max(crossed_r, 1):.0f}x")


if __name__ == "__main__":
    main()
