"""Batched vs scalar proxy datapath (the round-amortization experiment).

One ``ProxyRuntime.step()`` in batched mode gathers every ready channel's
admissible frame into a single ``LibraStack.recv_batch``/``forward_batch``
pair — one fused selective-copy pass (metadata compaction + payload
anchoring, then one fused payload gather on egress) for the whole round,
with scalar fallback for edge states. This is the XLB/MiddleNet-style
amortization applied to the socket facade.

Reported per connection count N ∈ {8, 64, 256}:

  * msgs/s scalar vs batched (best-of-k interleaved, same workload/seed),
  * per-round wall latency and per-quantum p50/p99 from the channel
    latency histograms (batched rounds charge the amortized share),
  * a CopyCounters identity check — the batched path must copy EXACTLY
    the tokens the scalar path copies (meta/full/zero-copy breakdown).

The batched data plane also runs once through the fused kernel oracle
(``batch_impl='ref'``) to confirm the device path produces the same wire
bytes (the kernel-driven mode; host mode is the allocation-free default).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv, is_smoke, run_stream

MIXED = ["length-prefixed", "delimiter", "chunked"]


def run_once(*, n_conns: int, n_msgs: int, payload: int, batched: bool,
             batch_impl: str = "host", parsers=None):
    return run_stream(n_conns=n_conns, n_msgs=n_msgs, payload=payload,
                      parsers=parsers or MIXED, batched=batched,
                      batch_impl=batch_impl)


def _percentiles(rt) -> tuple:
    hists = [c.stats.latency for c in rt.channels]
    tot = sum(h.count for h in hists)
    if not tot:
        return 0.0, 0.0
    # channel-count-weighted medians are close enough for telemetry lines
    p50 = sorted(h.percentile(0.5) for h in hists)[len(hists) // 2]
    p99 = max(h.percentile(0.99) for h in hists)
    return p50, p99


def main() -> None:
    smoke = is_smoke()
    n_msgs = 4 if smoke else 16
    payload = 64 if smoke else 256
    reps = 2 if smoke else 3
    conn_counts = (8, 64, 256)

    for n_conns in conn_counts:
        rows = {}
        for name, kw in (("scalar", dict(batched=False)),
                         ("batched", dict(batched=True))):
            best = None
            for _ in range(reps):   # interleaving is per-config; best-of-k
                stack, rt, msgs, dt = run_once(
                    n_conns=n_conns, n_msgs=n_msgs, payload=payload, **kw)
                if best is None or dt < best[3]:
                    best = (stack, rt, msgs, dt)
            rows[name] = best
        sc, bc = rows["scalar"][0].counters, rows["batched"][0].counters
        counters_match = sc.snapshot() == bc.snapshot()
        for name, (stack, rt, msgs, dt) in rows.items():
            p50, p99 = _percentiles(rt)
            tput = msgs / max(dt, 1e-9)
            csv(f"batched_datapath_c{n_conns}_{name}",
                1e6 / max(tput, 1e-9),
                f"msgs_per_s={tput:.0f} rounds={rt.rounds} "
                f"round_us={dt * 1e6 / max(rt.rounds, 1):.1f} "
                f"q_p50_us={p50 * 1e6:.1f} q_p99_us={p99 * 1e6:.1f} "
                f"counters_match={counters_match}")
        s_tput = rows["scalar"][2] / max(rows["scalar"][3], 1e-9)
        b_tput = rows["batched"][2] / max(rows["batched"][3], 1e-9)
        csv(f"batched_datapath_c{n_conns}_speedup", 0.0,
            f"batched_over_scalar={b_tput / max(s_tput, 1e-9):.2f}x")

    # kernel-driven mode: the fused selective-copy kernel (oracle backend on
    # CPU, Pallas on TPU) services the batched rounds — wire-identical
    t0 = time.time()
    stack_h, rt_h, msgs_h, _ = run_once(n_conns=8, n_msgs=n_msgs,
                                        payload=payload, batched=True)
    stack_k, rt_k, msgs_k, _ = run_once(n_conns=8, n_msgs=n_msgs,
                                        payload=payload, batched=True,
                                        batch_impl="ref")
    same = (stack_h.counters.snapshot() == stack_k.counters.snapshot()
            and msgs_h == msgs_k)
    csv("batched_datapath_kernel_mode", (time.time() - t0) * 1e6,
        f"impl=ref counters_match={same} msgs={msgs_k}")


if __name__ == "__main__":
    main()
