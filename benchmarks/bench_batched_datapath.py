"""Batched vs scalar proxy datapath (the round-amortization experiment).

One ``ProxyRuntime.step()`` in batched mode gathers every ready channel's
admissible frame into a single ``LibraStack.recv_batch``/``forward_batch``
pair — one fused selective-copy pass (metadata compaction + payload
anchoring, then one fused payload gather on egress) for the whole round,
with scalar fallback for edge states. This is the XLB/MiddleNet-style
amortization applied to the socket facade.

Reported per connection count N ∈ {8, 64, 256}:

  * msgs/s scalar vs batched (best-of-k interleaved, same workload/seed),
  * per-round wall latency and per-quantum p50/p99 from the channel
    latency histograms (batched rounds charge the amortized share),
  * a CopyCounters identity check — the batched path must copy EXACTLY
    the tokens the scalar path copies (meta/full/zero-copy breakdown).

The batched data plane also runs once through the fused kernel oracle
(``batch_impl='ref'``) to confirm the device path produces the same wire
bytes (the kernel-driven mode; host mode is the allocation-free default).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import counters_fields, csv, is_smoke, record, run_stream

MIXED = ["length-prefixed", "delimiter", "chunked"]


def run_once(*, n_conns: int, n_msgs: int, payload: int, batched: bool,
             batch_impl: str = "host", parsers=None, device_pool=True):
    return run_stream(n_conns=n_conns, n_msgs=n_msgs, payload=payload,
                      parsers=parsers or MIXED, batched=batched,
                      batch_impl=batch_impl, device_pool=device_pool)


def _percentiles(rt) -> tuple:
    hists = [c.stats.latency for c in rt.channels]
    tot = sum(h.count for h in hists)
    if not tot:
        return 0.0, 0.0
    # channel-count-weighted medians are close enough for telemetry lines
    p50 = sorted(h.percentile(0.5) for h in hists)[len(hists) // 2]
    p99 = max(h.percentile(0.99) for h in hists)
    return p50, p99


def main() -> None:
    smoke = is_smoke()
    n_msgs = 4 if smoke else 16
    payload = 64 if smoke else 256
    reps = 2 if smoke else 3
    conn_counts = (8, 64, 256)

    for n_conns in conn_counts:
        rows = {}
        for name, kw in (("scalar", dict(batched=False)),
                         ("batched", dict(batched=True))):
            best = None
            for _ in range(reps):   # interleaving is per-config; best-of-k
                stack, rt, msgs, dt = run_once(
                    n_conns=n_conns, n_msgs=n_msgs, payload=payload, **kw)
                if best is None or dt < best[3]:
                    best = (stack, rt, msgs, dt)
            rows[name] = best
        sc, bc = rows["scalar"][0].counters, rows["batched"][0].counters
        counters_match = sc.snapshot() == bc.snapshot()
        for name, (stack, rt, msgs, dt) in rows.items():
            p50, p99 = _percentiles(rt)
            tput = msgs / max(dt, 1e-9)
            csv(f"batched_datapath_c{n_conns}_{name}",
                1e6 / max(tput, 1e-9),
                f"msgs_per_s={tput:.0f} rounds={rt.rounds} "
                f"round_us={dt * 1e6 / max(rt.rounds, 1):.1f} "
                f"q_p50_us={p50 * 1e6:.1f} q_p99_us={p99 * 1e6:.1f} "
                f"counters_match={counters_match}")
            record(f"batched_datapath_c{n_conns}_{name}_counters",
                   impl="host", n_conns=n_conns, msgs_per_s=tput,
                   counters_match=bool(counters_match),
                   **counters_fields(stack))
        s_tput = rows["scalar"][2] / max(rows["scalar"][3], 1e-9)
        b_tput = rows["batched"][2] / max(rows["batched"][3], 1e-9)
        csv(f"batched_datapath_c{n_conns}_speedup", 0.0,
            f"batched_over_scalar={b_tput / max(s_tput, 1e-9):.2f}x")

    # kernel-driven mode: the fused selective-copy kernel (oracle backend on
    # CPU, Pallas on TPU) services the batched rounds — wire-identical
    t0 = time.time()
    stack_h, rt_h, msgs_h, _ = run_once(n_conns=8, n_msgs=n_msgs,
                                        payload=payload, batched=True)
    stack_k, rt_k, msgs_k, _ = run_once(n_conns=8, n_msgs=n_msgs,
                                        payload=payload, batched=True,
                                        batch_impl="ref")
    same = (stack_h.counters.snapshot() == stack_k.counters.snapshot()
            and msgs_h == msgs_k)
    csv("batched_datapath_kernel_mode", (time.time() - t0) * 1e6,
        f"impl=ref counters_match={same} msgs={msgs_k}")
    record("batched_datapath_kernel_mode_counters", impl="ref",
           counters_match=bool(same), **counters_fields(stack_k))

    # resident vs host-sync device rounds (the ROADMAP "no host sync per
    # round" item): the SAME kernel-driven batched workload against (a) the
    # resident DevicePool — zero pool-sized boundary crossings per round —
    # and (b) the legacy host pool that re-uploads the whole pool and syncs
    # the touched rows back every round. rounds/s + the measured transfer
    # volumes make the residency win machine-readable across PRs.
    n_res = 8 if smoke else 32
    series = {}
    for name, device_pool in (("resident", True), ("host_sync", False)):
        best = None
        for _ in range(reps):
            stack, rt, msgs, dt = run_once(
                n_conns=n_res, n_msgs=n_msgs, payload=payload, batched=True,
                batch_impl="ref", parsers=["length-prefixed"],
                device_pool=device_pool)
            if best is None or dt < best[3]:
                best = (stack, rt, msgs, dt)
        series[name] = best
        stack, rt, msgs, dt = best
        x = stack.pool.xfer
        rounds_s = rt.rounds / max(dt, 1e-9)
        csv(f"batched_datapath_device_{name}", dt * 1e6 / max(rt.rounds, 1),
            f"rounds_per_s={rounds_s:.0f} msgs_per_s={msgs / max(dt, 1e-9):.0f} "
            f"pool_syncs={x['pool_syncs']} device_rounds={x['device_rounds']} "
            f"h2d_tokens={x['h2d_tokens']} d2h_tokens={x['d2h_tokens']}")
        record(f"batched_datapath_device_{name}_counters", impl="ref",
               n_conns=n_res, rounds_per_s=rounds_s,
               **counters_fields(stack))
    r_tput = series["resident"][1].rounds / max(series["resident"][3], 1e-9)
    h_tput = series["host_sync"][1].rounds / max(series["host_sync"][3], 1e-9)
    rx, hx = series["resident"][0].pool.xfer, series["host_sync"][0].pool.xfer
    crossed_r = rx["h2d_tokens"] + rx["d2h_tokens"]
    crossed_h = hx["h2d_tokens"] + hx["d2h_tokens"]
    # on real hardware the boundary-traffic reduction IS the win (PCIe is
    # the bottleneck the paper removes); the CPU repro emulates transfers
    # with memcpy, so rounds/s is reported but the token ratio is the
    # trajectory metric
    csv("batched_datapath_device_residency", 0.0,
        f"rounds_ratio={r_tput / max(h_tput, 1e-9):.2f}x "
        f"boundary_tokens_reduction="
        f"{crossed_h / max(crossed_r, 1):.0f}x")


if __name__ == "__main__":
    main()
