"""Paper Fig. 9: micro-architectural efficiency breakdown.

9a (dTLB) analogue: page-granular sequential access = anchored pages are
touched exactly once (write) plus streamed reads; the standard stack's
cache is rewritten wholesale every step (scattered revisits). We report
bytes-touched-per-useful-byte as the locality proxy.
9b: cost breakdown by category (Std Copy / Std Alloc / Meta Sel-Copy /
Meta Alloc / Meta SKB-Trans analogues) from engine counters.
9c: data processed per unit of host-boundary work."""
from __future__ import annotations

from benchmarks.common import csv, prompts_for, proxy_model, run_engine
from repro.serving.engine import LibraEngine, StandardEngine


def main() -> None:
    cfg, model, params = proxy_model()
    for ctx in (32, 128, 320):
        prompts = prompts_for(cfg.vocab_size, 4, ctx)
        gen = 8
        libra, t_l = run_engine(LibraEngine, model, params, prompts, gen,
                                max_batch=4, max_len=ctx + gen + 8, page_size=8)
        std, t_s = run_engine(StandardEngine, model, params, prompts, gen,
                              max_batch=4, max_len=ctx + gen + 8)
        l, s = libra.stats, std.stats
        useful = l.anchored_bytes  # payload bytes the workload actually needs
        libra_touch = l.anchored_bytes + l.h2d_bytes + l.d2h_bytes
        std_touch = s.payload_copy_bytes + s.h2d_bytes + s.d2h_bytes
        csv(f"fig9a_ctx{ctx}_locality", 0.0,
            f"libra_touch_per_useful={libra_touch/max(useful,1):.2f} "
            f"std_touch_per_useful={std_touch/max(useful,1):.2f}")
        csv(f"fig9b_ctx{ctx}_libra", 0.0,
            f"sel_copy={l.h2d_bytes} meta_alloc={l.alloc_events} "
            f"skb_trans={l.zero_copy_bytes} anchored={l.anchored_bytes}")
        csv(f"fig9b_ctx{ctx}_std", 0.0,
            f"std_copy={s.payload_copy_bytes} std_alloc={s.alloc_events} "
            f"logits_d2h={s.d2h_bytes}")
        csv(f"fig9c_ctx{ctx}_efficiency", 0.0,
            f"libra_bytes_per_boundary_byte="
            f"{useful/max(l.h2d_bytes + l.d2h_bytes, 1):.1f} "
            f"std={s.payload_copy_bytes/max(s.h2d_bytes + s.d2h_bytes, 1):.1f}")


if __name__ == "__main__":
    main()
