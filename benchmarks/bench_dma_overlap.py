"""DMA-vs-compute overlap profile for the one-kernel scheduling round.

Runs :mod:`repro.kernels.dma_profile` at a representative fused-round
shape and reports, per candidate staging depth (blocked / double / quad
buffered), the measured rounds/s — plus the transfer/compute
decomposition of one staged round and the overlap ratio the DMA ring
actually achieved. The final row is the depth :func:`auto_buffer_depth`
selects (what ``DevicePool.fused_buffers`` should be pinned to on this
box); on the host interpret backend the async copies execute eagerly, so
the profile documents the *measurement*, not a predetermined winner.

The depth rows carry ``rounds_per_s`` so ``scripts/check_bench_trend.py``
gates them like every other throughput series.
"""
from __future__ import annotations

from benchmarks.common import csv, is_smoke, record
from repro.kernels.dma_profile import (
    DEFAULT_DEPTHS,
    auto_buffer_depth,
    dma_compute_profile,
    profile_fused_depths,
)


def main() -> None:
    smoke = is_smoke()
    shape = dict(b=4, page=8, pps=2, meta_max=8) if smoke else \
        dict(b=8, page=16, pps=4, meta_max=16)
    iters = 3 if smoke else 8
    warmup = 1 if smoke else 2

    profs = profile_fused_depths(iters=iters, warmup=warmup, **shape)
    for d in DEFAULT_DEPTHS:
        p = profs[d]
        csv(f"dma_overlap_depth{d}", p.round_s * 1e6,
            f"rounds_per_s={p.rounds_per_s:.0f} n_buffers={d}")
        record(f"dma_overlap_depth{d}_series", n_buffers=d,
               rounds_per_s=p.rounds_per_s, round_us=p.round_s * 1e6,
               **shape)

    decomp = dma_compute_profile(iters=iters, warmup=warmup, n_buffers=2,
                                 **shape)
    csv("dma_overlap_decomposition", decomp["fused_s"] * 1e6,
        f"transfer_us={decomp['transfer_s'] * 1e6:.1f} "
        f"compute_us={decomp['compute_s'] * 1e6:.1f} "
        f"overlap_ratio={decomp['overlap_ratio']:.2f}")
    record("dma_overlap_decomposition_series",
           overlap_ratio=decomp["overlap_ratio"],
           transfer_us=decomp["transfer_s"] * 1e6,
           compute_us=decomp["compute_s"] * 1e6,
           fused_us=decomp["fused_s"] * 1e6, **shape)

    depth = auto_buffer_depth(profiles=profs)
    csv("dma_overlap_selected", 0.0,
        f"auto_depth={depth} "
        f"candidates={'/'.join(str(d) for d in DEFAULT_DEPTHS)}")
    record("dma_overlap_selected_series", auto_depth=depth)


if __name__ == "__main__":
    main()
