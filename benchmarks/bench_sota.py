"""Paper Fig. 8: Libra vs Copier at low (2) and high (8) concurrency.

Speedups normalised to the standard stack, as in the paper. Divergence
note (DESIGN.md): the paper's Copier collapse at 64 connections is kernel-
thread lock contention; our Copier analogue has no shared lock, so its
speedup saturates instead of collapsing — the Libra-vs-Copier gap still
widens with concurrency because Copier remains O(payload)."""
from __future__ import annotations

from benchmarks.common import csv, prompts_for, proxy_model, run_engine
from repro.serving.engine import CopierEngine, LibraEngine, StandardEngine


def main() -> None:
    cfg, model, params = proxy_model()
    for conc in (2, 8):
        for ctx in (32, 128, 320):
            prompts = prompts_for(cfg.vocab_size, conc, ctx)
            gen = 8
            rows = {}
            for name, cls, kw in (
                ("standard", StandardEngine, {}),
                ("copier", CopierEngine, {}),
                ("libra", LibraEngine, dict(page_size=8)),
            ):
                eng, dt = run_engine(cls, model, params, prompts, gen,
                                     max_batch=conc, max_len=ctx + gen + 8,
                                     **kw)
                rows[name] = eng.throughput_tokens() / dt
            csv(f"fig8_conc{conc}_ctx{ctx}", 0.0,
                f"libra_speedup={rows['libra']/rows['standard']:.2f} "
                f"copier_speedup={rows['copier']/rows['standard']:.2f}")


if __name__ == "__main__":
    main()
