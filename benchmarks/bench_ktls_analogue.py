"""Paper Fig. 6c/6d (kTLS): encryption maps to KV-cache quantisation.

"SW kTLS" = a separate quantise/dequantise pass over the gathered KV each
step (the encrypt-and-copy the paper describes in B.1 — an extra full pass
over the payload that no software trick can fuse away once the data has
been gathered);
"HW kTLS" = quantisation fused into the attention read of anchored pages
(the NIC-inline analogue: zero extra passes).

Expected (paper) shape: SW mode *hurts* the zero-copy datapath (fragmented
payload + extra pass), HW mode unlocks it. We measure the decode-attention
core under the three regimes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv, time_fn


def _quant(x):
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True) + 1e-9
    q = jnp.clip(jnp.round(x / amax * 127), -127, 127).astype(jnp.int8)
    return q, amax


def _dequant(q, amax):
    return q.astype(jnp.float32) * amax / 127.0


def main() -> None:
    rng = np.random.default_rng(0)
    B, Hq, Hkv, hd = 8, 12, 12, 64
    for ctx in (256, 1024, 4096):
        q = jnp.array(rng.standard_normal((B, Hq, hd)), jnp.float32)
        kv = jnp.array(rng.standard_normal((B, ctx, 2, Hkv, hd)), jnp.float32)
        kq, kamax = _quant(kv)

        @jax.jit
        def plain(q, kv):
            k, v = kv[:, :, 0], kv[:, :, 1]
            s = jnp.einsum("bhd,bthd->bht", q, k)
            p = jax.nn.softmax(s, axis=-1)
            return jnp.einsum("bht,bthd->bhd", p, v)

        @jax.jit
        def sw_mode(q, kq, kamax):
            # separate pass: dequantise the WHOLE payload to a new buffer
            # (the encrypt-and-copy), then attend
            kv = _dequant(kq, kamax)
            return plain(q, kv)

        @jax.jit
        def hw_mode(q, kq, kamax):
            # fused: dequantise inside the attention contraction (inline)
            k = _dequant(kq[:, :, 0], kamax[:, :, 0])
            s = jnp.einsum("bhd,bthd->bht", q, k)
            p = jax.nn.softmax(s, axis=-1)
            v = _dequant(kq[:, :, 1], kamax[:, :, 1])
            return jnp.einsum("bht,bthd->bhd", p, v)

        t_plain = time_fn(lambda: plain(q, kv).block_until_ready(), iters=5)
        t_sw = time_fn(lambda: sw_mode(q, kq, kamax).block_until_ready(), iters=5)
        t_hw = time_fn(lambda: hw_mode(q, kq, kamax).block_until_ready(), iters=5)
        csv(f"fig6c_ktls_ctx{ctx}_plain", t_plain * 1e6, "mode=plaintext")
        csv(f"fig6c_ktls_ctx{ctx}_sw", t_sw * 1e6,
            f"slowdown_vs_plain={t_sw/t_plain:.2f} (separate pass)")
        csv(f"fig6c_ktls_ctx{ctx}_hw", t_hw * 1e6,
            f"slowdown_vs_plain={t_hw/t_plain:.2f} (fused inline)")


if __name__ == "__main__":
    main()
