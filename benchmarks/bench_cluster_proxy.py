"""Multi-worker cluster proxy throughput (the scale-out experiment).

Three questions, machine-readable answers:

1. **Worker scaling** — the same batched proxy workload on a
   ``LibraCluster`` of W ∈ {1, 2, 4} workers, 0% cross-worker flows.
   Workers are independent event loops; on real cores they run
   concurrently, so the single-process repro reports the **ideal-parallel
   wall clock**: ``max`` over per-worker completion times
   (``ClusterRuntime.run_parallel``). The acceptance line is ≥2.5x msgs/s
   at 4 workers vs 1.
2. **Steering policy** — consistent-hash vs app-defined (round-robin)
   placement at W=4: balance (per-worker share) and its effect on the
   parallel wall clock.
3. **Cross-worker handoff** — fraction sweep f ∈ {0, 0.25, 0.5, 1.0} at
   W=2, interleaved scheduling (no parallel credit): zero-copy grants vs
   the one-copy ``cross_worker_copied`` fallback, plus the identity check
   — aggregate CopyCounters equal to a single-stack run of the SAME
   workload at every fraction (byte identity is asserted in
   tests/test_cluster.py).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import counters_fields, csv, is_smoke, record
from repro.core import (
    ClusterRuntime,
    LibraCluster,
    LibraStack,
    ProxyRuntime,
    build_message,
)

STACK_KW = dict(n_shards=4, pages_per_shard=1024, page_size=16)


def _frames(n_chans: int, n_msgs: int, payload: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [[build_message(rng.integers(100, 200, 6),
                           rng.integers(1000, 2000, payload))
             for _ in range(n_msgs)]
            for _ in range(n_chans)]


def _load_cluster(cl, crt, frames, cross_fraction=0.0):
    w = len(cl.workers)
    dsts = []
    for i, chan_frames in enumerate(frames):
        sw = i % w
        dw = (sw + 1) % w if i < cross_fraction * len(frames) else sw
        src = cl.socket(worker=sw)
        dst = cl.socket(worker=dw)
        crt.channel(src, dst, name=f"ch{i}")
        dsts.append(dst)
        for f in chan_frames:
            src.deliver(f)
    return dsts


def _counters_sum(cl):
    agg = cl.counters_aggregate()
    out = {"meta_copied": agg.meta_copied, "full_copied": agg.full_copied,
           "anchored": agg.anchored, "zero_copied": agg.zero_copied,
           "vpi_injected": agg.vpi_injected, "allocs": agg.allocs,
           "crypto_copied": agg.crypto_copied,
           "cross_worker_grants": agg.cross_worker_grants,
           "cross_worker_copied": agg.cross_worker_copied}
    return out


def main() -> None:
    smoke = is_smoke()
    n_chans = 24 if smoke else 96
    n_msgs = 4 if smoke else 12
    payload = 64 if smoke else 192
    reps = 2 if smoke else 3
    total_msgs = n_chans * n_msgs

    # -- 1. worker scaling (batched, 0% cross-worker, ideal-parallel) -------
    frames = _frames(n_chans, n_msgs, payload)
    base_tput = None
    for workers in (1, 2, 4):
        best = None
        for r in range(reps):
            cl = LibraCluster(workers, secret=b"bench",
                              steering="app",
                              app_fn=lambda flow, n: flow[1] % n,
                              **STACK_KW)
            crt = ClusterRuntime(cl, batched=True, work_stealing=False)
            for i, chan_frames in enumerate(frames):
                src, dst = cl.socket_pair(flow=("ch", i))
                crt.channel(src, dst, name=f"ch{i}")
                for f in chan_frames:
                    src.deliver(f)
            msgs, times = crt.run_parallel()
            wall = max(times)
            if best is None or wall < best[0]:
                best = (wall, msgs, cl, times)
            crt.shutdown()
        wall, msgs, cl, times = best
        assert msgs == total_msgs, (msgs, total_msgs)
        tput = msgs / max(wall, 1e-9)
        if workers == 1:
            base_tput = tput
        speedup = tput / max(base_tput, 1e-9)
        csv(f"cluster_proxy_w{workers}_batched", 1e6 / max(tput, 1e-9),
            f"msgs_per_s={tput:.0f} ideal_parallel_wall_us={wall * 1e6:.0f} "
            f"speedup_vs_1w={speedup:.2f}x "
            f"worker_walls_us={'/'.join(f'{t * 1e6:.0f}' for t in times)}")
        record(f"cluster_proxy_w{workers}_batched_counters",
               workers=workers, msgs_per_s=tput, speedup_vs_1w=speedup,
               steering="app", cross_fraction=0.0, **_counters_sum(cl))

    # -- 1b. threaded executor: REAL wall clock, W ∈ {1, 2, 4} ---------------
    # run_parallel(threads=True) drives one OS thread per worker; unlike
    # series 1 this is measured wall time, not the ideal-parallel max().
    # On a multi-core host the 4-worker series is expected ≥1.5x the
    # 1-worker series; under the GIL on few cores the honest number is
    # ~1x (compute is pure-Python orchestration around numpy), so the
    # expectation is asserted only when the host actually has the cores.
    n_cpus = os.cpu_count() or 1
    base_real = None
    for workers in (1, 2, 4):
        best = None
        for _ in range(reps):
            cl = LibraCluster(workers, secret=b"bench",
                              steering="app",
                              app_fn=lambda flow, n: flow[1] % n,
                              **STACK_KW)
            crt = ClusterRuntime(cl, batched=True, work_stealing=False)
            for i, chan_frames in enumerate(frames):
                src, dst = cl.socket_pair(flow=("ch", i))
                crt.channel(src, dst, name=f"ch{i}")
                for f in chan_frames:
                    src.deliver(f)
            t0 = time.perf_counter()
            msgs, times = crt.run_parallel(threads=True)
            dt = time.perf_counter() - t0
            if best is None or dt < best[0]:
                best = (dt, max(times), msgs, cl)
            crt.shutdown()
        dt, ideal, msgs, cl = best
        assert msgs == total_msgs, (msgs, total_msgs)
        tput = msgs / max(dt, 1e-9)
        if workers == 1:
            base_real = tput
        speedup = tput / max(base_real, 1e-9)
        csv(f"cluster_proxy_w{workers}_threads", 1e6 / max(tput, 1e-9),
            f"msgs_per_s={tput:.0f} real_wall_us={dt * 1e6:.0f} "
            f"ideal_parallel_wall_us={ideal * 1e6:.0f} "
            f"speedup_vs_1w={speedup:.2f}x cpus={n_cpus}")
        record(f"cluster_proxy_w{workers}_threads_counters",
               workers=workers, msgs_per_s=tput, speedup_vs_1w=speedup,
               real_wall_s=dt, ideal_parallel_wall_s=ideal,
               cpu_count=n_cpus, threads=True, **_counters_sum(cl))
        if workers == 4 and n_cpus >= 4:
            assert speedup >= 1.5, \
                f"threaded 4-worker speedup {speedup:.2f}x < 1.5x on " \
                f"a {n_cpus}-CPU host"

    # -- 2. steering: consistent hash vs app-defined at W=4 ------------------
    for steer_name, steer_kw in (
            ("hash", dict(steering="hash")),
            ("app_rr", dict(steering="app",
                            app_fn=lambda flow, n: flow[1] % n))):
        best = None
        for _ in range(reps):
            cl = LibraCluster(4, secret=b"bench", **steer_kw, **STACK_KW)
            crt = ClusterRuntime(cl, batched=True, work_stealing=False)
            for i, chan_frames in enumerate(frames):
                src, dst = cl.socket_pair(flow=("ch", i))
                crt.channel(src, dst, name=f"ch{i}")
                for f in chan_frames:
                    src.deliver(f)
            msgs, times = crt.run_parallel()
            wall = max(times)
            crt.shutdown()
            if best is None or wall < best[0]:
                best = (wall, msgs, cl)
        wall, msgs, cl = best
        share = cl.steering.stats["per_worker"]
        tput = msgs / max(wall, 1e-9)
        csv(f"cluster_proxy_steering_{steer_name}", 1e6 / max(tput, 1e-9),
            f"msgs_per_s={tput:.0f} per_worker_flows={'/'.join(map(str, share))} "
            f"imbalance={max(share) / max(sum(share) / len(share), 1e-9):.2f}")
        record(f"cluster_proxy_steering_{steer_name}_counters",
               workers=4, msgs_per_s=tput, steering=steer_name,
               per_worker_flows=list(share), **_counters_sum(cl))

    # -- 3. cross-worker fraction sweep at W=2 (interleaved, with identity) --
    stack = LibraStack(secret=b"bench", **STACK_KW)
    rt = ProxyRuntime(stack, batched=True)
    for i, chan_frames in enumerate(frames):
        src, dst = stack.socket_pair()
        rt.channel(src, dst, name=f"ch{i}")
        for f in chan_frames:
            src.deliver(f)
    rt.run()
    single_snap = stack.counters.snapshot()
    rt.shutdown()

    for frac in (0.0, 0.25, 0.5, 1.0):
        best = None
        for _ in range(reps):
            cl = LibraCluster(2, secret=b"bench", **STACK_KW)
            crt = ClusterRuntime(cl, batched=True)
            _load_cluster(cl, crt, frames, cross_fraction=frac)
            t0 = time.perf_counter()
            msgs = crt.run()
            dt = time.perf_counter() - t0
            identical = cl.counters_aggregate().snapshot() == single_snap
            if best is None or dt < best[0]:
                best = (dt, msgs, cl, identical)
            crt.shutdown()
        dt, msgs, cl, identical = best
        tput = msgs / max(dt, 1e-9)
        csv(f"cluster_proxy_cross_{int(frac * 100)}pct",
            1e6 / max(tput, 1e-9),
            f"msgs_per_s={tput:.0f} grants={cl.stats['grants']} "
            f"copies={cl.stats['copies']} "
            f"counters_match_single_stack={identical}")
        record(f"cluster_proxy_cross_{int(frac * 100)}pct_counters",
               workers=2, cross_fraction=frac, msgs_per_s=tput,
               counters_match_single_stack=bool(identical),
               grants=cl.stats["grants"], copies=cl.stats["copies"],
               **_counters_sum(cl))


if __name__ == "__main__":
    main()
