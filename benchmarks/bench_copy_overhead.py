"""Paper Fig. 1a + Table 2: how much of the step cost is the copy tax, and
how small the metadata really is.

Fig 1a analogue: fraction of the standard engine's per-step data movement
that is pure payload copying (re-materialised contiguous KV + logits
shipping), vs Libra's metadata-only movement — reported for two payload
(context) sizes like the paper's 16KB/256KB pair.

Table 2 analogue: metadata fraction of the message for each built-in parser
policy on representative messages.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, prompts_for, proxy_model, run_engine
from repro.core.parser import (
    ChunkedParser,
    DelimiterParser,
    LengthPrefixedParser,
    build_chunked_message,
    build_delimited_message,
    build_message,
)
from repro.serving.engine import LibraEngine, StandardEngine


def main() -> None:
    cfg, model, params = proxy_model()
    for ctx in (32, 256):
        prompts = prompts_for(cfg.vocab_size, 4, ctx)
        gen = 8
        libra, t_l = run_engine(LibraEngine, model, params, prompts, gen,
                                max_batch=4, max_len=ctx + gen + 8, page_size=8)
        std, t_s = run_engine(StandardEngine, model, params, prompts, gen,
                              max_batch=4, max_len=ctx + gen + 8)
        s = std.stats
        copy_frac = s.payload_copy_bytes / max(
            s.payload_copy_bytes + s.d2h_bytes + s.h2d_bytes, 1)
        l = libra.stats
        libra_frac = l.payload_copy_bytes / max(
            l.anchored_bytes + l.h2d_bytes + l.d2h_bytes, 1)
        csv(f"fig1a_copy_fraction_std_ctx{ctx}", t_s * 1e6 / max(s.steps, 1),
            f"copy_frac={copy_frac:.3f}")
        csv(f"fig1a_copy_fraction_libra_ctx{ctx}", t_l * 1e6 / max(l.steps, 1),
            f"copy_frac={libra_frac:.3f}")

    # Table 2: metadata fraction per protocol policy
    rng = np.random.default_rng(0)
    meta = rng.integers(100, 200, 12)
    payload = rng.integers(1000, 2000, 2048)
    msgs = {
        "http1.0-length-prefixed":
            (LengthPrefixedParser(), build_message(meta, payload)),
        "http-delimited":
            (DelimiterParser(), build_delimited_message(meta, payload)),
        "http1.1-chunked":
            (ChunkedParser(), build_chunked_message(
                [payload[i:i + 256] for i in range(0, 2048, 256)])),
    }
    for name, (parser, msg) in msgs.items():
        res = parser.parse(msg)
        frac = res.meta_len / len(msg)
        csv(f"table2_meta_fraction_{name}", 0.0,
            f"meta={res.meta_len}tok of {len(msg)} ({frac:.4f})")


if __name__ == "__main__":
    main()
