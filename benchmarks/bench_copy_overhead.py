"""Paper Fig. 1a + Table 2: how much of the step cost is the copy tax, and
how small the metadata really is.

Fig 1a analogue: fraction of the standard engine's per-step data movement
that is pure payload copying (re-materialised contiguous KV + logits
shipping), vs Libra's metadata-only movement — reported for two payload
(context) sizes like the paper's 16KB/256KB pair.

Table 2 analogue: metadata fraction per built-in parser policy, measured
the honest way — by pushing a representative message through a
LibraSocket and reading the stack's copy counters (what actually crossed
the user boundary), not by inspecting parser internals.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    BUILDERS,
    csv,
    is_smoke,
    prompts_for,
    proxy_model,
    run_engine,
    stream_stack,
)


def engine_section() -> None:
    from repro.serving.engine import LibraEngine, StandardEngine

    cfg, model, params = proxy_model()
    for ctx in (32, 256):
        prompts = prompts_for(cfg.vocab_size, 4, ctx)
        gen = 8
        libra, t_l = run_engine(LibraEngine, model, params, prompts, gen,
                                max_batch=4, max_len=ctx + gen + 8, page_size=8)
        std, t_s = run_engine(StandardEngine, model, params, prompts, gen,
                              max_batch=4, max_len=ctx + gen + 8)
        s = std.stats
        copy_frac = s.payload_copy_bytes / max(
            s.payload_copy_bytes + s.d2h_bytes + s.h2d_bytes, 1)
        l = libra.stats
        libra_frac = l.payload_copy_bytes / max(
            l.anchored_bytes + l.h2d_bytes + l.d2h_bytes, 1)
        csv(f"fig1a_copy_fraction_std_ctx{ctx}", t_s * 1e6 / max(s.steps, 1),
            f"copy_frac={copy_frac:.3f}")
        csv(f"fig1a_copy_fraction_libra_ctx{ctx}", t_l * 1e6 / max(l.steps, 1),
            f"copy_frac={libra_frac:.3f}")


def table2_section() -> None:
    """Metadata fraction per protocol policy, through the socket facade."""
    rng = np.random.default_rng(0)
    meta = rng.integers(100, 200, 12)
    payload = rng.integers(1000, 2000, 2048)
    for proto, build in BUILDERS.items():
        stack = stream_stack(pages=2048, page_size=16)
        src, dst = stack.socket_pair(proto)
        src.deliver(build(meta, payload))
        logical = rx_copied = 0
        while src.rx_available() > 0:
            # Table 2 is a recv-boundary metric: meter the recv calls only,
            # excluding the send side's own metadata copy
            before = stack.counters.total_user_copies()
            buf, n = src.recv(1 << 20)
            if n == 0:
                break
            logical += n
            rx_copied += stack.counters.total_user_copies() - before
            src.forward(dst, buf)
        frac = rx_copied / max(logical, 1)
        csv(f"table2_meta_fraction_{proto}", 0.0,
            f"rx_copied={rx_copied}tok of {logical} ({frac:.4f}) "
            f"zerocopy={stack.counters.zero_copied}")


def main() -> None:
    table2_section()
    if not is_smoke():
        engine_section()


if __name__ == "__main__":
    main()
