"""Cluster-level fault-tolerance utilities (design + host-side mechanisms).

What runs here vs. what the cluster controller owns:

* **Preemption / checkpoint-restart** — implemented: signal-triggered final
  checkpoint (Trainer.install_signal_handlers) + atomic-commit checkpoints +
  exact pipeline resume. At 1000+ nodes the same protocol is driven by the
  cluster scheduler's preemption notice (SIGTERM with a grace window).
* **Elastic re-mesh** — implemented: checkpoints are mesh-agnostic; restore
  recomputes shardings for the surviving mesh (e.g. 2-pod 512 -> 1-pod 256
  after a pod loss) and re-places leaves. Batch size/LR rescaling policy is
  the caller's (examples/train_driver.py shows halving global batch).
* **Straggler mitigation** — implemented: rolling-median step-time deadline
  (Trainer); this module adds the *slice-level* monitor that decides between
  (a) tolerating, (b) excluding a slow pod from the 'pod' axis at the next
  re-mesh, (c) requesting a hot-spare swap. On real fleets the signal comes
  from per-host step barriers; here it is fed by step timings.
* **Gradient compression** — int8 + error feedback over the cross-pod axis
  (repro.training.optimizer.compressed_psum): DCI bandwidth is ~4x scarcer
  than ICI, and DP gradients are the only cross-pod traffic in our layout.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional


@dataclasses.dataclass
class SliceHealth:
    slice_id: int
    step_times: List[float] = dataclasses.field(default_factory=list)
    missed_barriers: int = 0
    excluded: bool = False


class StragglerMonitor:
    """Tracks per-slice (pod) step times; flags slices whose rolling median
    exceeds ``factor`` x the fleet median for ``patience`` windows."""

    def __init__(self, n_slices: int, factor: float = 1.5, patience: int = 3,
                 window: int = 20):
        self.slices = {i: SliceHealth(i) for i in range(n_slices)}
        self.factor = factor
        self.patience = patience
        self.window = window
        self._strikes: Dict[int, int] = {i: 0 for i in range(n_slices)}

    def record(self, slice_id: int, step_time: float) -> None:
        h = self.slices[slice_id]
        h.step_times.append(step_time)
        if len(h.step_times) > self.window:
            h.step_times.pop(0)

    def fleet_median(self) -> float:
        times = [t for h in self.slices.values() if not h.excluded
                 for t in h.step_times]
        return statistics.median(times) if times else 0.0

    def evaluate(self) -> List[int]:
        """Returns slice ids recommended for exclusion at the next re-mesh."""
        fleet = self.fleet_median()
        out = []
        if fleet <= 0:
            return out
        for sid, h in self.slices.items():
            if h.excluded or len(h.step_times) < 5:
                continue
            med = statistics.median(h.step_times)
            if med > self.factor * fleet:
                self._strikes[sid] += 1
            else:
                self._strikes[sid] = 0
            if self._strikes[sid] >= self.patience:
                out.append(sid)
        return out

    def exclude(self, slice_id: int) -> None:
        self.slices[slice_id].excluded = True


@dataclasses.dataclass
class ElasticPlan:
    """Re-mesh decision after slice loss/exclusion."""
    surviving_pods: int
    mesh_shape: tuple
    global_batch_scale: float
    lr_scale: float


def plan_elastic_restart(total_pods: int, lost_pods: int,
                         keep_batch: bool = False) -> ElasticPlan:
    """Degrade the 'pod' axis, keeping the within-pod (data, model) layout.
    Linear-scaling rule for LR when the global batch shrinks."""
    surviving = total_pods - lost_pods
    assert surviving >= 1, "no surviving pods"
    scale = 1.0 if keep_batch else surviving / total_pods
    shape = (surviving, 16, 16) if surviving > 1 else (16, 16)
    return ElasticPlan(surviving, shape, scale, scale)
