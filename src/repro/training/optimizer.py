"""Optimizers & LR schedules (pure JAX, no optax dependency).

AdamW with decoupled weight decay and global-norm clipping; LR schedules
include cosine and MiniCPM's warmup-stable-decay (WSD). Optimizer states are
fp32 and inherit the parameter sharding (ZeRO-1 via FSDP param sharding).

``compressed_allreduce`` implements int8 gradient compression with error
feedback for cross-pod gradient reduction (distributed-optimization trick;
see repro.distributed).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # scalar int32
    mu: Any              # first moment (pytree, f32)
    nu: Any              # second moment (pytree, f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"     # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10000
    # WSD: fraction of total steps spent in the final decay phase
    wsd_decay_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((s - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    if cfg.schedule == "wsd":
        # warmup -> stable plateau -> sqrt-style decay tail (MiniCPM)
        decay_start = 1.0 - cfg.wsd_decay_frac
        tail = jnp.clip((t - decay_start) / cfg.wsd_decay_frac, 0.0, 1.0)
        return cfg.lr * warm * jnp.where(t < decay_start, 1.0, 1.0 - tail)
    raise ValueError(cfg.schedule)


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros))


def abstract_adamw(abstract_params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), zeros,
                      jax.tree.map(lambda x: x, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(
    cfg: AdamWConfig, params, grads, state: AdamWState
) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), \
        {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# gradient compression (error feedback int8) — cross-pod reduction trick
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis_name: str, error_state):
    """int8 all-reduce with error feedback: the quantisation residual is
    carried into the next step, so the compressed reduction is unbiased in
    the long run. Used for the cross-pod ('pod' axis) gradient reduction,
    where DCI bandwidth — not ICI — is the bottleneck."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g)
        deq_sum = jax.lax.psum(dequantize_int8(q, scale), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = deq_sum / n
        new_e = g - dequantize_int8(q, scale)  # local residual
        return mean, new_e

    out = jax.tree.map(one, grads, error_state)
    red = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return red, err
