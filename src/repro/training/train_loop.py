"""Trainer: jitted train step + fault-tolerant loop.

Production behaviors implemented (and tested in tests/test_fault_tolerance):
  * periodic async sharded checkpoints (atomic commit), data-pipeline state
    included for exact resume;
  * preemption handling: SIGTERM/SIGINT triggers a final synchronous
    checkpoint before exit;
  * crash/restart: ``Trainer.resume()`` restores params + optimizer +
    pipeline state and continues bit-exactly;
  * elastic restart: restore onto a different mesh (shardings recomputed,
    leaves re-placed);
  * straggler mitigation: per-step deadline monitor; steps exceeding
    ``straggler_factor`` × rolling median are logged and counted (hook point
    for hot-spare swap at cluster level);
  * optional int8 gradient compression with error feedback for the
    cross-pod axis (repro.training.optimizer.compressed_psum).
"""
from __future__ import annotations

import signal
import statistics
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.common.sharding import tree_shardings
from repro.data.pipeline import DataPipeline, PipelineState
from repro.training.optimizer import (
    AdamWConfig,
    AdamWState,
    abstract_adamw,
    adamw_update,
    init_adamw,
)


class Trainer:
    def __init__(
        self,
        model,
        opt_cfg: AdamWConfig,
        pipeline: DataPipeline,
        *,
        mesh=None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 50,
        remat: str = "none",
        straggler_factor: float = 3.0,
        seed: int = 0,
    ):
        self.model = model
        self.opt_cfg = opt_cfg
        self.pipeline = pipeline
        self.mesh = mesh
        self.remat = remat
        self.ckpt = Checkpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.straggler_factor = straggler_factor
        self.step_times: List[float] = []
        self.straggler_events = 0
        self.step = 0
        self._preempted = False

        self.params = model.init_params(jax.random.PRNGKey(seed))
        self.opt_state = init_adamw(self.params)
        if mesh is not None:
            p_sh = tree_shardings(self.params, model.param_axes(), mesh)
            self.params = jax.tree.map(jax.device_put, self.params, p_sh)
        tp = 1
        if mesh is not None and "model" in mesh.axis_names:
            tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]

        def train_step(params, opt_state, batch):
            def lf(p):
                return model.loss_fn(p, batch, remat=remat, tp_size=tp)
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            new_p, new_o, om = adamw_update(opt_cfg, params, grads, opt_state)
            return new_p, new_o, {"loss": metrics["loss"], **om}

        self._jit_step = jax.jit(train_step, donate_argnums=(0, 1))
        self.history: List[Dict[str, float]] = []

    # -- preemption -----------------------------------------------------------
    def install_signal_handlers(self) -> None:
        def _handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, _handler)
        signal.signal(signal.SIGINT, _handler)

    # -- checkpointing ----------------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self, blocking: bool = False) -> None:
        if self.ckpt is None:
            return
        extra = {"pipeline": self.pipeline.state.as_dict(), "step": self.step}
        self.ckpt.save(self.step, self._state_tree(), extra, blocking=blocking)

    def resume(self, mesh=None) -> bool:
        """Restore the latest checkpoint (optionally onto a new mesh —
        elastic restart). Returns True if something was restored."""
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        mesh = mesh or self.mesh
        shardings = None
        if mesh is not None:
            p_sh = tree_shardings(
                jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                             self.params),
                self.model.param_axes(), mesh)
            shardings = {"params": p_sh,
                         "opt": AdamWState(None, p_sh, jax.tree.map(lambda s: s, p_sh))}
        tree, extra = self.ckpt.restore(latest, self._state_tree(), shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = extra["step"]
        self.pipeline.state = PipelineState.from_dict(extra["pipeline"])
        return True

    # -- the loop ----------------------------------------------------------------
    def train(self, num_steps: int, log_every: int = 10) -> List[Dict]:
        ctx = self.mesh if self.mesh is not None else _NullCtx()
        with ctx:
            for _ in range(num_steps):
                if self._preempted:
                    self.save(blocking=True)
                    break
                t0 = time.perf_counter()
                batch = self.pipeline.next_batch()
                jb = {"tokens": jnp.asarray(batch["tokens"]),
                      "labels": jnp.asarray(batch["labels"])}
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, jb)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.step += 1
                self.step_times.append(dt)
                if len(self.step_times) >= 5:
                    med = statistics.median(self.step_times[-50:])
                    if dt > self.straggler_factor * med:
                        self.straggler_events += 1
                self.history.append({"step": self.step, "loss": loss,
                                     "lr": float(metrics["lr"]),
                                     "grad_norm": float(metrics["grad_norm"]),
                                     "time": dt})
                if self.ckpt and self.step % self.checkpoint_every == 0:
                    self.save()
            if self.ckpt:
                self.ckpt.wait()
        return self.history


class _NullCtx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False
