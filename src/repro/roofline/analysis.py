"""Roofline terms: compute / memory / collective seconds per step per chip,
plus analytic MODEL_FLOPS for the useful-compute ratio."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.common.types import MeshSpec, ModelConfig, ShapeSpec
from repro.roofline import hw
from repro.roofline.hlo_analysis import HloCosts


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float        # analytic useful FLOPs (whole step, all chips)
    hlo_flops_device: float         # analyzer FLOPs per device
    useful_ratio: float             # model_flops / (hlo_flops * chips)

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_terms(costs: HloCosts, model_flops: float, n_chips: int,
                   compute_dtype_peak: float = hw.PEAK_FLOPS_BF16) -> RooflineTerms:
    compute_s = costs.flops / compute_dtype_peak
    memory_s = costs.hbm_bytes / hw.HBM_BW
    collective_s = costs.collective_ring / hw.ICI_LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_total = costs.flops * n_chips
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops_total=model_flops,
        hlo_flops_device=float(costs.flops),
        useful_ratio=(model_flops / hlo_total) if hlo_total else 0.0)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (6·N·D convention + attention/scan terms)
# ---------------------------------------------------------------------------

def _embed_params(cfg: ModelConfig) -> int:
    return cfg.vocab_size * cfg.d_model


def matmul_params(cfg: ModelConfig) -> int:
    """Active parameters that participate in matmuls (embedding lookup
    excluded; tied lm_head counted once as compute below)."""
    n = cfg.active_param_count()
    n -= _embed_params(cfg)  # lookup table
    if cfg.family == "encdec":
        n -= 0  # embed already subtracted; enc/dec both matmul-active
    return max(n, 0)


def _attn_flops_fwd(cfg: ModelConfig, tokens: int, ctx: int, batch: int) -> float:
    """Score + PV flops for causal attention, per forward pass."""
    if cfg.family == "ssm":
        # recurrent scan term: ~10 flops per (token, channel, state)
        ud = cfg.ssm_expand * cfg.d_model
        return 10.0 * tokens * ud * (ud // cfg.num_heads) / 64  # matrix memory, chunked
    qdim = cfg.num_heads * cfg.head_dim
    layers = cfg.num_layers
    if cfg.family == "hybrid":
        # window layers see min(ctx, window); plus mamba scan term
        n_glob = len(cfg.global_attn_layers)
        n_win = layers - n_glob
        eff = n_glob * ctx / 2 + n_win * min(cfg.window, ctx / 2)
        ssm = 10.0 * tokens * (cfg.ssm_expand * cfg.d_model) * cfg.ssm_state * layers
        return 4.0 * tokens * qdim * eff + ssm
    if cfg.family == "encdec":
        enc = 4.0 * batch * cfg.enc_frames * cfg.enc_frames * qdim * cfg.enc_layers / 1
        self_a = 4.0 * tokens * qdim * (ctx / 2) * layers
        cross = 4.0 * tokens * qdim * cfg.enc_frames * layers
        return enc + self_a + cross
    return 4.0 * tokens * qdim * (ctx / 2) * layers


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    b, s = shape.global_batch, shape.seq_len
    nm = matmul_params(cfg)
    logits_flops = 2.0 * cfg.d_model * cfg.vocab_size

    if shape.kind == "train":
        tokens = b * s
        fwd = 2.0 * tokens * nm + tokens * logits_flops \
            + _attn_flops_fwd(cfg, tokens, s, b)
        return 3.0 * fwd
    if shape.kind == "prefill":
        tokens = b * s
        return 2.0 * tokens * nm + b * logits_flops \
            + _attn_flops_fwd(cfg, tokens, s, b)
    # decode: one token per request over ctx = s
    tokens = b
    if cfg.family == "ssm":
        scan = _attn_flops_fwd(cfg, tokens, s, b)
        return 2.0 * tokens * nm + tokens * logits_flops + scan
    qdim = cfg.num_heads * cfg.head_dim
    if cfg.family == "hybrid":
        n_glob = len(cfg.global_attn_layers)
        n_win = cfg.num_layers - n_glob
        eff = n_glob * s + n_win * min(cfg.window, s)
        attn = 4.0 * tokens * qdim * eff
        attn += 10.0 * tokens * (cfg.ssm_expand * cfg.d_model) * cfg.ssm_state \
            * cfg.num_layers
    elif cfg.family == "encdec":
        attn = 4.0 * tokens * qdim * s * cfg.num_layers \
            + 4.0 * tokens * qdim * cfg.enc_frames * cfg.num_layers
        nm = nm  # encoder runs at prefill, not per decode step
    else:
        attn = 4.0 * tokens * qdim * s * cfg.num_layers
    if cfg.family == "encdec":
        # decoder-side matmul params only for the per-step cost
        nm = nm // 2
    return 2.0 * tokens * nm + tokens * logits_flops + attn
