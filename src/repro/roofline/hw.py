"""TPU v5e hardware constants for the roofline model (per chip)."""

PEAK_FLOPS_BF16 = 197e12       # 197 TFLOP/s bf16
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9                 # 819 GB/s
ICI_LINK_BW = 50e9             # ~50 GB/s per ICI link (per direction)
HBM_BYTES = 16 * 2 ** 30       # 16 GiB HBM per chip
VMEM_BYTES = 128 * 2 ** 20     # ~128 MiB VMEM
DCI_BW = 12.5e9                # inter-pod (data-center interconnect) per chip, est.

MXU_TILE = (128, 128)          # systolic array tile
LANE = 128
SUBLANE_F32 = 8
SUBLANE_BF16 = 16
