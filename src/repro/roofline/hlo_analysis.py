"""Static analyzer for compiled (post-SPMD, post-fusion) HLO text.

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts a while
body ONCE, so any scan-over-layers program (every production model) has its
FLOPs understated by ~num_layers. This walker:

  * builds the computation call graph (entry -> while bodies x trip count,
    fusions, calls, conditionals),
  * recovers scan trip counts from while-condition compare constants,
  * counts dot FLOPs from operand/result shapes x multiplicity,
  * estimates HBM traffic as bytes crossing fusion boundaries (operands +
    results of top-level instructions — the standard post-fusion roofline
    estimate),
  * sums collective bytes per device with ring-algorithm link-traffic
    adjustment (all-gather/reduce-scatter (N-1)/N, all-reduce 2(N-1)/N).

All quantities are PER DEVICE (HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string (handles tuples by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        total += _DTYPE_BYTES.get(dt, 4) * _numel(dims)
    return total


def first_array_shape(shape_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instruction:
    name: str
    shape_str: str
    opcode: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    by_name: Dict[str, Instruction]


_COMP_HDR = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)(?:\.clone)? \(.*\) -> .* \{")
_INSTR = re.compile(
    r"^\s*(?:ROOT )?%?([\w.\-]+) = ((?:\([^)]*\))|(?:[\w\[\],]+(?:\{[\d,]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip())
        if hdr and ("->" in line) and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, shape_str, opcode, rest = m.groups()
            # operands: %refs before the first '),' attribute boundary
            paren = rest.split("),")[0] if ")," in rest else rest
            ops = _OPERAND.findall(paren)
            inst = Instruction(name, shape_str, opcode, ops, line)
            cur.instructions.append(inst)
            cur.by_name[name] = inst
    return comps


def _attr(raw: str, key: str) -> Optional[str]:
    m = re.search(key + r"=([^,\s]+)", raw)
    return m.group(1) if m else None


def _called_comps(inst: Instruction) -> List[str]:
    """Computations invoked by this instruction (fusion/call/map/reduce...)."""
    names = []
    for key in ("calls", "to_apply", "body", "condition", "true_computation",
                "false_computation", "branch_computations"):
        m = re.search(key + r"=\{?%?([\w.\-]+(?:, ?%?[\w.\-]+)*)\}?", inst.raw)
        if m:
            for n in m.group(1).split(","):
                names.append(n.strip().lstrip("%"))
    return names


def while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Scan-generated while conds compare an s32 induction var to a constant."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for inst in cond.instructions:
        if inst.opcode == "constant" and inst.shape_str.startswith("s32"):
            m = re.search(r"constant\((-?\d+)\)", inst.raw)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def dot_flops(inst: Instruction, comp: Computation,
              shapes: Dict[str, str]) -> int:
    out = first_array_shape(inst.shape_str)
    if out is None:
        return 0
    _, out_dims = out
    lhs_name = inst.operands[0] if inst.operands else None
    lhs_shape_str = shapes.get(lhs_name)
    if lhs_shape_str is None:
        return 0
    lhs = first_array_shape(lhs_shape_str)
    if lhs is None:
        return 0
    _, lhs_dims = lhs
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.raw)
    contracted = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            contracted *= lhs_dims[int(d)]
    return 2 * _numel(",".join(map(str, out_dims))) * contracted


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(inst: Instruction) -> Tuple[str, int, int]:
    """Returns (kind, naive_operand_bytes, ring_link_bytes) per device."""
    kind = inst.opcode
    if kind.endswith("-start"):
        kind = kind[: -len("-start")]
    result_bytes = shape_bytes(inst.shape_str)
    # group size N from replica_groups=[G,N]<= or explicit {{...},{...}}
    n = 1
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", inst.raw)
    if m:
        n = int(m.group(2))
    else:
        m = re.search(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}", inst.raw)
        if m:
            first = m.group(1).split("},")[0].strip("{}")
            n = len([x for x in first.split(",") if x.strip() != ""])
    n = max(n, 1)
    if kind == "all-gather":
        naive = result_bytes // n
        ring = result_bytes * (n - 1) // n
    elif kind == "all-reduce":
        naive = result_bytes
        ring = 2 * result_bytes * (n - 1) // n
    elif kind == "reduce-scatter":
        naive = result_bytes * n
        ring = result_bytes * (n - 1)
    elif kind == "all-to-all":
        naive = result_bytes
        ring = result_bytes * (n - 1) // n
    else:  # collective-permute
        naive = result_bytes
        ring = result_bytes
    return kind, naive, ring


_SKIP_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id", "replica-id",
    "iota",
}

_SLICING_OPS = {"dynamic-slice", "gather", "slice"}
_INPLACE_OPS = {"dynamic-update-slice", "scatter"}


def _operand_traffic(op_shape_str: str, users_ops: List[Tuple[str, str]]) -> int:
    """Bytes actually read from an operand given its user instructions.

    If every user is a slicing op, only the slices' outputs are read; an
    in-place update (DUS/scatter) reads just the updated region (charged on
    the output side instead)."""
    full = shape_bytes(op_shape_str)
    if not users_ops:
        return full
    if all(op in _SLICING_OPS or op in _INPLACE_OPS for op, _ in users_ops):
        sliced = sum(shape_bytes(s) for op, s in users_ops if op in _SLICING_OPS)
        return min(full, sliced)
    return full


def instruction_traffic(inst: Instruction, shapes: Dict[str, str],
                        comps: Dict[str, "Computation"]) -> int:
    """HBM bytes for one top-level (fusion-boundary) instruction."""
    op = inst.opcode
    if op in _SKIP_TRAFFIC or op.endswith("-done"):
        return 0
    if op == "dynamic-slice" or op == "slice":
        return 2 * shape_bytes(inst.shape_str)
    if op == "gather":
        return 2 * shape_bytes(inst.shape_str)
    if op == "dynamic-update-slice":
        # in-place: read+write the updated region (operand 1)
        upd = shapes.get(inst.operands[1], "") if len(inst.operands) > 1 else ""
        return 2 * shape_bytes(upd)
    if op == "scatter":
        upd = shapes.get(inst.operands[-1], "") if inst.operands else ""
        return 2 * shape_bytes(upd)
    if op == "fusion":
        called = _called_comps(inst)
        fused = comps.get(called[0]) if called else None
        if fused is None:
            return shape_bytes(inst.shape_str) + sum(
                shape_bytes(shapes.get(o, "")) for o in inst.operands)
        # map fusion operands -> parameter users inside the fused computation
        params: Dict[int, str] = {}
        users: Dict[str, List[Tuple[str, str]]] = {}
        for fi in fused.instructions:
            if fi.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", fi.raw)
                if m:
                    params[int(m.group(1))] = fi.name
        for fi in fused.instructions:
            for o in fi.operands:
                users.setdefault(o, []).append((fi.opcode, fi.shape_str))
        total = 0
        for idx, oname in enumerate(inst.operands):
            pname = params.get(idx)
            total += _operand_traffic(shapes.get(oname, ""),
                                      users.get(pname, []) if pname else [])
        # output side: in-place DUS roots write only the update region
        dus_bytes = 0
        dus_full = 0
        for fi in fused.instructions:
            if fi.opcode == "dynamic-update-slice":
                upd = fi.operands[1] if len(fi.operands) > 1 else None
                upd_shape = next((x.shape_str for x in fused.instructions
                                  if x.name == upd), "")
                dus_bytes += 2 * shape_bytes(upd_shape)
                dus_full += shape_bytes(fi.shape_str)
        out_bytes = shape_bytes(inst.shape_str)
        total += dus_bytes + max(0, out_bytes - dus_full)
        return total
    return shape_bytes(inst.shape_str) + sum(
        shape_bytes(shapes.get(o, "")) for o in inst.operands)


@dataclasses.dataclass
class HloCosts:
    flops: int = 0
    hbm_bytes: int = 0
    collective_naive: int = 0
    collective_ring: int = 0
    collective_breakdown: Dict[str, int] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)

    def merge_scaled(self, other: "HloCosts", k: int) -> None:
        self.flops += other.flops * k
        self.hbm_bytes += other.hbm_bytes * k
        self.collective_naive += other.collective_naive * k
        self.collective_ring += other.collective_ring * k
        self.collective_count += other.collective_count * k
        for kk, v in other.collective_breakdown.items():
            self.collective_breakdown[kk] = (
                self.collective_breakdown.get(kk, 0) + v * k)


def analyze_computation(
    comps: Dict[str, Computation], name: str,
    memo: Dict[str, HloCosts], top_level: bool,
) -> HloCosts:
    key = f"{name}@{top_level}"
    if key in memo:
        return memo[key]
    comp = comps.get(name)
    costs = HloCosts()
    if comp is None:
        memo[key] = costs
        return costs
    shapes = {i.name: i.shape_str for i in comp.instructions}
    for inst in comp.instructions:
        op = inst.opcode
        if op in ("dot", "dot-general"):
            costs.flops += dot_flops(inst, comp, shapes)
            if top_level:
                costs.hbm_bytes += instruction_traffic(inst, shapes, comps)
        elif op.rstrip("-start").rstrip("-done") in _COLLECTIVES or \
                any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith("-done"):
                continue
            kind, naive, ring = collective_bytes(inst)
            costs.collective_naive += naive
            costs.collective_ring += ring
            costs.collective_count += 1
            costs.collective_breakdown[kind] = (
                costs.collective_breakdown.get(kind, 0) + ring)
            if top_level:
                costs.hbm_bytes += shape_bytes(inst.shape_str)
        elif op == "while":
            body = _attr(inst.raw, "body")
            cond = _attr(inst.raw, "condition")
            body = body.lstrip("%") if body else None
            cond = cond.lstrip("%") if cond else None
            trip = while_trip_count(comps, cond) if cond else 1
            costs.trip_counts.append(trip)
            if body:
                sub = analyze_computation(comps, body, memo, True)
                costs.merge_scaled(sub, trip)
                costs.trip_counts.extend([t for t in sub.trip_counts])
        elif op == "fusion":
            for c in _called_comps(inst):
                sub = analyze_computation(comps, c, memo, False)
                # fused interior: only flops count; traffic is the fusion IO
                costs.flops += sub.flops
            if top_level:
                costs.hbm_bytes += instruction_traffic(inst, shapes, comps)
        elif op in ("call", "conditional", "custom-call", "map", "reduce",
                    "sort", "scatter", "reduce-window", "select-and-scatter"):
            for c in _called_comps(inst):
                sub = analyze_computation(comps, c, memo,
                                          op in ("call", "conditional"))
                costs.merge_scaled(sub, 1)
            if top_level and op not in ("call", "conditional"):
                costs.hbm_bytes += instruction_traffic(inst, shapes, comps)
        else:
            if top_level and op not in _SKIP_TRAFFIC:
                costs.hbm_bytes += instruction_traffic(inst, shapes, comps)
    memo[key] = costs
    return costs


def find_entry(comps: Dict[str, Computation], text: str) -> str:
    m = re.search(r"^ENTRY %?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


def analyze_hlo_text(text: str) -> HloCosts:
    comps = parse_hlo(text)
    entry = find_entry(comps, text)
    return analyze_computation(comps, entry, {}, True)
