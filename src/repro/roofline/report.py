# libra: waive[IMPORT001] report renderer invoked as python -m repro.roofline.report (subprocess, not statically imported)
"""Render the §Roofline markdown table from dry-run artifacts.

  PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = [
    "phi3-mini-3.8b", "phi4-mini-3.8b", "minicpm-2b", "mistral-nemo-12b",
    "hymba-1.5b", "xlstm-350m", "whisper-medium", "qwen3-moe-30b-a3b",
    "qwen2-moe-a2.7b", "internvl2-76b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str) -> List[Dict]:
    return [json.load(open(f)) for f in sorted(glob.glob(os.path.join(dir_, "*.json")))]


def fmt_row(r: Dict) -> str:
    cell = f"{r['arch']} | {r['shape']} | {r['mesh']}"
    if r.get("skipped"):
        return f"| {cell} | — | — | — | — | — | skip (full attention) |"
    if not r.get("ok"):
        return f"| {cell} | — | — | — | — | — | FAIL |"
    t = r["roofline"]
    h = r["hlo"]
    dom = t["dominant"]
    peak = r["memory"]["peak_estimate_bytes"] / 2 ** 30
    return (f"| {cell} | {t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | **{dom}** | {t['useful_ratio']:.2f} | "
            f"{peak:.1f} GiB |")


def main() -> None:
    ap = argparse.ArgumentParser()
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    ap.add_argument("--dir", default=os.path.join(here, "results", "dryrun"))
    args = ap.parse_args()
    recs = load(args.dir)
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    print("| arch | shape | mesh | compute s | memory s | collective s "
          "| dominant | useful | peak/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("singlepod", "multipod"):
                r = by_key.get((arch, shape, mesh))
                if r:
                    print(fmt_row(r))
    # coverage summary
    ok = sum(1 for r in recs if r.get("ok") and not r.get("skipped"))
    skip = sum(1 for r in recs if r.get("skipped"))
    fail = sum(1 for r in recs if not r.get("ok"))
    print(f"\ncells: {ok} compiled OK, {skip} assignment skips, {fail} failed")


if __name__ == "__main__":
    main()
