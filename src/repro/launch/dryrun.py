# libra: waive[IMPORT001] launch entry point driven via subprocess in test_dryrun_launch (invisible to the static graph)
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell this lowers AND compiles
the real step function (train_step for train shapes, prefill/serve_step for
serving shapes) against ShapeDtypeStruct inputs — no allocation — on the
production meshes: single-pod (16×16 = 256 chips) and multi-pod
(2×16×16 = 512 chips). It records memory_analysis + cost_analysis + the
trip-count-corrected HLO roofline terms into one JSON per cell.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod both]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.sharding import spec_for, tree_shardings
from repro.common.types import SHAPES_BY_NAME, MeshSpec, ModelConfig, ShapeSpec
from repro.configs import ARCHS, get_config
from repro.models.attention import plan_decode_sharding
from repro.models.registry import build_model, decode_layout, input_specs
from repro.roofline.analysis import model_flops, roofline_terms
from repro.roofline.hlo_analysis import analyze_hlo_text
from repro.training.optimizer import AdamWConfig, abstract_adamw, adamw_update

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and cfg.has_full_attention:
        return ("full-attention architecture: long_500k requires sub-quadratic "
                "attention (skip recorded per assignment; see DESIGN.md)")
    return None


def _batch_shardings(specs: Dict[str, Any], mesh, batch_axis,
                     rules=None) -> Dict[str, Any]:
    """Sharding tree for a dry-run input-spec dict (batch dim 0 unless pool)."""
    all_axes = tuple(mesh.axis_names)

    def batch_spec(sds):
        if rules is not None:
            return NamedSharding(mesh, spec_for(
                sds.shape, ("batch",) + (None,) * (len(sds.shape) - 1), mesh,
                rules))
        return NamedSharding(mesh, P(batch_axis, *([None] * (len(sds.shape) - 1))))

    def shard_one(key, sds):
        if key == "pool":
            return NamedSharding(mesh, P(None, all_axes))
        if key == "cross_kv":  # layer-stacked [L, B, ...]: batch is dim 1
            return NamedSharding(mesh, P(None, batch_axis,
                                         *([None] * (len(sds.shape) - 2))))
        if key in ("state", "ssm_state"):  # handled a level up
            return None
        return batch_spec(sds)

    out = {}
    for k, v in specs.items():
        if isinstance(v, dict):
            out[k] = {kk: NamedSharding(mesh, P(None, batch_axis,
                                                *([None] * (len(vv.shape) - 2))))
                      for kk, vv in v.items()}
        else:
            out[k] = shard_one(k, v)
    return out


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Tuple:
    """Returns (fn, args tuple, in_shardings tuple, donate_argnums)."""
    model = build_model(cfg)
    mesh_spec = MeshSpec(tuple(mesh.devices.shape), tuple(mesh.axis_names))
    tp = mesh_spec.axis_size("model")
    specs = input_specs(cfg, shape, mesh_spec)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_axis = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)

    if shape.kind == "train":
        from repro.common.sharding import STRATEGIES

        # fsdp2d needs >= 1 sample per chip (else the model axis replicates
        # the batch); MoE needs the model axis for expert parallelism.
        fits_2d = shape.global_batch % mesh.devices.size == 0
        default = "fsdp_tp" if (cfg.family == "moe" or not fits_2d) else "fsdp2d"
        strategy = os.environ.get("REPRO_SHARDING", default)
        rules = STRATEGIES[strategy]()
        abs_params = model.abstract_params(jnp.float32)
        p_sh = tree_shardings(abs_params, model.param_axes(), mesh, rules=rules)
        opt = abstract_adamw(abs_params)
        o_sh = type(opt)(NamedSharding(mesh, P()),
                         jax.tree.map(lambda s: s, p_sh),
                         jax.tree.map(lambda s: s, p_sh))
        b_sh = _batch_shardings(specs, mesh, batch_axis, rules=rules)
        opt_cfg = AdamWConfig(schedule=cfg.lr_schedule)

        remat = os.environ.get("REPRO_REMAT", "full")  # §Perf hillclimb knob

        def train_step(params, opt_state, batch):
            def lf(p):
                return model.loss_fn(p, batch, remat=remat, tp_size=tp)
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            new_p, new_o, om = adamw_update(opt_cfg, params, grads, opt_state)
            return new_p, new_o, {"loss": loss, **om}

        return (train_step, (abs_params, opt, specs), (p_sh, o_sh, b_sh), (0, 1))

    # serving cells use bf16 params with pure tensor-parallel sharding:
    # FSDP weight all-gathers are amortised over a whole batch in training
    # but are pure overhead per decode step (hillclimb #2, EXPERIMENTS §Perf)
    from repro.common.sharding import DEFAULT_RULES

    serve_rules = dict(DEFAULT_RULES)
    serve_rules["fsdp"] = ()
    abs_params = model.abstract_params(jnp.bfloat16)
    p_sh = tree_shardings(abs_params, model.param_axes(), mesh,
                          rules=serve_rules)
    b_axis, combine = plan_decode_sharding(shape.global_batch, mesh)
    sh = _batch_shardings(specs, mesh, b_axis)

    if cfg.family == "ssm":
        if shape.kind == "prefill":
            def fn(params, tokens, seq_lens):
                return model.prefill(params, tokens, seq_lens)
            args = (abs_params, specs["tokens"], specs["seq_lens"])
            return (fn, args, (p_sh, sh["tokens"], sh["seq_lens"]), ())

        def fn(params, tokens, seq_lens, state):
            return model.decode_step(params, tokens, seq_lens, state)
        args = (abs_params, specs["tokens"], specs["seq_lens"], specs["state"])
        return (fn, args, (p_sh, sh["tokens"], sh["seq_lens"], sh["state"]), (3,))

    if shape.kind == "prefill":
        names = ["tokens", "seq_lens", "pool", "tables", "token_shard",
                 "token_slot", "token_off", "token_valid"]
        extra = []
        if cfg.family == "vlm":
            extra = ["img_embeds"]
        if cfg.family == "encdec":
            extra = ["frames"]

        def fn(params, *a):
            kw = dict(zip(names + extra, a))
            if cfg.family == "vlm":
                return model.prefill(params, kw["tokens"], kw["seq_lens"],
                                     kw["pool"], kw["tables"], kw["token_shard"],
                                     kw["token_slot"], kw["token_off"],
                                     kw["token_valid"], mesh=mesh,
                                     batch_axis=b_axis, combine_axes=combine,
                                     img_embeds=kw["img_embeds"], tp_size=tp)
            if cfg.family == "encdec":
                return model.prefill(params, kw["tokens"], kw["seq_lens"],
                                     kw["pool"], kw["tables"], kw["token_shard"],
                                     kw["token_slot"], kw["token_off"],
                                     kw["token_valid"], kw["frames"], mesh=mesh,
                                     batch_axis=b_axis, combine_axes=combine,
                                     tp_size=tp)
            return model.prefill(params, kw["tokens"], kw["seq_lens"],
                                 kw["pool"], kw["tables"], kw["token_shard"],
                                 kw["token_slot"], kw["token_off"],
                                 kw["token_valid"], mesh=mesh,
                                 batch_axis=b_axis, combine_axes=combine,
                                 tp_size=tp)

        args = (abs_params,) + tuple(specs[n] for n in names + extra)
        shards = (p_sh,) + tuple(sh[n] for n in names + extra)
        return (fn, args, shards, (3,))  # donate pool

    # decode
    if cfg.family == "encdec":
        def fn(params, tokens, seq_lens, pool, tables, page_pos, wsh, wsl,
               cross_kv):
            return model.decode_step(params, tokens, seq_lens, pool, tables,
                                     page_pos, wsh, wsl, cross_kv, mesh=mesh,
                                     batch_axis=b_axis, combine_axes=combine)
        names = ["tokens", "seq_lens", "pool", "tables", "page_pos",
                 "write_shard", "write_slot", "cross_kv"]
        args = (abs_params,) + tuple(specs[n] for n in names)
        shards = (p_sh,) + tuple(sh[n] for n in names)
        return (fn, args, shards, (3,))

    if cfg.family == "hybrid":
        def fn(params, tokens, seq_lens, pool, tables, page_pos, wsh, wsl,
               ssm_state):
            return model.decode_step(params, tokens, seq_lens, pool, tables,
                                     page_pos, wsh, wsl, mesh=mesh,
                                     batch_axis=b_axis, combine_axes=combine,
                                     ssm_state=ssm_state)
        names = ["tokens", "seq_lens", "pool", "tables", "page_pos",
                 "write_shard", "write_slot", "ssm_state"]
        args = (abs_params,) + tuple(specs[n] for n in names)
        shards = (p_sh,) + tuple(sh[n] for n in names)
        return (fn, args, shards, (3, 8))

    def fn(params, tokens, seq_lens, pool, tables, page_pos, wsh, wsl):
        return model.decode_step(params, tokens, seq_lens, pool, tables,
                                 page_pos, wsh, wsl, mesh=mesh,
                                 batch_axis=b_axis, combine_axes=combine)
    names = ["tokens", "seq_lens", "pool", "tables", "page_pos",
             "write_shard", "write_slot"]
    args = (abs_params,) + tuple(specs[n] for n in names)
    shards = (p_sh,) + tuple(sh[n] for n in names)
    return (fn, args, shards, (3,))


def build_dense_baseline(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Tuple:
    """The paper's 'standard stack' at production scale: contiguous KV
    [L, B, Smax, 2, Hkv, hd] re-materialised every step (undonated) + full
    logits shipped to the host. Sharded (batch->data, seq->model) — the
    best the dense layout can do; batch-only sharding would need 43 GB/chip
    for nemo@32k and not even fit."""
    from repro.common.sharding import DEFAULT_RULES

    model = build_model(cfg)
    serve_rules = dict(DEFAULT_RULES)
    serve_rules["fsdp"] = ()
    abs_params = model.abstract_params(jnp.bfloat16)
    p_sh = tree_shardings(abs_params, model.param_axes(), mesh,
                          rules=serve_rules)
    b, s = shape.global_batch, shape.seq_len
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_axis = data_axes if len(data_axes) > 1 else data_axes[0]
    cache = jax.ShapeDtypeStruct(
        (cfg.num_layers, b, s + 128, 2, cfg.num_kv_heads, cfg.head_dim),
        jnp.bfloat16)
    cache_sh = NamedSharding(mesh, P(None, batch_axis, "model"))
    tok_sh = NamedSharding(mesh, P(batch_axis))

    def fn(params, tokens, seq_lens, kv_cache):
        logits, new_cache = model.decode_step_dense(params, tokens, seq_lens,
                                                    kv_cache)
        return logits, new_cache  # undonated: the copy tax

    args = (abs_params, jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.int32), cache)
    return (fn, args, (p_sh, tok_sh, tok_sh, cache_sh), ())


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, dense_baseline: bool = False) -> Dict:
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    pod = "multipod" if multi_pod else "singlepod"
    cell = f"{arch}__{shape_name}__{pod}"
    if dense_baseline:
        cell += "__dense-baseline"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell + ".json")
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": pod,
                           "ok": False}

    reason = skip_reason(cfg, shape)
    if reason:
        rec.update({"ok": True, "skipped": True, "reason": reason})
        json.dump(rec, open(out_path, "w"), indent=1)
        return rec

    try:
        from repro.common.sharding import STRATEGIES, use_rules

        mesh = make_production_mesh(multi_pod=multi_pod)
        fits_2d = shape.global_batch % mesh.devices.size == 0
        default = "fsdp_tp" if (cfg.family == "moe" or not fits_2d) \
            else "fsdp2d"
        strategy = os.environ.get("REPRO_SHARDING", default) \
            if shape.kind == "train" else "fsdp_tp"
        with mesh, use_rules(STRATEGIES[strategy]()):
            if dense_baseline:
                fn, args, shards, donate = build_dense_baseline(cfg, shape, mesh)
            else:
                fn, args, shards, donate = build_cell(cfg, shape, mesh)
            t0 = time.time()
            jfn = jax.jit(fn, in_shardings=shards, donate_argnums=donate)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        rec["sharding_strategy"] = strategy
        rec["remat"] = os.environ.get("REPRO_REMAT", "full") \
            if shape.kind == "train" else None

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        costs = analyze_hlo_text(txt)
        mf = model_flops(cfg, shape)
        n_chips = mesh.devices.size
        terms = roofline_terms(costs, mf, n_chips)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_chips": int(n_chips),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "cost_analysis": {"flops_body_once": ca.get("flops", 0.0),
                              "bytes_body_once": ca.get("bytes accessed", 0.0)},
            "hlo": {
                "flops_per_device": costs.flops,
                "hbm_bytes_per_device": costs.hbm_bytes,
                "collective_bytes_naive": costs.collective_naive,
                "collective_bytes_ring": costs.collective_ring,
                "collective_breakdown": costs.collective_breakdown,
                "collective_count": costs.collective_count,
                "scan_trip_counts": costs.trip_counts[:16],
            },
            "roofline": terms.as_dict(),
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    json.dump(rec, open(out_path, "w"), indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + ["all"],
                    help="architecture id")
    ap.add_argument("--shape", default="all",
                    choices=list(SHAPES_BY_NAME) + ["all"])
    ap.add_argument("--multipod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--dense-baseline", action="store_true",
                    help="lower the standard-stack dense decode instead")
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(SHAPES_BY_NAME) if args.shape == "all" else [args.shape]
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multipod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                cell = f"{arch}__{shape}__{'multipod' if mp else 'singlepod'}"
                path = os.path.join(args.out, cell + ".json")
                if args.skip_existing and os.path.exists(path):
                    prev = json.load(open(path))
                    if prev.get("ok"):
                        print(f"[skip] {cell}")
                        continue
                t0 = time.time()
                rec = run_cell(arch, shape, mp, args.out,
                               dense_baseline=args.dense_baseline)
                status = "SKIP" if rec.get("skipped") else (
                    "OK" if rec["ok"] else "FAIL")
                extra = ""
                if rec.get("roofline"):
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']} comp={r['compute_s']:.4f}s"
                             f" mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s"
                             f" useful={r['useful_ratio']:.2f}")
                if not rec["ok"]:
                    failures += 1
                    extra = " " + rec.get("error", "")[:160]
                print(f"[{status}] {cell} ({time.time()-t0:.0f}s){extra}",
                      flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
