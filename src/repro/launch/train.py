# libra: waive[IMPORT001] launch entry point driven via subprocess in test_dryrun_launch (invisible to the static graph)
"""Training launcher.

Local run (reduced config, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt /tmp/ckpt

Production flags (--mesh single|multi) build the 256/512-chip mesh; on this
CPU container they are exercised through launch/dryrun.py instead (no
allocation). On a real fleet the same entrypoint runs under the cluster
launcher with one process per host; resume is automatic from --ckpt.
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import ARCHS, get_config, get_reduced
from repro.data.pipeline import DataPipeline, SyntheticCorpus
from repro.models.registry import build_model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="libra-proxy-125m",
                    choices=ARCHS + ["libra-proxy-125m"])
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log", default=None, help="write history JSON here")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    corpus = SyntheticCorpus(cfg.vocab_size, seed=0)
    pipe = DataPipeline(corpus, batch=args.batch, seq_len=args.seq)
    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps,
                      schedule=cfg.lr_schedule)
    trainer = Trainer(model, opt, pipe, checkpoint_dir=args.ckpt,
                      checkpoint_every=args.ckpt_every, remat=args.remat)
    trainer.install_signal_handlers()
    if args.resume and trainer.resume():
        print(f"resumed from step {trainer.step}")

    hist = trainer.train(args.steps - trainer.step)
    for h in hist[:: max(len(hist) // 20, 1)]:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} lr {h['lr']:.2e} "
              f"({h['time']*1000:.0f} ms)")
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f}; "
              f"stragglers flagged: {trainer.straggler_events}")
    if args.log:
        json.dump(hist, open(args.log, "w"))


if __name__ == "__main__":
    main()
