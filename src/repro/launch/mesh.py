# libra: waive[IMPORT001] launch entry point driven via subprocess in test_dryrun_launch (invisible to the static graph)
"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state). Single pod: (data=16, model=16) = 256 chips of TPU v5e;
multi-pod: (pod=2, data=16, model=16) = 512 chips, the 'pod' axis mapping
to the DCI-connected pod dimension (params replicated across pods, DP
gradient reduction over it).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.common.sharding import AxisType, make_mesh
from repro.common.types import MULTI_POD, SINGLE_POD, MeshSpec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh_from_spec(spec: MeshSpec) -> Mesh:
    return make_mesh(spec.shape, spec.axes,
                     axis_types=(AxisType.Auto,) * len(spec.axes))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    return make_mesh((data, model), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)


def mesh_spec_for(mesh: Mesh) -> MeshSpec:
    return MeshSpec(tuple(mesh.devices.shape), tuple(mesh.axis_names))


def degraded_mesh(lost_pods: int = 1) -> Mesh:
    """Elastic restart target after losing ``lost_pods`` pods: the same code
    compiles for the smaller mesh and checkpoints reshard on restore."""
    assert lost_pods < 2
    return make_production_mesh(multi_pod=False)
