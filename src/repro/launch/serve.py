# libra: waive[IMPORT001] launch entry point driven via subprocess in test_dryrun_launch (invisible to the static graph)
"""Serving launcher: run a model under any of the four engines and print
throughput / latency / boundary-traffic stats.

  PYTHONPATH=src python -m repro.launch.serve --engine libra --requests 16 \
      --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, get_reduced
from repro.core.parser import TokenStreamParser
from repro.models.registry import build_model
from repro.serving.engine import (
    CopierEngine,
    LibraEngine,
    StandardEngine,
    StaticEngine,
)

ENGINES = {"libra": LibraEngine, "standard": StandardEngine,
           "copier": CopierEngine, "static": StaticEngine}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="libra-proxy-125m",
                    choices=ARCHS + ["libra-proxy-125m"])
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--engine", default="libra", choices=list(ENGINES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--header-len", type=int, default=8)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    parser = TokenStreamParser(header_len=args.header_len)
    max_len = args.prompt_len + args.gen + 8

    kw = dict(max_len=max_len, parser=parser)
    if args.engine == "static":
        kw["memory_budget"] = 1 << 28
    else:
        kw["max_batch"] = args.batch
    if args.engine == "libra":
        kw["page_size"] = 8
    eng = ENGINES[args.engine](model, params, **kw)

    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(rng.integers(1, cfg.vocab_size - 1, args.prompt_len),
                   max_new_tokens=args.gen)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    s = eng.stats
    print(f"engine={args.engine} batch={eng.max_batch} "
          f"requests={len(eng.completed)}")
    print(f"throughput: {eng.throughput_tokens()/dt:.1f} tok/s   "
          f"p99 latency: {eng.p99_latency()*1000:.1f} ms")
    print(f"boundary traffic: h2d={s.h2d_bytes/1e3:.1f}KB "
          f"d2h={s.d2h_bytes/1e3:.1f}KB in {s.d2h_calls} transfers")
    print(f"payload: copied={s.payload_copy_bytes/1e6:.2f}MB "
          f"anchored={s.anchored_bytes/1e6:.2f}MB "
          f"zero-copy-forwarded={s.zero_copy_bytes/1e6:.2f}MB")


if __name__ == "__main__":
    main()
