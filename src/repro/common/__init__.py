from repro.common.types import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    MULTI_POD,
    PREFILL_32K,
    SHAPES_BY_NAME,
    SINGLE_POD,
    TRAIN_4K,
    MeshSpec,
    ModelConfig,
    RunShape,
    ShapeSpec,
)

__all__ = [
    "ModelConfig",
    "ShapeSpec",
    "MeshSpec",
    "RunShape",
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "SINGLE_POD",
    "MULTI_POD",
]
