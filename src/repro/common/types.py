"""Core configuration types shared across the framework.

Every architecture (dense / MoE / SSM / hybrid / enc-dec / VLM backbone) is
described by a single ``ModelConfig``; shape points (train_4k, prefill_32k,
decode_32k, long_500k) by ``ShapeSpec``; meshes by ``MeshSpec``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Field semantics follow the assignment table."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # experts padded up so EP divides the model axis
    expert_pad_to: int = 0
    router_aux_coef: float = 0.001

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hymba: sliding-window size for local-attention layers; layers in
    # ``global_attn_layers`` use full attention.
    window: int = 0
    global_attn_layers: Tuple[int, ...] = ()
    # xlstm: one sLSTM block every `slstm_every` blocks (rest mLSTM)
    slstm_every: int = 0

    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_frames: int = 0  # precomputed frame embeddings from the conv stub

    # --- vlm (internvl) ---
    img_tokens: int = 0  # precomputed patch embeddings from the ViT stub

    # --- common knobs ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"  # swiglu | gelu
    # minicpm depth/width residual scaling (mu-p style)
    residual_scale: float = 1.0
    embed_scale: float = 1.0
    logit_soft_cap: float = 0.0
    qk_norm: bool = False  # qwen3-style

    # training schedule hint (minicpm uses WSD)
    lr_schedule: str = "cosine"  # cosine | wsd

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_experts(self) -> int:
        if self.num_experts == 0:
            return 0
        return max(self.num_experts, self.expert_pad_to or self.num_experts)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_full_attention(self) -> bool:
        """True if any layer uses unwindowed full attention (quadratic)."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid":
            # hymba keeps a few global-attention layers but is dominated by
            # sliding window + SSM -> sub-quadratic treatment per assignment.
            return False
        return True

    def param_count(self) -> int:
        """Approximate parameter count (exact for our implementations)."""
        from repro.models.registry import count_params_from_config

        return count_params_from_config(self)

    def active_param_count(self) -> int:
        from repro.models.registry import count_params_from_config

        return count_params_from_config(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape point from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Logical device-mesh description (axis names × sizes)."""

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        if name not in self.axes:
            return 1
        return self.shape[self.axes.index(name)]


SINGLE_POD = MeshSpec((16, 16), ("data", "model"))
MULTI_POD = MeshSpec((2, 16, 16), ("pod", "data", "model"))


@dataclasses.dataclass(frozen=True)
class RunShape:
    """A fully-resolved (arch, shape, mesh) cell of the evaluation grid."""

    arch: str
    shape: ShapeSpec
    mesh: MeshSpec

    @property
    def cell(self) -> str:
        pod = "multipod" if "pod" in self.mesh.axes else "singlepod"
        return f"{self.arch}/{self.shape.name}/{pod}"
