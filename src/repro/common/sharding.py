"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter / activation dimension carries a *logical* axis name; rules
map logical names onto physical mesh axes. ``spec_for`` degrades gracefully:
a dimension that is not divisible by its mapped mesh axes is replicated
rather than erroring, which is what lets one rule table serve ten
architectures (e.g. 8 KV heads on a 16-way model axis -> replicate).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# --- jax version compat ------------------------------------------------------
# ``AxisType`` / ``make_mesh(axis_types=...)`` only exist on newer jax; on
# jax 0.4.x every mesh axis is implicitly Auto, so the fallbacks below are
# semantically identical for this codebase (which only ever uses Auto).
try:
    from jax.sharding import AxisType  # jax >= 0.5

    HAS_AXIS_TYPE = True
except ImportError:  # jax 0.4.x
    HAS_AXIS_TYPE = False

    class AxisType:  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types=None, devices=None) -> Mesh:
    """``jax.make_mesh`` that tolerates jax versions without ``axis_types``."""
    kw = {} if devices is None else {"devices": devices}
    if HAS_AXIS_TYPE:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=axis_types, **kw)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across the 0.4.x/0.5.x signature change
    ((sizes, names) vs a single ((name, size), ...) tuple)."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_shapes)))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across versions: on jax 0.4.x it lives in
    ``jax.experimental.shard_map`` and the replication-check kwarg is
    ``check_rep`` rather than ``check_vma``."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            # transition releases expose top-level shard_map but still
            # spell the replication check ``check_rep``
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)

# logical axis -> ordered tuple of physical mesh axes it may shard over.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),          # sequence parallelism is opt-in (see sp_rules)
    "embed": (),
    "act_heads": ("model",),
    "act_ff": ("model",),
    # params: FSDP over data, TP over model; replicated over pod
    "fsdp": ("data",),
    "tensor": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "expert": ("model",),
    "layers": (),
    "conv": (),
    "state": (),
    # serving pools
    "kv_pages": ("data",),
    "page": (),
    "requests": ("data",),
}


def sp_rules(base: Optional[Dict[str, Tuple[str, ...]]] = None) -> Dict[str, Tuple[str, ...]]:
    """Rules with sequence parallelism enabled (long-prefill shapes)."""
    rules = dict(base or DEFAULT_RULES)
    rules["seq"] = ("model",)
    return rules


def fsdp2d_rules() -> Dict[str, Tuple[str, ...]]:
    """Pure-FSDP (ZeRO-3) strategy: batch and parameters shard over the
    in-pod axes (data, model); the pod axis stays pure DP (params
    replicated across pods, gradients all-reduced over DCI). No tensor
    parallelism: for dense-model training this trades per-layer activation
    psums (O(tokens·d_model) each) for per-layer weight all-gathers
    (O(params/layer)) — a 6.6x collective win for phi3-class models at 4k
    context (EXPERIMENTS §Perf hillclimb #3). MoE keeps fsdp_tp (experts
    need the model axis)."""
    rules = dict(DEFAULT_RULES)
    rules["batch"] = ("data", "model", "pod")
    rules["fsdp"] = ("data", "model")
    rules["tensor"] = ()
    rules["act_heads"] = ()
    rules["act_ff"] = ()
    rules["vocab"] = ()
    rules["expert"] = ()
    return rules


STRATEGIES = {
    "fsdp_tp": lambda: dict(DEFAULT_RULES),
    "fsdp2d": fsdp2d_rules,
}

# module-level active rules: model code calls constrain() without plumbing
# rules through every layer; the launcher scopes a strategy per cell.
_ACTIVE_RULES: Dict[str, Tuple[str, ...]] = dict(DEFAULT_RULES)


class use_rules:
    def __init__(self, rules: Dict[str, Tuple[str, ...]]):
        self.rules = rules
        self._prev = None

    def __enter__(self):
        global _ACTIVE_RULES
        self._prev = _ACTIVE_RULES
        _ACTIVE_RULES = self.rules
        return self

    def __exit__(self, *a):
        global _ACTIVE_RULES
        _ACTIVE_RULES = self._prev
        return False


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    # works for both Mesh and AbstractMesh (no .devices on the latter)
    return dict(mesh.shape)


def spec_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> P:
    """Resolve logical axes for a concrete shape into a PartitionSpec.

    A mesh axis is used at most once across the whole spec (XLA requirement);
    axes are claimed greedily left-to-right. Non-divisible dims replicate.
    """
    rules = rules or DEFAULT_RULES
    sizes = _mesh_axis_sizes(mesh)
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        mapped = [a for a in rules.get(name, ()) if a in sizes and a not in used]
        # claim the largest divisible prefix of the mapped axes
        claimed = []
        prod = 1
        for a in mapped:
            if dim % (prod * sizes[a]) == 0:
                claimed.append(a)
                prod *= sizes[a]
        if not claimed:
            out.append(None)
        elif len(claimed) == 1:
            out.append(claimed[0])
            used.add(claimed[0])
        else:
            out.append(tuple(claimed))
            used.update(claimed)
    return P(*out)


def sharding_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


def tree_specs(abstract_tree, axes_tree, mesh: Mesh, rules=None):
    """Map a pytree of ShapeDtypeStructs + matching logical-axes pytree to
    a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda x, ax: spec_for(x.shape, ax, mesh, rules),
        abstract_tree,
        axes_tree,
        is_leaf=lambda x: x is None,
    )


def tree_shardings(abstract_tree, axes_tree, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda x, ax: sharding_for(x.shape, ax, mesh, rules),
        abstract_tree,
        axes_tree,
        is_leaf=lambda x: x is None,
    )


def constrain(x, logical: Sequence[Optional[str]], mesh: Optional[Mesh] = None, rules=None):
    """``with_sharding_constraint`` via logical names; no-op outside jit/mesh."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(x.shape, logical, mesh, rules or _ACTIVE_RULES)
    )


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.get_concrete_mesh()
        if m is not None and not m.empty:
            return m
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
