"""Deterministic fault injection for the Libra datapath (the chaos
harness).

A :class:`FaultPlan` is a seeded schedule of failures injected at named
points of the stack — the harness the fault-tolerance layer (backend
health/failover, bounded retries, worker-failure migration, epoch policy
hot-swap) is tested against. Everything is driven by the plan's own
monotonic step clock (advanced once per runtime scheduling round) and by
keyed blake2b coins over *stable* identifiers (event id, backend index,
channel name, step), so a plan replays identically for identical
schedules — chaos runs are property-testable against fault-free runs.

Fault kinds (builder methods, chainable):

* :meth:`eagain` / :meth:`stall` — sends to backend index ``k`` fail with
  an *unexplained* EAGAIN (the socket is writable; there is no organic
  busy continuation to wait out) during a step window, with probability
  ``p`` per attempt. Exercises the channel's bounded retry/backoff loop
  and the HealthTable trip → failover path.
* :meth:`reset` — one-shot per channel: the first send to backend ``k``
  at/after step ``at`` finds the connection reset (the channel closes the
  backend socket). Exercises the dead-destination re-route/drop path.
* :meth:`pool_pressure` — holds ``fraction`` of a pool's free pages for a
  step window (watermark backpressure + §A.1 overflow under pressure).
* :meth:`kill_worker` — asks the :class:`ClusterRuntime` to kill worker
  ``w`` at step ``at`` (drain + flow migration + dead-owner grant
  copy-out).
* :meth:`corrupt` — flips one payload token of delivered frames with
  probability ``p`` per frame during a window. Frame-aware: the parser
  locates message boundaries and only payload spans are damaged, so
  framing survives and the corruption is *detectable* (an hw/sw-kTLS
  record fails its auth tag and is rejected-and-counted; the stream
  never wedges).
* :meth:`at` — a generic one-shot callback ``fn(runtime)`` at step
  ``when`` (policy-table swaps under traffic, ad-hoc chaos).

Install by passing ``fault_plan=plan`` to :class:`ProxyRuntime` or
:class:`ClusterRuntime` (which set ``stack.fault_plan`` on their stacks
and drive :meth:`on_tick` / :meth:`on_cluster_step` once per round), or
call :meth:`install` on a bare stack. ``plan.log`` records every fired
event; :meth:`release_all` returns any pages still held by pool-pressure
events (runtime shutdown calls it, so leak asserts stay meaningful).
"""
from __future__ import annotations

import dataclasses
import hashlib
import struct
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.anchor_pool import PoolExhausted


def _coin(seed: int, *key) -> float:
    """Deterministic uniform [0, 1) keyed on ``(seed, *key)`` — order-
    independent across unrelated draws (no shared RNG stream), so one
    extra consultation never perturbs every later coin."""
    h = hashlib.blake2b(repr(key).encode(),
                        key=struct.pack("<q", int(seed)), digest_size=8)
    return struct.unpack("<Q", h.digest())[0] / 2.0 ** 64


def _coin_int(seed: int, *key) -> int:
    h = hashlib.blake2b(repr(key).encode(),
                        key=struct.pack("<q", int(seed) ^ 0x5EED),
                        digest_size=8)
    return struct.unpack("<Q", h.digest())[0]


@dataclasses.dataclass
class _Event:
    kind: str                       # eagain|reset|pressure|kill|corrupt|at
    eid: int
    backend: int = -1
    start: int = 0
    until: Optional[int] = None     # None = open-ended window
    p: float = 1.0
    at: int = 0
    worker: int = -1
    fraction: float = 0.0
    fn: Optional[Callable] = None
    done: bool = False              # one-shot events (reset is per-channel)
    hits: int = 0
    hit_channels: Set[str] = dataclasses.field(default_factory=set)


class FaultPlan:
    """A seeded, deterministic schedule of injected faults (see module
    docstring). Builder methods return ``self`` for chaining."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        #: the plan's step clock — advanced once per runtime round by
        #: :meth:`on_tick` / :meth:`on_cluster_step`; every window/firing
        #: time is expressed in these steps
        self.now = 0
        self.events: List[_Event] = []
        #: (step, kind, detail...) tuples for every *fired* discrete event
        self.log: List[Tuple] = []
        self._serials: Dict[int, int] = {}        # sock id -> deliveries seen
        # process-global filenos are not replay-stable: coins and logs key
        # on a plan-local dense id assigned in first-seen order instead
        self._sock_ids: Dict[int, int] = {}
        # pool-pressure holds: (eid, id(alloc)) -> held PageRef list
        self._pressure: Dict[Tuple[int, int], list] = {}
        self._allocs: Dict[int, object] = {}

    # -- builders ------------------------------------------------------------
    def _add(self, **kw) -> "FaultPlan":
        self.events.append(_Event(eid=len(self.events), **kw))
        return self

    def eagain(self, backend: int, start: int = 0,
               until: Optional[int] = None, p: float = 1.0) -> "FaultPlan":
        """EAGAIN storm on backend index ``backend`` during steps
        ``[start, until)``: each send attempt fails with probability
        ``p`` (unexplained — counted against the retry budget)."""
        return self._add(kind="eagain", backend=int(backend),
                         start=int(start), until=until, p=float(p))

    def stall(self, backend: int, start: int = 0,
              until: Optional[int] = None) -> "FaultPlan":
        """Hard stall: every send to ``backend`` fails for the window
        (an :meth:`eagain` storm with p=1)."""
        return self.eagain(backend, start=start, until=until, p=1.0)

    def reset(self, backend: int, at: int = 0) -> "FaultPlan":
        """Connection reset: the first send each channel attempts to
        backend ``backend`` at/after step ``at`` finds the destination
        closed (one-shot per channel)."""
        return self._add(kind="reset", backend=int(backend), at=int(at))

    def pool_pressure(self, fraction: float, start: int = 0,
                      until: Optional[int] = None) -> "FaultPlan":
        """Hold ``fraction`` of each target pool's free pages for the
        window (released when it closes, and by :meth:`release_all`)."""
        assert 0.0 <= fraction <= 1.0, fraction
        return self._add(kind="pressure", fraction=float(fraction),
                         start=int(start), until=until)

    def kill_worker(self, worker: int, at: int) -> "FaultPlan":
        """Kill cluster worker ``worker`` at step ``at`` (one-shot;
        requires a :class:`ClusterRuntime` driving the plan)."""
        return self._add(kind="kill", worker=int(worker), at=int(at))

    def corrupt(self, p: float = 1.0, start: int = 0,
                until: Optional[int] = None) -> "FaultPlan":
        """Flip one payload token per delivered frame with probability
        ``p`` during the window (frame-aware — framing survives)."""
        return self._add(kind="corrupt", p=float(p), start=int(start),
                         until=until)

    def at(self, when: int, fn: Callable) -> "FaultPlan":
        """One-shot callback ``fn(runtime)`` at step ``when`` (e.g. a
        policy-table :meth:`~repro.core.policy.PolicyTable.swap`)."""
        return self._add(kind="at", at=int(when), fn=fn)

    # -- installation --------------------------------------------------------
    def install(self, stack) -> "FaultPlan":
        """Attach to a bare :class:`LibraStack` (runtimes do this through
        their ``fault_plan=`` kwarg)."""
        stack.fault_plan = self
        return self

    # -- hook: channel send path ---------------------------------------------
    def _active(self, ev: _Event) -> bool:
        return ev.start <= self.now and (ev.until is None
                                         or self.now < ev.until)

    def send_fault(self, backend: int, channel: str) -> Optional[str]:
        """Consulted by the channel before every send attempt: returns
        ``'reset'`` (destination is to be closed), ``'eagain'`` (injected
        unexplained EAGAIN) or ``None``. Deterministic: the coin is keyed
        on (event, backend, channel, step), so re-consultation within one
        step agrees with itself."""
        for ev in self.events:
            if ev.kind == "reset" and ev.backend == backend \
                    and self.now >= ev.at \
                    and channel not in ev.hit_channels:
                ev.hit_channels.add(channel)
                ev.hits += 1
                self.log.append((self.now, "reset", backend, channel))
                return "reset"
        for ev in self.events:
            if ev.kind != "eagain" or ev.backend != backend \
                    or not self._active(ev):
                continue
            if ev.p >= 1.0 or _coin(self.seed, "eagain", ev.eid, backend,
                                    channel, self.now) < ev.p:
                ev.hits += 1
                return "eagain"
        return None

    # -- hook: ingress delivery ----------------------------------------------
    def corrupt_ingress(self, sock, data: np.ndarray) -> np.ndarray:
        """Consulted by ``LibraSocket.deliver``: frame-aware token
        corruption. The socket's parser walks the delivered chunk frame
        by frame; a corrupted frame gets ONE payload token XORed with a
        keyed nonzero value — framing intact, content damaged (an
        encrypted record then fails its auth tag downstream)."""
        active = [ev for ev in self.events
                  if ev.kind == "corrupt" and self._active(ev)]
        arr = np.asarray(data, np.int64)
        if not active or len(arr) == 0:
            return arr
        fd = self._sock_ids.setdefault(sock.fileno(), len(self._sock_ids))
        serial = self._serials.get(fd, 0)
        self._serials[fd] = serial + 1
        out = None
        pos = idx = 0
        while pos < len(arr):
            res = sock.parser.parse(arr[pos:])
            if not getattr(res, "ok", False) or res.payload_len < 0:
                break
            span = res.meta_len + res.payload_len
            if span <= 0 or pos + span > len(arr):
                break
            for ev in active:
                if res.payload_len <= 0:
                    continue
                if _coin(self.seed, "corrupt", ev.eid, fd, serial,
                         idx) >= ev.p:
                    continue
                if out is None:
                    out = arr.copy()
                off = pos + res.meta_len + int(
                    _coin_int(self.seed, "cpos", fd, serial, idx)
                    % res.payload_len)
                out[off] ^= 1 + int(_coin_int(self.seed, "cval", fd, serial,
                                              idx) % 997)
                ev.hits += 1
                self.log.append((self.now, "corrupt", fd, idx))
                break
            pos += span
            idx += 1
        return arr if out is None else out

    # -- hook: scheduler rounds ----------------------------------------------
    def on_tick(self, runtime) -> None:
        """One single-stack scheduling round: advance the step clock,
        apply pool pressure to the runtime's stack, fire due callbacks."""
        self.now += 1
        self._apply_pressure([runtime.stack])
        self._fire_ats(runtime)

    def on_cluster_step(self, runtime) -> None:
        """One cluster round: advance the clock, apply pressure to every
        live worker pool, fire due worker kills and callbacks."""
        self.now += 1
        live = [w for i, w in enumerate(runtime.cluster.workers)
                if i not in runtime.cluster.dead_workers]
        self._apply_pressure(live)
        for ev in self.events:
            if ev.kind == "kill" and not ev.done and self.now >= ev.at:
                ev.done = True
                ev.hits += 1
                self.log.append((self.now, "kill_worker", ev.worker))
                runtime.kill_worker(ev.worker)
        self._fire_ats(runtime)

    def _fire_ats(self, runtime) -> None:
        for ev in self.events:
            if ev.kind == "at" and not ev.done and self.now >= ev.at:
                ev.done = True
                ev.hits += 1
                self.log.append((self.now, "callback", ev.eid))
                ev.fn(runtime)

    def _apply_pressure(self, stacks) -> None:
        for ev in self.events:
            if ev.kind != "pressure":
                continue
            for st in stacks:
                key = (ev.eid, id(st.alloc))
                held = self._pressure.get(key)
                if self._active(ev) and held is None:
                    n = int(ev.fraction * st.alloc.free_pages)
                    pages = []
                    try:
                        for _ in range(n):
                            pages.append(st.alloc.alloc_page(0))
                    except PoolExhausted:
                        pass
                    self._pressure[key] = pages
                    self._allocs[id(st.alloc)] = st.alloc
                    ev.hits += 1
                    self.log.append((self.now, "pressure_on", len(pages)))
                elif not self._active(ev) and held:
                    st.alloc.free_pages_list(held)
                    self._pressure[key] = []
                    self.log.append((self.now, "pressure_off", len(held)))

    def release_all(self) -> int:
        """Free every page still held by pool-pressure events (runtime
        shutdown calls this before asserting zero leaks). Returns the
        number of pages released."""
        freed = 0
        for key, pages in list(self._pressure.items()):
            if pages:
                self._allocs[key[1]].free_pages_list(pages)
                freed += len(pages)
            self._pressure[key] = []
        return freed

    # -- telemetry -----------------------------------------------------------
    def summary(self) -> Dict[str, object]:
        by_kind: Dict[str, int] = {}
        for ev in self.events:
            by_kind[ev.kind] = by_kind.get(ev.kind, 0) + ev.hits
        return {"now": self.now, "events": len(self.events),
                "hits_by_kind": by_kind, "log_entries": len(self.log)}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FaultPlan(seed={self.seed}, now={self.now}, "
                f"events={len(self.events)}, fired={len(self.log)})")
