"""Device-resident payload pool — the kernel-retained skb pages, kept on
the fast side of the boundary.

Libra's premise is that payloads are written once into the kernel-retained
pool and never touched again. The legacy :class:`~repro.core.stream.
TokenPool` honours that on the host but betrays it at the device boundary:
every batched device round re-uploads the whole pool (``astype(int32)``)
and syncs the touched rows back — two O(pool) crossings per scheduling
round, exactly the "bulk data crosses the boundary" failure mode the paper
eliminates (and the regime kernel-resident L7 datapaths like XLB win in).

:class:`DevicePool` keeps the ``[P+1, page]`` pool **resident as a jax
array across rounds**: the fused ingress kernel's donation updates it in
place, the fused egress gather reads it in place, and only O(batch) data
(the round's stream/tables/keystreams up, the gathered payloads down) ever
crosses the boundary. The host ``int64`` mirror inherited from
``TokenPool`` stays available for the scalar datapaths and the tests via
**dirty-row tracking**:

* ``host-dirty`` rows — host truth, device copy stale/unfaithful. Set by
  scalar-path writes (``write_payload``/``write_payload_batch``) and for
  rows whose int64 content does not survive the int32 device dtype.
  Uploaded lazily (O(rows)) when a device round touches them; a round that
  would need an out-of-range row raises :class:`DeviceRangeError` so the
  caller can bounce that round to the int64-exact host path.
* ``device-dirty`` rows — device truth, host mirror stale. Set by device
  anchoring rounds. Materialized lazily (O(rows)) when a host read/write
  or a whole-pool view (``data``/``flat_with_scratch``) needs them.

Every boundary crossing is counted in :attr:`TokenPool.xfer`
(``h2d_tokens``/``d2h_tokens``); ``pool_syncs`` — the O(pool) crossing
counter — stays at zero for this class by construction, and the batched-
datapath tests assert it.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.anchor_pool import AnchorPool, PageRef
from repro.core.stream import TokenPool

I32_MIN, I32_MAX = -(1 << 31), (1 << 31) - 1


class DeviceRangeError(Exception):
    """A round needs pool rows / operands whose int64 values do not survive
    the int32 device dtype — serve it from the int64-exact host path."""


class DevicePool(TokenPool):
    """A :class:`TokenPool` whose batched device rounds run against one
    resident jax array instead of per-round whole-pool bounces."""

    def __init__(self, alloc: AnchorPool):
        super().__init__(alloc)
        self._dev = None                      # jax.Array [P+1, page] int32
        rows = self._flat.shape[0]
        self._host_dirty = np.zeros((rows,), bool)
        self._dev_dirty = np.zeros((rows,), bool)
        # DMA staging ring depth for the fused one-kernel round (0 =
        # blocked layout); set from kernels.dma_profile.auto_buffer_depth
        # or the LIBRA_FUSED_BUFFERS env var by the deployment
        self.fused_buffers = 0

    # -- residency -----------------------------------------------------------
    @property
    def resident(self) -> bool:
        """True once the device copy exists (first device round)."""
        return self._dev is not None

    def dirty_rows(self) -> np.ndarray:
        """Rows whose truth currently lives on the device (host mirror
        stale) — telemetry/testing hook."""
        return np.flatnonzero(self._dev_dirty)

    def _ensure_device(self) -> None:
        """Create the resident device pool from the host mirror — ONE
        O(pool) upload for the lifetime of the pool, not one per round.
        Rows whose int64 content does not fit int32 stay host-truth."""
        if self._dev is not None:
            return
        import jax.numpy as jnp

        flat = self._flat
        oob = ((flat < I32_MIN) | (flat > I32_MAX)).any(axis=1)
        self._host_dirty |= oob
        self._dev = jnp.asarray(flat.astype(np.int32))
        self.xfer["resident_init_tokens"] += flat.size

    def _upload_rows(self, rows: np.ndarray) -> None:
        """Make ``rows`` faithful on the device (host-dirty rows go up,
        O(rows) not O(pool)). Raises :class:`DeviceRangeError` — before
        touching anything — when a row's content cannot survive int32."""
        sel = rows[self._host_dirty[rows]]
        if len(sel) == 0:
            return
        vals = self._flat[sel]
        if vals.size and (vals.min() < I32_MIN or vals.max() > I32_MAX):
            raise DeviceRangeError("host-truth rows exceed int32")
        self._dev = self._dev.at[sel].set(vals.astype(np.int32))
        self._host_dirty[sel] = False
        self.xfer["h2d_tokens"] += vals.size

    def _materialize_rows(self, rows: np.ndarray) -> None:
        """Pull device-truth ``rows`` back into the host mirror (lazy,
        O(rows)): int32 device values are exact in the int64 mirror."""
        sel = rows[self._dev_dirty[rows]]
        if len(sel) == 0:
            return
        host = np.asarray(self._dev[sel]).astype(np.int64)
        self._flat[sel] = host
        self._dev_dirty[sel] = False
        self.xfer["d2h_tokens"] += host.size

    def materialize(self) -> None:
        """Sync every device-truth row into the host mirror (tests and
        whole-pool consumers; scalar datapaths use the per-row lazy path)."""
        self._materialize_rows(np.arange(self._flat.shape[0]))

    def _rows_of(self, pages: Sequence[PageRef]) -> np.ndarray:
        return np.unique(np.fromiter(
            (self.alloc.flat_pid(pg) for pg in pages), np.int64,
            count=len(pages)))

    # -- host views materialize lazily ----------------------------------------
    # Both whole-pool views keep TokenPool's write-through contract: the
    # caller may mutate what they return. A write through the view cannot
    # be observed, so once resident the ENTIRE pool must be treated as
    # host-truth after handing one out — later device rounds lazily re-
    # upload whichever of those rows they actually touch (still O(rows)).
    @property
    def data(self) -> np.ndarray:
        self.materialize()
        if self._dev is not None:
            self._host_dirty[:] = True
        return self._data_view

    @property
    def flat_with_scratch(self) -> np.ndarray:
        self.materialize()
        if self._dev is not None:
            self._host_dirty[:] = True
        return self._flat

    # -- host (scalar-path) writes/reads keep the mirror authoritative --------
    def write_payload(self, pages: List[PageRef], payload: np.ndarray,
                      keystream: Optional[np.ndarray] = None) -> None:
        if self._dev is not None and pages and len(payload):
            rows = self._rows_of(pages)
            # a partial-page host write must land on the row's true content
            self._materialize_rows(rows)
            self._host_dirty[rows] = True
        super().write_payload(pages, payload, keystream=keystream)

    def write_payload_batch(self, seqs, keystreams=None) -> None:
        if self._dev is not None:
            all_pages = [pg for pages, p in seqs if len(p) and pages
                         for pg in pages]
            if all_pages:
                rows = self._rows_of(all_pages)
                self._materialize_rows(rows)
                self._host_dirty[rows] = True
        super().write_payload_batch(seqs, keystreams=keystreams)

    def read_payload(self, pages: List[PageRef], length: int,
                     keystream: Optional[np.ndarray] = None) -> np.ndarray:
        if self._dev is not None and pages and length:
            self._materialize_rows(self._rows_of(pages))
        return super().read_payload(pages, length, keystream=keystream)

    def read_payload_batch(self, seqs, keystreams=None):
        if self._dev is not None:
            all_pages = [pg for pages, ln in seqs if ln and pages
                         for pg in pages]
            if all_pages:
                self._materialize_rows(self._rows_of(all_pages))
        return super().read_payload_batch(seqs, keystreams=keystreams)

    # -- device data plane: resident, zero O(pool) crossings -------------------
    def anchor_batch_device(self, stream: np.ndarray, meta_len: np.ndarray,
                            total_len: np.ndarray, tables: np.ndarray, *,
                            meta_max: int, impl: str,
                            keystream: Optional[np.ndarray] = None) -> None:
        """One batched ingress round, entirely on-device: upload O(batch)
        operands (plus any host-dirty rows the round overwrites), run the
        fused kernel against the resident pool, and keep the donated result
        resident — **nothing O(pool) crosses the boundary, nothing syncs
        back**. Touched rows become device-truth (lazy host views).

        The resident pool is **donated through the outer jit**
        (``donate_pool=True``): the round updates the one live pool buffer
        in place instead of allocating an output copy next to the input —
        verified per round by comparing buffer pointers
        (``xfer['donated_rounds']``)."""
        from repro.kernels import ops

        self._ensure_device()
        rows = np.unique(tables[tables >= 0]).astype(np.int64)
        self._upload_rows(rows)               # may raise DeviceRangeError
        self.xfer["h2d_tokens"] += stream.size + tables.size \
            + meta_len.size + total_len.size \
            + (keystream.size if keystream is not None else 0)
        donated_in = self._dev
        new_meta, new_pool = ops.selective_copy(
            stream, meta_len, total_len, self._dev, tables,
            meta_max=meta_max, impl=impl, reserved_scratch=True,
            keystream=keystream, donate_pool=True)
        del new_meta  # host buffers keep the int64-exact metadata
        self._dev = new_pool
        # the donation's guarantee: XLA consumed (deleted) the input pool
        # buffer, so exactly ONE pool allocation stays live across the
        # round — not an input + an output copy
        try:
            if donated_in is not new_pool and donated_in.is_deleted():
                self.xfer["donated_rounds"] += 1
        except Exception:  # pragma: no cover - backend without the API
            pass
        self._dev_dirty[rows] = True
        self.xfer["device_rounds"] += 1
        self.xfer["anchor_rounds"] += 1

    def fused_round_device(
        self, stream: np.ndarray, meta_len: np.ndarray,
        total_len: np.ndarray, tables: np.ndarray, *, meta_max: int,
        impl: str, keystream: Optional[np.ndarray] = None,
        tx_keystream: Optional[np.ndarray] = None,
        cond_off: Optional[np.ndarray] = None,
        cond_lo: Optional[np.ndarray] = None,
        cond_hi: Optional[np.ndarray] = None,
        live: Optional[np.ndarray] = None,
        meta_ks: Optional[np.ndarray] = None,
        n_buffers: int = 0,
    ) -> Tuple[Optional[np.ndarray], np.ndarray]:
        """The **one-kernel scheduling round**: anchor + hw-kTLS keystream
        XOR + policy first-match + egress gather in a SINGLE launch against
        the resident pool — ``xfer['fused_rounds']`` counts exactly one
        ``device_rounds`` bump where the multi-pass path pays three
        (anchor + policy match + gather). Upload is O(batch) operands plus
        any host-dirty rows the round overwrites; only the verdict column
        and the gathered payload block come down. Touched rows become
        device-truth, and the resident pool is donated through the outer
        jit exactly like :meth:`anchor_batch_device`.

        Returns ``(verdict [B] | None, gathered [B, pps*page] int64)`` —
        the int64-exact metadata stays host-side (the caller already holds
        it), and ``gathered`` is the round's speculative egress block
        (TX-encrypted when ``tx_keystream`` is supplied)."""
        from repro.kernels import ops

        self._ensure_device()
        rows = np.unique(tables[tables >= 0]).astype(np.int64)
        self._upload_rows(rows)               # may raise DeviceRangeError
        self.xfer["h2d_tokens"] += stream.size + tables.size \
            + meta_len.size + total_len.size \
            + sum(op.size for op in (keystream, tx_keystream, cond_off,
                                     cond_lo, cond_hi, live, meta_ks)
                  if op is not None)
        donated_in = self._dev
        new_meta, new_pool, verdict, gathered = ops.fused_round(
            stream, meta_len, total_len, self._dev, tables,
            meta_max=meta_max, impl=impl, keystream=keystream,
            tx_keystream=tx_keystream, cond_off=cond_off, cond_lo=cond_lo,
            cond_hi=cond_hi, live=live, meta_ks=meta_ks,
            n_buffers=n_buffers, donate_pool=True)
        del new_meta  # host buffers keep the int64-exact metadata
        self._dev = new_pool
        try:
            if donated_in is not new_pool and donated_in.is_deleted():
                self.xfer["donated_rounds"] += 1
        except Exception:  # pragma: no cover - backend without the API
            pass
        self._dev_dirty[rows] = True
        self.xfer["device_rounds"] += 1
        self.xfer["anchor_rounds"] += 1
        self.xfer["fused_rounds"] += 1
        host_out = np.asarray(gathered)
        self.xfer["d2h_tokens"] += host_out.size
        host_verdict = None
        if verdict is not None:
            host_verdict = np.asarray(verdict)
            self.xfer["d2h_tokens"] += host_verdict.size
        return host_verdict, host_out.astype(np.int64)

    def gather_batch_device(self, tables: np.ndarray, lengths: np.ndarray, *,
                            impl: str,
                            keystream: Optional[np.ndarray] = None,
                            ) -> np.ndarray:
        """One batched egress round: fused gather straight off the resident
        pool. Only the gathered payload block (O(batch)) comes down — the
        bytes that are leaving on the wire anyway."""
        from repro.kernels import ops

        self._ensure_device()
        rows = np.unique(tables[tables >= 0]).astype(np.int64)
        self._upload_rows(rows)               # may raise DeviceRangeError
        self.xfer["h2d_tokens"] += tables.size + lengths.size \
            + (keystream.size if keystream is not None else 0)
        out = ops.selective_gather(self._dev, tables, lengths, impl=impl,
                                   keystream=keystream)
        host = np.asarray(out)
        self.xfer["d2h_tokens"] += host.size
        self.xfer["device_rounds"] += 1
        return host.astype(np.int64)
