"""``LibraSocket`` — the POSIX-shaped per-connection facade.

The paper's headline property is that selective copy slots under an
*unmodified* proxy: the application calls ``recv``/``send``/``close`` and
never sees pools, registries, or tick clocks. This module restores that
surface for the repro: a ``LibraSocket`` wraps one :class:`Connection` and
routes every call through the owning :class:`~repro.core.stack.LibraStack`'s
pool/registry/counters, so call-sites carry no plumbing.

Semantics mirrored from the kernel implementation:

* ``recv(buf_len)``   — instrumented recvmsg (§3.3). Returns
  ``(buffer, logical_len)``: on the selective path the buffer holds
  ``[metadata..., VPI]`` while ``logical_len`` covers metadata + anchored
  payload (recv transparency).
* ``send(buf)``       — instrumented sendmsg (§3.4) on THIS socket. The
  anchoring (source) connection is resolved from the embedded VPI through
  the stack's owner map, just as the kernel resolves it through the global
  eBPF map. ``send()`` with no buffer continues a budget-truncated message.
* ``forward(dst, buf)`` — the proxy idiom: message received on ``self``,
  transmitted on ``dst`` (``self`` is the anchor owner).
* ``close()``         — §A.4 safe teardown; still-anchored payloads enter
  the grace period and are reclaimed by ``LibraStack.tick()``.
* ``poll()``          — readiness bits for the event-driven runtime.

Partial sends: selective-copy (FAST_PATH) messages resume from the TX
machine's cumulative offset — callers re-enter with ``send()`` until
``pending_send`` clears. Full-copy paths are plain byte streams and are
sliced by the facade's own progress counter.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import numpy as np

from repro.core.crypto import TlsSession
from repro.core.egress import libra_close, libra_send
from repro.core.ingress import libra_recv
from repro.core.parser import ParserPolicy
from repro.core.state_machine import MIN_PAYLOAD, St
from repro.core.stream import Connection
from repro.core.vpi import VpiEntry, VpiRegistry


class Events(enum.IntFlag):
    """``poll()`` readiness bits (poll(2) analogue)."""
    NONE = 0
    READABLE = 1       # bytes waiting in the receive queue
    WRITABLE = 2       # a NEW message is accepted (no truncated send pending)
    SEND_PENDING = 4   # a budget-truncated message awaits continuation
    CLOSED = 8


@dataclasses.dataclass
class _PendingSend:
    """One in-flight outbound message on a TX socket."""
    src_conn: Connection      # connection whose RX anchored the payload
    msg: np.ndarray           # full outgoing buffer as first submitted
    logical: int              # total logical length of the message
    accepted: int = 0         # logical bytes accepted so far


class LibraSocket:
    """One proxied connection, POSIX surface. Construct via
    :meth:`LibraStack.socket` — the stack owns all shared state."""

    def __init__(self, stack, parser: ParserPolicy, *,
                 min_payload: int = MIN_PAYLOAD,
                 send_budget: Optional[int] = None,
                 tls: Optional[str] = None):
        self._stack = stack
        self.parser = parser
        self.send_budget = send_budget   # default per-call budget (None = ∞)
        self._conn = Connection(parser, stack.registry, min_payload=min_payload)
        # kTLS-analogue session (tls='sw'|'hw'): per-direction keys derive
        # from the stack's VPI-registry secret; the datapaths find the
        # session on the connection, the wire-side peers through ``.tls``
        self.tls: Optional[TlsSession] = None
        if tls is not None:
            self.tls = TlsSession(
                tls,
                stack.registry.derive_key(b"tls-rx", self._conn.conn_id),
                stack.registry.derive_key(b"tls-tx", self._conn.conn_id))
            self._conn.crypto = self.tls
        self._pending: Optional[_PendingSend] = None
        self._first_parse = None       # ParseResult handed to the first send
        self._parse_memo = None        # (queue fingerprint, ParseResult)
        # set by recv_batch when the auth sweep rejected this socket's
        # record (the batch drops the slot instead of raising); the
        # runtime reads-and-clears it to attribute the reject to a channel
        self._auth_rejected = False
        # set by recv_batch's fused L7 policy pass: the Verdict for the
        # message this socket delivered in the round; the runtime pops it
        # into the owning channel so routing skips the per-channel callbacks
        self._policy_verdict = None
        # set by the one-kernel fused round: the speculative TX descriptor
        # (gather output + hw-kTLS keystream spans) for the message this
        # socket delivered; forward_batch validates and consumes it
        self._fused_tx = None

    # -- identity / state ---------------------------------------------------
    def fileno(self) -> int:
        return self._conn.conn_id

    @property
    def closed(self) -> bool:
        return self._conn.closed

    @property
    def connection(self) -> Connection:
        """Escape hatch to the underlying connection (compat layer)."""
        return self._conn

    @property
    def stack(self):
        """The owning :class:`~repro.core.stack.LibraStack`."""
        return self._stack

    @property
    def worker_id(self) -> Optional[int]:
        """The cluster worker this socket's stack lives on (None for a
        standalone stack)."""
        return self._stack.worker_id

    @property
    def pending_send(self) -> Optional[_PendingSend]:
        return self._pending

    def rx_available(self) -> int:
        return self._conn.rx_available()

    def parse_pending(self):
        """ParseResult for the current head of the receive queue — a pure
        function of the queue fingerprint, memoised so idle poll rounds and
        the batched datapath never rescan the window (KMP for delimiters)."""
        conn = self._conn
        key = conn.rx_fingerprint()
        if self._parse_memo is not None and self._parse_memo[0] == key:
            return self._parse_memo[1]
        res = self.parser.parse(conn.rx_window(self.parser.lookahead))
        self._parse_memo = (key, res)
        return res

    def needs_more_data(self) -> bool:
        """True when the buffered bytes are only the prefix of a message
        whose boundary the parser cannot locate yet (``need_more``). A raw
        ``recv`` would return these bytes (POSIX semantics); an L7 event
        loop uses this to wait for a parseable frame instead."""
        conn = self._conn
        if conn.closed or conn.rx_available() == 0:
            return False
        if conn.rx_drain_remaining > 0:
            return False
        if conn.rx_machine.state is not St.DEFAULT:
            return False
        res = self.parse_pending()
        return not res.ok and res.need_more

    def next_frame_selective(self) -> bool:
        """True when the pending frame would take the selective (anchoring)
        path on recv — the backpressure predicate: pausing such a socket
        sheds pool load; full-copy frames never touch the pool."""
        conn = self._conn
        if conn.closed or conn.rx_available() == 0 or conn.rx_drain_remaining:
            return False
        if conn.rx_machine.state is not St.DEFAULT:
            return False
        res = self.parse_pending()
        return res.ok and res.payload_len >= conn.rx_machine.min_payload

    def tx_wire(self) -> np.ndarray:
        return self._conn.tx_wire()

    def poll(self) -> Events:
        if self._conn.closed:
            return Events.CLOSED
        ev = Events.NONE
        if self._conn.rx_available() > 0:
            ev |= Events.READABLE
        if self._pending is not None:
            # send(new_buf) would raise EAGAIN: the bit and the call agree
            ev |= Events.SEND_PENDING
        else:
            ev |= Events.WRITABLE
        return ev

    # -- network side (NIC DMA analogue) ------------------------------------
    def deliver(self, data) -> None:
        """The network delivers bytes into this socket's receive queue.
        An installed :class:`~repro.core.faults.FaultPlan` sees the bytes
        first (frame-aware corruption injection — the wire is the fault
        boundary; internal migrations use ``connection.deliver``)."""
        data = np.asarray(data, np.int64)
        plan = getattr(self._stack, "fault_plan", None)
        if plan is not None:
            data = plan.corrupt_ingress(self, data)
        self._conn.deliver(data)

    # -- POSIX surface -------------------------------------------------------
    def recv(self, buf_len: int) -> Tuple[np.ndarray, int]:
        """Instrumented recvmsg: returns ``(user_buffer, logical_len)``."""
        if self._conn.closed:
            raise OSError("recv on closed LibraSocket")
        buf, n = libra_recv(self._conn, buf_len, self._stack.pool,
                            self._stack.registry, self._stack.counters)
        if self._conn.anchored:
            self._stack._note_anchor_owner(self)
        return buf, n

    def send(self, buf=None, *, budget: Optional[int] = None) -> int:
        """Transmit on this socket; returns logical bytes accepted (like a
        non-blocking send). ``buf=None`` continues the pending message."""
        return self._transmit(None, buf, budget)

    def forward(self, dst: "LibraSocket", buf, *,
                budget: Optional[int] = None) -> int:
        """Proxy forwarding: a message received on ``self`` goes out on
        ``dst``; ``self`` is the connection that anchored the payload."""
        return dst._transmit(self, buf, budget)

    def close(self) -> int:
        """§A.4 safe teardown. Returns the number of anchors deferred into
        the grace period (freed by subsequent ``LibraStack.tick()``s)."""
        if self._conn.closed:
            return 0
        deferred = libra_close(self._conn, self._stack.pool,
                               self._stack.registry, self._stack.now_tick)
        self._stack._detach(self)
        return deferred

    # -- transmit core -------------------------------------------------------
    def _peek_message(self, msg: np.ndarray):
        """(meta_len, vpi, entry, parse_result): entry when ``msg`` is
        [metadata..., VPI] with a live registry entry, None otherwise. The
        ParseResult is returned so the egress machine can reuse it (parse
        is pure; the message is scanned once per send)."""
        res = self.parser.parse(msg)
        if res.ok and res.payload_len >= 0 and len(msg) >= res.meta_len + 1:
            vpi = VpiRegistry.from_token(int(msg[res.meta_len]))
            entry: Optional[VpiEntry] = self._stack.registry.peek(vpi)
            if entry is not None:
                return res.meta_len, vpi, entry, res
            return len(msg), vpi, None, res
        return len(msg), None, None, res

    def _transmit(self, src: Optional["LibraSocket"], buf,
                  budget: Optional[int],
                  payload_prefetched: Optional[np.ndarray] = None,
                  peeked=None) -> int:
        if self._conn.closed:
            raise OSError("send on closed LibraSocket")
        budget = self.send_budget if budget is None else budget
        p = self._pending
        if p is not None and buf is not None:
            # a new message while one is budget-truncated would silently
            # interleave frames; refuse like a full non-blocking send buffer
            raise BlockingIOError(
                "send buffer full: a budget-truncated message is pending; "
                "call send() with no buffer to continue it")
        if p is None:
            if buf is None:
                raise ValueError("send() without a buffer and no pending message")
            sm_prev = self._conn.tx_machine
            if sm_prev.state in (St.FALLBACK_BYPASS, St.METADATA_PARSED):
                # the facade frames messages: bypass/partial-metadata state
                # left over from a completed frame (stale VPI, or a header
                # whose payload never follows) must not swallow or corrupt
                # this new message. Raw byte-stream continuations stay on
                # the compat layer.
                sm_prev.reset()
            msg = np.asarray(buf, np.int64)
            # ``peeked`` lets the batched forwarder hand in the
            # _peek_message it already ran for prefetch eligibility
            meta_len, vpi, entry, parsed = (peeked if peeked is not None
                                            else self._peek_message(msg))
            if entry is None and vpi is not None:
                # the handle may be anchored on a peer worker: adopt it
                # through the cluster interconnect (zero-copy grant or the
                # counted one-copy fallback) and transmit the translated
                # message — a no-op for standalone stacks / garbage tokens
                adopted = self._stack._adopt_message(msg, vpi, parsed)
                if adopted is not None:
                    msg = adopted
                    meta_len, vpi, entry, parsed = self._peek_message(msg)
            src_conn = src._conn if src is not None else None
            if src_conn is None and vpi is not None:
                owner = self._stack._anchor_owner(vpi)
                src_conn = owner._conn if owner is not None else None
            if src_conn is None:
                # no live anchor owner (raw message, or a stale/torn-down
                # handle): cross-path cleanup must not touch any real RX
                # machine — aim it at the stack's inert null connection
                src_conn = self._stack._null_source()
            # logical length must mirror what THIS socket's TX machine will
            # do: it fast-paths (meta + anchored payload) only when the
            # payload clears its own admission threshold; otherwise the
            # frame is a plain byte buffer
            if entry is not None and \
                    entry.payload_len >= self._conn.tx_machine.min_payload:
                logical = meta_len + entry.payload_len
            else:
                logical = len(msg)
            p = self._pending = _PendingSend(src_conn, msg, logical)
            self._first_parse = parsed
        sm = self._conn.tx_machine
        # FAST_PATH resumes machine-side from the cumulative offset and needs
        # the full message; every other path is a plain byte stream.
        chunk = p.msg if sm.state is St.FAST_PATH else p.msg[p.accepted:]
        parsed = self._first_parse if p.accepted == 0 else None
        self._first_parse = None
        n = libra_send(p.src_conn, self._conn, chunk, self._stack.pool,
                       self._stack.registry, self._stack.counters,
                       send_budget=budget, parsed=parsed,
                       payload_prefetched=payload_prefetched,
                       pool_router=self._stack.pool_for_entry)
        p.accepted += n
        if p.accepted >= p.logical:
            self._pending = None
            self._stack._gc_anchor_owners()
        return n

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LibraSocket(fd={self.fileno()}, parser={self.parser.name}, "
                f"rx={self.rx_available()}, closed={self.closed})")
