"""Egress datapath — §3.4: payload reassembly with L7 state synchronisation.

``libra_send`` wraps the instrumented sendmsg with the two-phase eBPF
orchestration:

  Pre-Send  : parse new metadata, extract + resolve the embedded VPI
              (map hit -> FAST_PATH; miss -> FALLBACK_BYPASS)
  Data plane: copy only the new metadata; transfer ownership of the
              anchored pages into the egress stream (two-phase staging,
              §A.2/§A.3 — no payload bytes move)
  Post-Send : cumulative byte accounting (non-blocking partial sends);
              on completion, delete the VPI entry, free pages (refcount,
              §A.4) and reset BOTH state machines (cross-datapath cleanup)

Encrypted destinations (``dst_conn.crypto`` set — the kTLS analogue)
re-encrypt outbound records under the transmitting socket's TX key: the
inner metadata is sealed during the metadata copy, and the payload cipher
is either a separate encrypt-and-copy pass after the gather (``sw`` mode,
§B.1's software penalty, counted in ``CopyCounters.crypto_copied``) or
fused into the gather itself (``hw`` mode — the NIC consumes plaintext
pages and encrypts inline, zero extra passes). The §A.2 staging window now
brackets the payload compose, so a failure between extract and commit
aborts the transfer instead of leaving the §A.3 budget raised forever.

Multi-worker routing: a VPI entry may be a **cross-worker grant** — the
payload lives in ANOTHER worker's pool. ``pool_router`` (supplied by the
socket facade) maps an entry to the pool that actually owns its pages, so
the §A.2 staging, the payload gather, and the final frees all run against
the owning allocator. Grant completion forwards teardown back to the
owner's registry (releasing the owner entry when it is still live; an
owner already inside its §A.4 grace period keeps its own deferred-free
schedule — the grant's pin reference is what kept the pages alive). The
one-copy fallback entries instead carry the payload in ``entry.stash`` and
touch no pool at all.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.anchor_pool import PageRef
from repro.core.crypto import REC_HEADER, record_header
from repro.core.ingress import reset_rx_from_tx
from repro.core.state_machine import St
from repro.core.stream import Connection, CopyCounters, TokenPool
from repro.core.sync import plane_lock
from repro.core.vpi import VpiRegistry


def _extract_vpi(buf: np.ndarray, meta_len: int) -> Optional[int]:
    """The VPI occupies the single int64 slot right after the metadata."""
    if len(buf) < meta_len + 1:
        return None
    v = VpiRegistry.from_token(int(buf[meta_len]))
    return v if v != 0 else None


def _tx_full_copy_crypt(crypto, buf: np.ndarray,
                        chunk: np.ndarray) -> np.ndarray:
    """Encrypt a full-copy TX chunk of a record frame (fallback/bypass/
    short-payload paths). The session tracks (seq, position, end) across
    budget-truncated calls so the keystream resumes mid-record; frames that
    do not start with a record header pass through raw (with the same
    continuation tracking, so a later record is never mistaken for one)."""
    if crypto.tx_resume is None:
        hdr = record_header(buf)
        seq = hdr[0] if hdr is not None else None
        pos, end = 0, len(buf)
    else:
        seq, pos, end = crypto.tx_resume
    out = crypto.tx_encrypt_span(chunk, seq, pos) if seq is not None else chunk
    pos += len(chunk)
    crypto.tx_resume = (seq, pos, end) if pos < end else None
    return out


def libra_send(
    src_conn: Connection,
    dst_conn: Connection,
    buf: np.ndarray,
    pool: TokenPool,
    registry: VpiRegistry,
    counters: CopyCounters,
    send_budget: Optional[int] = None,
    parsed=None,
    payload_prefetched: Optional[np.ndarray] = None,
    pool_router=None,
) -> int:
    """Transmit the proxy's outgoing buffer [new_metadata..., VPI] on
    ``dst_conn``. Returns the number of *logical* bytes accepted (like a
    non-blocking send). ``send_budget`` models a constrained send buffer;
    ``parsed`` reuses a ParseResult already computed for ``buf``;
    ``payload_prefetched`` hands in this message's anchored payload when a
    batched forward already gathered it (one fused read for the round) —
    it MUST be the exact payload bytes this socket would compose itself
    (``read_payload`` output, with the TX keystream already fused for an
    encrypted hw-mode destination). ``pool_router`` (entry -> TokenPool)
    resolves the pool that owns an entry's pages — cross-worker grant
    entries route to the owning worker's pool; None keeps everything on
    ``pool`` (single-stack behaviour).
    """
    sm = dst_conn.tx_machine
    crypto = dst_conn.crypto
    decision = sm.pre_send(buf, _extract_vpi, parsed=parsed)

    if decision.state in (St.DEFAULT, St.FALLBACK_BYPASS, St.METADATA_PARSED):
        n = len(buf) if send_budget is None else min(len(buf), send_budget)
        chunk = np.asarray(buf[:n]).copy()
        if crypto is not None and n:
            chunk = _tx_full_copy_crypt(crypto, buf, chunk)
        dst_conn.tx_stream.append(chunk)
        counters.full_copied += n
        if decision.state != St.DEFAULT:
            done = sm.post_send(n)
            if done:
                reset_rx_from_tx(src_conn)
        return n

    assert decision.state == St.FAST_PATH
    # cumulative resume offset: a budget-constrained send picks the message
    # up where the previous call left off (Post-Send accounting, §3.4)
    start = sm.sent_cumulative
    entry = registry.resolve(decision.vpi)
    if entry is None:
        # only reachable on a resume: the anchoring socket closed mid-send
        # (§A.4 moved the entry to TEARDOWN and deferred the page frees).
        # The staged frame completes the transmission; teardown expiry owns
        # the pages now, so the done-cleanup below must not free them.
        assert start > 0 and sm.staged_out is not None, decision.vpi
        owned = None
        data_pool = pool
    else:
        # cross-worker grant entries name another worker's pool: stage,
        # gather and free against the pool that owns the pages
        data_pool = pool_router(entry) if pool_router is not None else pool
        owned = [PageRef(*pg) for pg in entry.pages]
        if start == 0:
            meta = np.asarray(buf[: sm.meta_len]).copy()
            # §A.2 two-phase ownership transfer through the staging list;
            # the payload compose sits INSIDE the stage->commit window so a
            # failure aborts the transfer (restoring the §A.3 budget raise)
            # instead of leaving it elevated forever. For a cross-worker
            # grant entry, data_pool is the OWNING worker's pool and this
            # code may run from the destination worker's quantum — the
            # whole stage->commit window holds the cluster-plane lock
            # (a no-op single-stack; see repro.core.sync).
            with plane_lock(data_pool.alloc):
                staged = data_pool.alloc.stage_transfer(owned)
                try:
                    if crypto is not None:
                        seq = int(meta[1])
                        imeta = len(meta) - REC_HEADER
                        meta = crypto.seal_meta(meta)
                    # zero-copy "transmission": the NIC consumes anchored
                    # pages in place; the composed frame stays staged
                    # across partial sends. A one-copy cross-worker entry
                    # already carries its payload (entry.stash) — the pool
                    # is never consulted.
                    raw = (np.asarray(entry.stash, np.int64)
                           if entry.stash is not None else None)
                    if payload_prefetched is not None:
                        payload = payload_prefetched
                    elif crypto is None:
                        payload = raw if raw is not None else \
                            data_pool.read_payload(owned, entry.payload_len)
                    elif crypto.mode == "hw":
                        # hw-kTLS: the TX cipher rides the gather — the NIC
                        # encrypts inline while consuming the anchored pages
                        ks = crypto.tx_payload_keystream(
                            seq, imeta, entry.payload_len)
                        payload = (np.bitwise_xor(raw, ks)
                                   if raw is not None
                                   else data_pool.read_payload(
                                       owned, entry.payload_len,
                                       keystream=ks))
                    else:
                        # sw-kTLS: encrypt-and-copy re-touches the gathered
                        # payload in a separate pass (§B.1)
                        payload = raw if raw is not None else \
                            data_pool.read_payload(owned, entry.payload_len)
                        payload = crypto.sw_encrypt_payload(seq, imeta,
                                                            payload)
                        counters.crypto_copied += entry.payload_len
                except BaseException:
                    data_pool.alloc.abort_transfer(staged)
                    raise
                owned = data_pool.alloc.commit_transfer(staged)
            # data plane: selective copy of the new metadata only (counted
            # after the commit so an aborted compose, retried later, does
            # not double-charge the copy telemetry)
            counters.meta_copied += len(meta)
            counters.zero_copied += entry.payload_len
            sm.staged_out = np.concatenate([meta, payload])
    out = sm.staged_out

    remaining = len(out) - start
    n = remaining if send_budget is None else min(remaining, send_budget)
    dst_conn.tx_stream.append(out[start : start + n])

    if sm.post_send(n):
        # cross-datapath cleanup: VPI entry out of the global map, pages
        # refcount-released, RX machine of the source connection reset.
        # A cross-worker completion mutates BOTH the destination registry
        # and the owner's registry/pool, possibly from the source worker's
        # quantum — the whole cleanup holds the cluster-plane lock.
        grant = entry.grant if entry is not None else None
        with plane_lock(registry):
            if owned is not None and registry.release(decision.vpi):
                if grant is not None:
                    # drop the grant's pin ref on the owning worker's pool,
                    # then forward the completion to the owner: a
                    # still-live owner entry gets the exact single-stack
                    # cleanup (entry released, original page ref dropped);
                    # an owner already in — or past — its §A.4 grace
                    # period keeps its deferred-free schedule (the expiry
                    # drops the original ref, we only dropped ours)
                    data_pool.alloc.release_export(owned)
                    oreg, ovpi = grant.owner_registry, grant.owner_vpi
                    if oreg.peek(ovpi) is not None and oreg.release(ovpi):
                        data_pool.alloc.free_pages_list(owned)
                    src_conn.anchored.pop(ovpi, None)
                else:
                    data_pool.alloc.free_pages_list(owned)
        src_conn.anchored.pop(decision.vpi, None)
        reset_rx_from_tx(src_conn)
    return n


def libra_close(
    conn: Connection,
    pool: TokenPool,
    registry: VpiRegistry,
    now_tick: int,
) -> int:
    """§A.4 safe teardown: if payloads are still anchored when the socket
    closes, defer the free by a grace period instead of dangling."""
    conn.closed = True
    deferred = 0
    # membership check → teardown+defer is one atomic region (a threaded
    # peer completing a grant forward could drop the entry in between)
    with plane_lock(registry):
        for vpi, (pages, _ln) in list(conn.anchored.items()):
            if vpi in registry:
                registry.begin_teardown(vpi, now_tick)
                pool.alloc.defer_free(pages, now_tick + registry.grace_ticks)
                deferred += 1
            conn.anchored.pop(vpi, None)
    return deferred


def expire_teardowns(pool: TokenPool, registry: VpiRegistry, now_tick: int) -> int:
    """Periodic tick: release grace-period-expired anchors (§A.4)."""
    with plane_lock(registry):
        registry.expire_teardowns(now_tick)
    with plane_lock(pool.alloc):
        return pool.alloc.expire_deferred(now_tick)
