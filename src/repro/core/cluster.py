"""``LibraCluster`` — multi-worker scale-out of the Libra stack.

One :class:`~repro.core.stack.LibraStack` is the paper's single-core
kernel instance: one anchor pool, one VPI map, one scheduler round.  Real
L7 deployments steer flows across many queues/cores *before* the proxy
sees them (RSS / application-defined receive-side dispatching) and keep
per-core state partitioned (XLB-style).  This module is that layer:

* :class:`SteeringPolicy` — the RSS analogue.  ``mode='hash'`` places a
  flow by **consistent hashing** its 4-tuple-analogue key onto a ring of
  virtual nodes (adding/removing a worker re-steers only ~1/N of flows);
  ``mode='app'`` delegates to an application callable (the RSD idea:
  steering is programmable, like the parser policies).  Live re-steering
  is supported and counted (``resteer``).
* :class:`LibraCluster` — owns N independent workers (each a full
  ``LibraStack``: own pool, own registry, own clock) plus the steering
  layer.  ``cluster.socket(flow=...)`` / ``socket_pair`` place endpoints
  transparently; the returned sockets are ordinary :class:`LibraSocket`\\ s.
* **Cross-worker handoff (the VPI grant protocol)** — a proxied flow whose
  src and dst land on different workers must move an anchored payload from
  worker A's pool to worker B's egress *without a user-space bounce*:

  - **zero-copy grant** (default): B's registry imports a grant entry that
    *references* A's pages (``VpiRegistry.import_grant``); A pins them with
    an extra refcount (``AnchorPool.export_grant``) so the grant safely
    outlives even A's §A.4 teardown grace.  B's egress composes the frame
    straight out of A's pool (``LibraStack.pool_for_entry`` routing; the
    batched path runs the fused gather against A's resident device pool —
    the peer-to-peer DMA analogue).  Completion forwards teardown back to
    A.  Counted in ``CopyCounters.cross_worker_grants``.
  - **one-copy fallback**: when B's pool sits above its watermark (a
    congested egress worker should not pin a peer's memory across a long
    backlog), the payload is gathered once out of A's pool at handoff time,
    A's anchor is released immediately (relieving the owner), and the grant
    entry carries the bytes itself (``entry.stash``).  The copied tokens
    are counted in ``CopyCounters.cross_worker_copied`` — separately from
    the Fig. 9 categories, so a cluster run stays **counter-identical** to
    a single-stack run at any cross-worker fraction.

* :class:`ClusterRuntime` — drives one :class:`ProxyRuntime` per worker
  round-robin, with **work stealing**: a worker whose ready set is empty
  services ready channels stolen from the most-backlogged peer (scalar
  quanta — channels are self-contained, so stealing changes *where* a
  quantum runs, never its bytes or counters).  Aggregated counters and
  latency summaries across workers; ``run_parallel`` reports per-worker
  wall times for the ideal-parallel throughput model (the workers are
  independent event loops — on real cores they run concurrently; the
  single-process repro emulates that by taking the slowest worker's
  critical path).
"""
from __future__ import annotations

import bisect
import hashlib
import struct
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.anchor_pool import PageRef
from repro.core.runtime import ProxyChannel, ProxyRuntime
from repro.core.socket import LibraSocket
from repro.core.stack import LibraStack, ParserLike
from repro.core.stream import CopyCounters
from repro.core.sync import ClusterLock

#: steering callable signature for mode='app': (flow_key, n_workers) -> int
AppSteer = Callable[[object, int], int]


def _stable_hash(secret: bytes, obj: object) -> int:
    """Position on the steering ring: keyed blake2b of a stable encoding
    of the flow key (repr — flow keys are meant to be plain tuples/ints/
    strings, the 4-tuple analogue)."""
    h = hashlib.blake2b(repr(obj).encode(), key=secret, digest_size=8)
    return struct.unpack("<Q", h.digest())[0]


class _WorkerCtx:
    """Scopes ``LibraCluster.current_worker`` to one scheduling quantum
    (restoring the previous attribution on exit, so nested quanta — a
    survivor draining a dying worker's channel — unwind correctly)."""

    __slots__ = ("cluster", "w", "prev")

    def __init__(self, cluster: "LibraCluster", w: Optional[int]):
        self.cluster = cluster
        self.w = w

    def __enter__(self) -> "_WorkerCtx":
        self.prev = self.cluster.current_worker
        self.cluster.current_worker = self.w
        return self

    def __exit__(self, *exc) -> None:
        self.cluster.current_worker = self.prev


class SteeringPolicy:
    """RSS-style flow steering: flow key -> worker id.

    ``hash`` mode is a consistent-hash ring with ``replicas`` virtual
    nodes per worker — the same flow maps to the same worker across
    re-registration and across policy instances built with the same
    parameters, and resizing the worker set moves only ~1/N of flows.
    ``app`` mode delegates to ``app_fn(flow, n_workers)`` (the
    application-defined receive-side dispatching analogue).

    ``stats`` records live steering behaviour: per-worker placements,
    total decisions, and — across :meth:`resteer` calls — how many tracked
    flows actually moved.
    """

    MODES = ("hash", "app")

    def __init__(self, n_workers: int, mode: str = "hash",
                 app_fn: Optional[AppSteer] = None, replicas: int = 64,
                 secret: bytes = b"libra-steer"):
        assert mode in self.MODES, mode
        assert n_workers >= 1, n_workers
        if mode == "app" and app_fn is None:
            raise ValueError("mode='app' needs an app_fn(flow, n_workers)")
        self.mode = mode
        self.app_fn = app_fn
        self.replicas = replicas
        self.secret = secret
        self.n_workers = n_workers
        # every worker steers through this one object (placements, stats,
        # the ring): self-locking, per the repro.core.sync discipline
        self.lock = ClusterLock("steering")
        # workers removed by failure: their vnodes leave the ring (hash
        # mode) / their index is skipped (app mode); indices of the
        # survivors never shift, so placements stay stable
        self.dead: set = set()
        self._ring: List[Tuple[int, int]] = []
        self._build_ring()
        # flow -> worker placements observed so far (live re-steer stats)
        self.placements: Dict[object, int] = {}
        self.stats = {"steered": 0, "resteers": 0, "moved": 0,
                      "per_worker": [0] * n_workers}

    def _build_ring(self) -> None:
        ring = []
        for w in range(self.n_workers):
            if w in self.dead:
                continue
            for r in range(self.replicas):
                ring.append((_stable_hash(self.secret, ("vnode", w, r)), w))
        assert ring, "steering needs at least one live worker"
        ring.sort()
        self._ring = ring
        self._ring_keys = [h for h, _ in ring]   # bisect array, built once

    def worker_for(self, flow: object, track: bool = True) -> int:
        """Steer ``flow`` (any hashable 4-tuple analogue) to a worker.
        ``track=False`` skips the placement record — used for one-shot
        auto-generated flow keys that can never recur, so a long-lived
        cluster's placement map stays bounded by *named* flows."""
        with self.lock:
            if self.mode == "app":
                w = int(self.app_fn(flow, self.n_workers)) % self.n_workers
                while w in self.dead:
                    # app steering is dead-worker-oblivious:
                    # deterministically walk to the next live index
                    # (consistent across callers)
                    w = (w + 1) % self.n_workers
            else:
                pos = _stable_hash(self.secret, flow)
                i = bisect.bisect_right(self._ring_keys, pos) \
                    % len(self._ring)
                w = self._ring[i][1]
            self.stats["steered"] += 1
            self.stats["per_worker"][w] += 1
            if track:
                self.placements[flow] = w
            return w

    def forget(self, flow: object) -> None:
        """Drop a tracked flow (its connection closed) from the placement
        map, so resteer stats cover only live flows."""
        with self.lock:
            self.placements.pop(flow, None)

    def resteer(self, n_workers: Optional[int] = None,
                mode: Optional[str] = None,
                app_fn: Optional[AppSteer] = None) -> int:
        """Live policy change (worker set resize / mode swap). Re-evaluates
        every tracked flow and returns how many moved (also accumulated in
        ``stats['moved']``) — with consistent hashing a resize moves only
        ~1/N of flows; a mode swap can move anything."""
        if mode is not None:
            assert mode in self.MODES, mode
        if (mode or self.mode) == "app" and (app_fn or self.app_fn) is None:
            # validate BEFORE mutating any state: a hash->app swap without
            # a callable must not die mid-resteer with stats half-reset
            raise ValueError("mode='app' needs an app_fn(flow, n_workers)")
        with self.lock:
            if n_workers is not None:
                self.n_workers = n_workers
            if mode is not None:
                self.mode = mode
            if app_fn is not None:
                self.app_fn = app_fn
            self._build_ring()
            self.stats["per_worker"] = ([0] * self.n_workers)
            self.stats["resteers"] += 1
            moved = 0
            old = dict(self.placements)
            for flow, prev in old.items():
                if self.worker_for(flow) != prev:
                    moved += 1
            self.stats["moved"] += moved
            return moved

    def remove_worker(self, w: int) -> int:
        """Take a failed worker out of the steering set: its vnodes leave
        the ring (app mode skips its index), survivor indices never shift,
        and every tracked flow is re-evaluated — with consistent hashing
        only the dead worker's ~1/N of flows move. Idempotent; returns how
        many flows moved."""
        with self.lock:
            if w in self.dead:
                return 0
            assert len(self.dead) + 1 < self.n_workers, \
                "cannot remove the last live worker"
            self.dead.add(w)
            return self.resteer()


class LibraCluster:
    """N independent :class:`LibraStack` workers + flow steering + the
    cross-worker VPI grant interconnect. Constructor keyword arguments
    other than the ones below are forwarded to every worker stack
    (``pages_per_shard``, ``page_size``, ``device_pool``, ...)."""

    def __init__(self, n_workers: int = 2, *,
                 steering: Union[str, SteeringPolicy] = "hash",
                 app_fn: Optional[AppSteer] = None,
                 secret: Optional[bytes] = None,
                 grace_ticks: int = 5,
                 **stack_kw):
        assert n_workers >= 1, n_workers
        # ONE coarse cluster-plane lock (see repro.core.sync): every
        # cross-worker mutation — grant pins, grant tables, freelists of a
        # peer pool — holds it; attached to each worker's alloc/registry so
        # the egress completion path can find it via plane_lock()
        self.lock = ClusterLock()
        # the worker whose scheduling quantum is executing right now (None
        # = control plane); maintained by ClusterRuntime via as_worker()
        # and read by the test-time LocksetMonitor. Thread-local: under
        # run_parallel(threads=True) each worker thread carries its own
        # attribution, while the cooperative scheduler keeps setting it
        # from the main thread exactly as before.
        self._worker_ctx = threading.local()
        self.workers: List[LibraStack] = []
        for i in range(n_workers):
            wsecret = (None if secret is None
                       else hashlib.blake2b(struct.pack("<q", i), key=secret,
                                            digest_size=16).digest())
            w = LibraStack(secret=wsecret, grace_ticks=grace_ticks,
                           **stack_kw)
            w.worker_id = i
            w.pool.pool_id = f"libra-worker-{i}"
            w.interconnect = self
            w.alloc.lock = self.lock
            w.registry.lock = self.lock
            self.workers.append(w)
        for w in self.workers:
            for peer in self.workers:
                if peer is not w:
                    w.register_peer_pool(peer.pool)
        self.steering = (steering if isinstance(steering, SteeringPolicy)
                         else SteeringPolicy(n_workers, mode=steering,
                                             app_fn=app_fn))
        assert self.steering.n_workers == n_workers, \
            (self.steering.n_workers, n_workers)
        self._flow_serial = 0
        self._worker_by_pool = {w.pool.pool_id: w for w in self.workers}
        # workers torn down by kill_worker: excluded from steering,
        # find_owner and the runtimes' scheduling (indices never shift)
        self.dead_workers: set = set()
        # cross-worker handoff telemetry (cluster-wide; the per-stack
        # CopyCounters carry the same events on the destination worker)
        self.stats = {"grants": 0, "grant_pages": 0,
                      "copies": 0, "copied_tokens": 0, "adopt_misses": 0,
                      "grants_reclaimed": 0, "worker_kills": 0,
                      "dead_grants_copied": 0, "migrated_flows": 0}

    # -- placement -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.workers)

    @property
    def current_worker(self) -> Optional[int]:
        return getattr(self._worker_ctx, "w", None)

    @current_worker.setter
    def current_worker(self, w: Optional[int]) -> None:
        self._worker_ctx.w = w

    def as_worker(self, w: Optional[int]) -> "_WorkerCtx":
        """Scope ``current_worker`` to ``w`` for one scheduling quantum —
        the attribution the lockset instrumentation uses to tell a
        worker-context mutation from control-plane bookkeeping."""
        return _WorkerCtx(self, w)

    def _next_flow(self) -> Tuple[str, int]:
        self._flow_serial += 1
        return ("flow", self._flow_serial)

    def worker_for(self, flow: object) -> LibraStack:
        return self.workers[self.steering.worker_for(flow)]

    def socket(self, parser: ParserLike = "length-prefixed", *,
               flow: Optional[object] = None,
               worker: Optional[int] = None, **kw) -> LibraSocket:
        """Open a connection somewhere on the cluster: ``worker`` pins it,
        ``flow`` steers it through the policy, neither auto-assigns a fresh
        flow key. The returned socket is an ordinary :class:`LibraSocket`
        (its ``worker_id`` tells where it landed)."""
        if worker is not None:
            stack = self.workers[worker]
        elif flow is not None:
            stack = self.workers[self.steering.worker_for(flow)]
        else:
            stack = self.workers[self.steering.worker_for(
                self._next_flow(), track=False)]
        return stack.socket(parser, **kw)

    def socket_pair(self, parser: ParserLike = "length-prefixed", *,
                    flow: Optional[object] = None,
                    **kw) -> Tuple[LibraSocket, LibraSocket]:
        """A (client-side, backend-side) pair of ONE proxied flow — both
        endpoints land on the same worker (flow affinity, the RSS
        property). Cross-worker channels arise when a channel pairs
        sockets of *different* flows."""
        track = flow is not None
        if flow is None:
            flow = self._next_flow()
        w = self.steering.worker_for(flow, track=track)
        stack = self.workers[w]
        return stack.socket(parser, **kw), stack.socket(parser, **kw)

    # -- the VPI grant interconnect -----------------------------------------
    def find_owner(self, vpi: int,
                   exclude: Optional[LibraStack] = None
                   ) -> Optional[LibraStack]:
        """The worker whose registry holds ``vpi`` live (TEARDOWN entries
        do not count: their §A.4 grace belongs to the owner)."""
        for w in self.workers:
            if w is exclude or w.worker_id in self.dead_workers:
                continue
            if w.registry.peek(vpi) is not None:
                return w
        return None

    def grant_into(self, dst_stack: LibraStack, vpi: int) -> Optional[int]:
        """Adopt ``vpi`` — anchored on some peer worker — into
        ``dst_stack``'s registry so its egress can transmit the payload.
        Returns the destination-side VPI, or None when no live owner
        exists cluster-wide (stale handle: the caller's FALLBACK_BYPASS
        takes over, exactly as single-stack).

        Zero-copy grant by default; the counted one-copy fallback when the
        destination pool is above its watermark (see module docstring).

        Holds the cluster-plane lock end to end: the adoption reads the
        owner's registry and mutates two workers' state (pin + grant
        entry), and the caller may be ANY worker's egress quantum."""
        with self.lock:
            return self._grant_into_locked(dst_stack, vpi)

    def _grant_into_locked(self, dst_stack: LibraStack,
                           vpi: int) -> Optional[int]:
        owner = self.find_owner(vpi, exclude=dst_stack)
        if owner is None:
            self.stats["adopt_misses"] += 1
            return None
        entry = owner.registry.peek(vpi)
        pages = list(entry.pages)
        if entry.stash is not None:
            # the owner entry is itself a one-copy handoff: forward the
            # stashed bytes as-is (self-contained — no pool, no pin, no
            # additional copy; the bytes already left the owning pool)
            return dst_stack.registry.import_grant(
                owner.registry, vpi, dst_stack.pool.pool_id, [],
                entry.payload_len, stash=entry.stash)
        if entry.grant is not None:
            # the owner entry is itself a zero-copy grant: FLATTEN the
            # chain — pin and reference the ROOT pool/registry directly so
            # completion always releases the true owner, never a
            # middleman's bookkeeping (the middleman's grant lives on,
            # released by its own transmit or the shutdown reclaim)
            root = entry.grant
            root_worker = self._worker_by_pool.get(entry.pool_id, owner)
            root_worker.alloc.export_grant([PageRef(*pg) for pg in pages])
            try:
                new_vpi = dst_stack.registry.import_grant(
                    root.owner_registry, root.owner_vpi, entry.pool_id, pages,
                    entry.payload_len)
            except BaseException:
                # a pin must never outlive a failed import — that is the
                # PR 5 abandoned-grant leak in miniature (OWN001)
                root_worker.alloc.release_export(
                    [PageRef(*pg) for pg in pages])
                raise
            dst_stack.counters.cross_worker_grants += 1
            self.stats["grants"] += 1
            self.stats["grant_pages"] += len(pages)
            return new_vpi
        if dst_stack.alloc.above_watermark():
            # one-copy fallback: gather once out of the owner's pool, free
            # the owner's anchor immediately (the copy IS the handoff), and
            # ship the bytes on the grant entry itself
            refs = [PageRef(*pg) for pg in pages]
            payload = owner.pool.read_payload(refs, entry.payload_len)
            dst_stack.counters.cross_worker_copied += entry.payload_len
            self.stats["copies"] += 1
            self.stats["copied_tokens"] += entry.payload_len
            new_vpi = dst_stack.registry.import_grant(
                owner.registry, vpi, dst_stack.pool.pool_id, [],
                entry.payload_len, stash=payload)
            owner_sock = owner._anchor_owner(vpi)
            if owner.registry.release(vpi):
                owner.alloc.free_pages_list(refs)
            if owner_sock is not None:
                owner_sock.connection.anchored.pop(vpi, None)
            return new_vpi
        # zero-copy grant: pin the owner's pages, reference them from the
        # destination registry, forward teardown on completion (egress)
        owner.alloc.export_grant([PageRef(*pg) for pg in pages])
        try:
            new_vpi = dst_stack.registry.import_grant(
                owner.registry, vpi, owner.pool.pool_id, pages,
                entry.payload_len)
        except BaseException:
            owner.alloc.release_export([PageRef(*pg) for pg in pages])
            raise
        dst_stack.counters.cross_worker_grants += 1
        self.stats["grants"] += 1
        self.stats["grant_pages"] += len(pages)
        return new_vpi

    # -- cluster-wide lifecycle / telemetry ----------------------------------
    def reclaim_abandoned_grants(self) -> int:
        """Release cross-worker handoff entries that will never transmit
        (their grantee socket closed, or shutdown abandoned the message
        holding the granted VPI). Drops each zero-copy grant's pin on the
        owner's pool — the egress completion that normally does this can
        no longer happen — and removes the entry; stash entries just go.
        Returns the number of entries reclaimed. Called by
        :meth:`ClusterRuntime.shutdown` after every socket is closed and
        grace periods have drained (the single-stack analogue: staged
        frames abandoned on closed sockets die at shutdown)."""
        reclaimed = 0
        with self.lock:
            for w in self.workers:
                for entry in w.registry.handoffs():
                    if entry.grant is not None:
                        owner = self._worker_by_pool.get(entry.pool_id)
                        if owner is not None:
                            owner.alloc.release_export(
                                [PageRef(*pg) for pg in entry.pages])
                    w.registry.drop(entry.vpi)
                    reclaimed += 1
        self.stats["grants_reclaimed"] += reclaimed
        return reclaimed

    def kill_worker(self, w: int) -> Dict[str, int]:
        """Tear down worker ``w`` as a *failure* (state-plane half; the
        :class:`ClusterRuntime` drains and migrates flows first):

        1. Survivor registries holding **zero-copy grants into the dying
           pool** copy the payload out while the pages still exist —
           the grant becomes a self-contained stash entry (counted in
           ``cross_worker_copied``, like the live one-copy fallback) and
           the dead pool's pin is released. Survivors' in-flight messages
           therefore stay byte-identical.
        2. Grants the dying worker held **into survivor pools** release
           their pins (the dead-owner extension of
           :meth:`reclaim_abandoned_grants`) and are dropped.
        3. Every dying-worker socket closes; grace periods flush.
        4. The worker leaves the steering set (idempotent with a prior
           :meth:`SteeringPolicy.remove_worker`) and joins
           ``dead_workers``.

        Ends by asserting the dead pool leaked nothing: every page free,
        zero outstanding grant pins. Returns a small accounting dict.
        Holds the cluster-plane lock end to end — the sweep walks and
        mutates every survivor's grant table and the dying pool."""
        with self.lock:
            return self._kill_worker_locked(w)

    def _kill_worker_locked(self, w: int) -> Dict[str, int]:
        assert 0 <= w < len(self.workers), w
        assert w not in self.dead_workers, f"worker {w} already dead"
        dead = self.workers[w]
        info = {"grants_copied_out": 0, "grants_released": 0,
                "pages_reclaimed": 0, "flows_resteered": 0}
        for surv in self.workers:
            if surv is dead or surv.worker_id in self.dead_workers:
                continue
            for entry in surv.registry.handoffs():
                if entry.grant is None \
                        or entry.pool_id != dead.pool.pool_id:
                    continue
                refs = [PageRef(*pg) for pg in entry.pages]
                entry.stash = dead.pool.read_payload(refs, entry.payload_len)
                entry.grant = None
                entry.pages = []
                entry.pool_id = surv.pool.pool_id
                dead.alloc.release_export(refs)
                surv.counters.cross_worker_copied += entry.payload_len
                self.stats["copies"] += 1
                self.stats["copied_tokens"] += entry.payload_len
                self.stats["dead_grants_copied"] += 1
                info["grants_copied_out"] += 1
        for entry in dead.registry.handoffs():
            if entry.grant is not None:
                owner = self._worker_by_pool.get(entry.pool_id)
                if owner is not None and owner is not dead:
                    owner.alloc.release_export(
                        [PageRef(*pg) for pg in entry.pages])
                    info["grants_released"] += 1
            dead.registry.drop(entry.vpi)
        dead.close_all()
        info["pages_reclaimed"] = dead.drain()
        info["flows_resteered"] = self.steering.remove_worker(w)
        self.dead_workers.add(w)
        self.stats["worker_kills"] += 1
        assert dead.alloc.granted_out_pages == 0, \
            f"worker {w} leaked {dead.alloc.granted_out_pages} grant pins"
        assert dead.alloc.free_pages == dead.alloc.total_pages, \
            (f"worker {w} leaked pages: {dead.alloc.free_pages}/"
             f"{dead.alloc.total_pages} free")
        return info

    def assert_no_leaks(self) -> None:
        """The zero-leak guarantee, checked pool by pool: every page back
        on its freelist, zero outstanding grant pins, no handoff entries
        left in any registry (dead workers included — their teardown
        already enforced this)."""
        for w in self.workers:
            a = w.alloc
            assert a.granted_out_pages == 0, \
                (w.worker_id, "granted_out_pages", a.granted_out_pages)
            assert a.free_pages == a.total_pages, \
                (w.worker_id, "pages", a.free_pages, a.total_pages)
            assert not w.registry.handoffs(), \
                (w.worker_id, "handoff entries remain")

    def tick(self, n: int = 1) -> int:
        return sum(w.tick(n) for w in self.workers)

    def drain(self) -> int:
        return sum(w.drain() for w in self.workers)

    def close_all(self) -> int:
        return sum(w.close_all() for w in self.workers)

    @property
    def pages_in_use(self) -> int:
        return sum(w.pages_in_use for w in self.workers)

    def counters_aggregate(self) -> CopyCounters:
        """Cluster-wide CopyCounters (field-wise sum over workers) — the
        quantity that must be identical to a single-stack run of the same
        workload, at any cross-worker fraction."""
        agg = CopyCounters()
        for w in self.workers:
            for f in CopyCounters.__dataclass_fields__:
                setattr(agg, f, getattr(agg, f) + getattr(w.counters, f))
        return agg

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LibraCluster(workers={len(self.workers)}, "
                f"steering={self.steering.mode}, "
                f"grants={self.stats['grants']}, "
                f"copies={self.stats['copies']})")


class ClusterRuntime:
    """One :class:`ProxyRuntime` per worker, driven round-robin, with
    optional work stealing between idle and backlogged workers."""

    def __init__(self, cluster: LibraCluster, *,
                 work_stealing: bool = True, steal_batch: int = 4,
                 policy=None, fault_plan=None, **rt_kw):
        self.cluster = cluster
        # chaos harness: one FaultPlan for the whole cluster — installed
        # on every worker stack (send/deliver hooks) and driven once per
        # CLUSTER round via on_cluster_step (worker kills, pool pressure,
        # scheduled callbacks); the per-worker runtimes do not drive it
        self.fault_plan = fault_plan
        if fault_plan is not None:
            for w in cluster.workers:
                fault_plan.install(w)
        # per-worker L7 policy tables: a PolicyTable is cloned per worker
        # (token-bucket state is worker-local, like every other hot-path
        # structure); a callable ``policy(worker_id)`` builds each worker's
        # table instead, for deliberately heterogeneous clusters
        if policy is None:
            tables = [None] * len(cluster.workers)
        elif callable(policy):
            tables = [policy(i) for i in range(len(cluster.workers))]
        else:
            tables = [policy.clone() for _ in cluster.workers]
        self.policies = tables
        self.runtimes = [ProxyRuntime(w, policy=t, **rt_kw)
                         for w, t in zip(cluster.workers, tables)]
        self.work_stealing = work_stealing
        self.steal_batch = steal_batch
        self.rounds = 0
        self.stats = {"steals": 0, "stolen_quanta": 0}

    def policy_summary(self) -> dict:
        """Cluster-wide policy telemetry: the field-wise sum of each
        worker's table stats (mirroring :meth:`LibraCluster.
        counters_aggregate` — the totals must match a single-worker run of
        the same workload), plus the per-worker summaries."""
        per_worker = [None if t is None else t.summary()
                      for t in self.policies]
        agg: dict = {}
        for s in per_worker:
            if s is None:
                continue
            for k, v in s.items():
                if isinstance(v, int):
                    agg[k] = agg.get(k, 0) + v
                elif isinstance(v, list):
                    cur = agg.setdefault(k, [0] * len(v))
                    for i, x in enumerate(v):
                        cur[i] += x
                elif isinstance(v, dict):
                    cur = agg.setdefault(k, {})
                    for rk, rv in v.items():
                        cur[rk] = cur.get(rk, 0) + rv
        return {"aggregate": agg, "per_worker": per_worker}

    # -- registration --------------------------------------------------------
    def channel(self, src: LibraSocket, dst, **kw) -> ProxyChannel:
        """Create a channel and register it on the runtime of the worker
        that owns ``src`` (ingress locality: the receive side is where the
        flow was steered; a dst on another worker makes the channel
        cross-worker and exercises the grant protocol)."""
        rt = self.runtimes[src.worker_id]
        return rt.channel(src, dst, **kw)

    @property
    def channels(self) -> List[ProxyChannel]:
        return [c for rt in self.runtimes for c in rt.channels]

    # -- scheduling ----------------------------------------------------------
    def step(self) -> int:
        """One cluster round: each worker runtime takes one scheduling
        round over its own channels; with work stealing, a worker whose
        ready set is empty first services up to ``steal_batch`` ready
        channels of the most-backlogged peer (scalar quanta — a channel
        is self-contained, so the bytes and counters it produces are
        identical wherever the quantum runs)."""
        progressed = 0
        stolen: set = set()
        dead = self.cluster.dead_workers
        if not self.work_stealing:
            for i, rt in enumerate(self.runtimes):
                if i not in dead:
                    with self.cluster.as_worker(i):
                        progressed += rt.step()
            self.rounds += 1
            if self.fault_plan is not None:
                self.fault_plan.on_cluster_step(self)
            return progressed
        # one readiness evaluation per channel per round: the same lists
        # drive both the stealing decision and each runtime's step
        readys = [([] if i in dead else rt.poll())
                  for i, rt in enumerate(self.runtimes)]
        for i, rdy in enumerate(readys):
            if rdy or i in dead:
                continue
            donor = max(range(len(readys)),
                        key=lambda j: len([c for c in readys[j]
                                           if c not in stolen]))
            avail = [c for c in readys[donor] if c not in stolen]
            if len(avail) < 2:
                continue  # nothing worth stealing (donor keeps its one)
            take = avail[-(min(self.steal_batch, len(avail) // 2)):]
            self.stats["steals"] += 1
            for ch in take:
                stolen.add(ch)
                self.stats["stolen_quanta"] += 1
                # steal-under-lock: the THIEF executes the quantum while
                # holding the plane lock, so the stolen channel's state
                # (the donor's pool/registry) is owner-pinned for the
                # whole handoff — LocksetMonitor attributes the mutations
                # with no special case, and a threaded donor can never
                # race the thief on its own freelists
                with self.cluster.lock:
                    with self.cluster.as_worker(i):
                        progressed += bool(ch.service())
        for i, (rt, rdy) in enumerate(zip(self.runtimes, readys)):
            if i in dead:
                continue
            with self.cluster.as_worker(i):
                progressed += rt.step(
                    skip=stolen if stolen else None,
                    ready=[c for c in rdy if c not in stolen])
        self.rounds += 1
        if self.fault_plan is not None:
            self.fault_plan.on_cluster_step(self)
        return progressed

    def kill_worker(self, w: int, drain_rounds: int = 20000) -> Dict[str, int]:
        """Worker failure with in-flight flow migration (the runtime-plane
        half; :meth:`LibraCluster.kill_worker` finishes the state plane):

        1. **Quiesce** the dying worker's runtime: continuations and held
           sends finish where the backend allows (bounded — retries against
           faulted backends expire into counted timeouts). Survivors mid-
           continuation *into* the dying worker finish too (a budget send
           always accepts bytes, so both loops terminate).
        2. Stragglers that cannot finish (held messages whose anchor dies
           with the worker, half-reassembled messages) are force-dropped
           and counted — their pages free through the close/drain below.
        3. Each dying-worker **flow migrates**: a fresh socket on a
           steering-chosen survivor takes over the channel — the kTLS
           session object moves with it (keys and sequence state ride
           along), undelivered receive-ring bytes are re-delivered
           verbatim, and the channel (stats and all) re-registers on the
           survivor's runtime. Backend sockets on the dying worker are NOT
           migrated — they died with it; health/failover re-routes their
           traffic.
        4. :meth:`LibraCluster.kill_worker` copies dead-owner grants out,
           releases pins, closes/drains the dead stack, removes it from
           steering, and asserts the dead pool leaked nothing.
        """
        cluster = self.cluster
        assert w not in cluster.dead_workers, f"worker {w} already dead"
        rt = self.runtimes[w]
        dead_stack = cluster.workers[w]
        guard = drain_rounds
        with cluster.as_worker(w):
            while guard > 0 and rt.step() > 0:
                guard -= 1
        for i, rt2 in enumerate(self.runtimes):
            if i == w or i in cluster.dead_workers:
                continue
            for ch in rt2.channels:
                guard = drain_rounds
                with cluster.as_worker(i):
                    while ch._inflight is not None \
                            and ch._inflight.stack is dead_stack \
                            and guard > 0:
                        ch.service()
                        guard -= 1
        # steering loses the worker now so migration targets are live
        # (idempotent — LibraCluster.kill_worker's call becomes a no-op)
        cluster.steering.remove_worker(w)
        migrated = 0
        # migration rebinds channels onto survivor workers (fresh sockets,
        # kTLS session moves, runtime re-registration): survivor state
        # mutated from the control plane — hold the plane lock throughout
        with cluster.lock:
            migrated = self._migrate_channels_locked(rt, w, cluster)
        info = cluster.kill_worker(w)
        info["flows_migrated"] = migrated
        return info

    def _migrate_channels_locked(self, rt, w: int, cluster) -> int:
        migrated = 0
        dead_stack = cluster.workers[w]
        for ch in list(rt.channels):
            # stragglers: a held message's anchor dies with this worker —
            # a counted timeout-drop, pages freed via the stack teardown
            if ch._held is not None:
                h, ch._held = ch._held, None
                ch._expire_held(h)
            if ch._rx_parts:
                ch._rx_parts, ch._rx_logical = [], 0
                ch.stats.drops += 1
            ch._pending_verdict = None
            old = ch.src
            if old.closed:
                continue
            tw = cluster.steering.worker_for(("migrate", old.fileno()),
                                             track=False)
            tgt = cluster.workers[tw]
            new = tgt.socket(old.parser,
                             min_payload=old.connection.rx_machine.min_payload,
                             send_budget=old.send_budget)
            if old.tls is not None:
                # kTLS flow migration: the session OBJECT moves — keys and
                # record sequence state continue on the new worker
                new.tls = old.tls
                new.connection.crypto = old.tls
            pend = old.connection.rx_peek(old.rx_available())
            if len(pend):
                # internal hand-off, not network delivery: bypass the
                # socket's fault hook (no double corruption)
                new.connection.deliver(np.array(pend))
            old.close()
            if ch.policy is rt.policy:
                ch.policy = None     # inherit the survivor's table clone
            ch.src = new
            rt.channels.remove(ch)
            self.runtimes[tw].register(ch)
            migrated += 1
            cluster.stats["migrated_flows"] += 1
        return migrated

    def run(self, max_rounds: int = 10 ** 6) -> int:
        """Interleaved cluster loop until no worker has ready work."""
        rounds = 0
        while rounds < max_rounds:
            if self.step() == 0:
                break
            rounds += 1
        return self.messages_forwarded()

    def run_parallel(self, max_rounds: int = 10 ** 6, *,
                     threads: bool = False, epoch_rounds: int = 256
                     ) -> Tuple[int, List[float]]:
        """Run each worker's runtime to completion independently and
        return ``(messages_forwarded, per-worker wall seconds)``. The
        workers are independent event loops (cross-worker forwards are
        driven entirely by the src-side channel), so on real cores they
        run concurrently; with ``threads=False`` the single-process repro
        emulates the parallel wall clock as ``max(per-worker seconds)``
        — the critical path.

        ``threads=True`` makes it real: one OS thread per live worker,
        each scoped to its island via the thread-local worker context.
        Byte- and counter-identical to the emulated scheduler (the only
        cross-thread state — peer pools/registries on the grant path —
        is plane-locked end to end; the grant-vs-copy choice depends
        only on destination watermark pressure, not on interleaving).
        With a ``fault_plan``, workers run in *epochs* of
        ``epoch_rounds`` rounds with a full barrier between epochs: the
        control plane fires due fault events (worker kills migrate flows
        while every worker thread is joined), so ``at=`` times are in
        epoch units under this executor.
        """
        if not threads:
            times: List[float] = []
            for i, rt in enumerate(self.runtimes):
                t0 = time.perf_counter()
                with self.cluster.as_worker(i):
                    rt.run(max_rounds)
                times.append(time.perf_counter() - t0)
            return self.messages_forwarded(), times
        return self._run_threads(max_rounds, epoch_rounds)

    def _run_threads(self, max_rounds: int, epoch_rounds: int
                     ) -> Tuple[int, List[float]]:
        times = [0.0] * len(self.runtimes)
        errors: List[BaseException] = []

        def drive(i: int, rt: ProxyRuntime, budget: int) -> None:
            t0 = time.perf_counter()
            try:
                with self.cluster.as_worker(i):
                    rt.run(budget)
            except BaseException as e:  # propagate to the joining thread
                errors.append(e)
            finally:
                times[i] += time.perf_counter() - t0

        def epoch(budget: int) -> None:
            ts = [threading.Thread(target=drive, args=(i, rt, budget),
                                   name=f"libra-worker-{i}")
                  for i, rt in enumerate(self.runtimes)
                  if i not in self.cluster.dead_workers]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            if errors:
                raise errors[0]

        if self.fault_plan is None:
            epoch(max_rounds)
            return self.messages_forwarded(), times

        # epoch-barrier loop: threads run epoch_rounds, join, then the
        # control plane (single-threaded) fires due fault events — a
        # kill_worker migration never races a live worker thread
        plan = self.fault_plan
        rounds_left = max_rounds
        last_msgs = -1
        while rounds_left > 0:
            epoch(min(epoch_rounds, rounds_left))
            rounds_left -= epoch_rounds
            self.rounds += 1
            plan.on_cluster_step(self)
            msgs = self.messages_forwarded()
            pending = any(
                (ev.kind in ("kill", "at") and not ev.done)
                or (ev.kind == "reset" and plan.now < ev.at)
                for ev in plan.events)
            busy = any(rt.poll() for i, rt in enumerate(self.runtimes)
                       if i not in self.cluster.dead_workers)
            if msgs == last_msgs and not busy and not pending:
                break
            last_msgs = msgs
        return self.messages_forwarded(), times

    def shutdown(self) -> int:
        if self.fault_plan is not None:
            self.fault_plan.release_all()
        deferred = sum(rt.shutdown() for rt in self.runtimes)
        # grants whose transmit was abandoned by the shutdown would pin
        # their owner's pages forever — reclaim them now that every
        # socket is closed and every grace period has drained; then close
        # any stray non-channel sockets, flush the last grace periods, and
        # hold the zero-leak guarantee on every pool
        self.cluster.close_all()
        self.cluster.drain()
        self.cluster.reclaim_abandoned_grants()
        self.cluster.assert_no_leaks()
        return deferred

    # -- telemetry -----------------------------------------------------------
    def messages_forwarded(self) -> int:
        return sum(rt.messages_forwarded() for rt in self.runtimes)

    def logical_bytes(self) -> int:
        return sum(rt.logical_bytes() for rt in self.runtimes)

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for i, rt in enumerate(self.runtimes):
            for name, s in rt.latency_summary().items():
                out[f"w{i}/{name}"] = s
        return out
