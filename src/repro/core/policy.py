"""In-data-plane L7 policy engine — a vectorized policy table fused into
the batched metadata pass.

Libra's bet is that proxies only need small *metadata* in user space while
the bulk payload stays below the boundary. This module pushes the routing
decision itself below the boundary too (the "Offloading L7 Policies to the
Kernel" / XLB direction): a :class:`PolicyTable` is an ordered list of
rules — header-prefix / byte-range matchers over the metadata tokens →
an action — that **compiles to dense int32 arrays** and is evaluated for a
whole batched round as ONE vectorized first-match pass
(:func:`repro.kernels.ops.policy_match`: pure-jnp oracle, interpret-mode
Pallas kernel, or the real TPU kernel — plus an int64-exact numpy path for
the host datapath). Matched messages are admitted, matched, and queued for
``forward_batch`` to their verdict backend without the per-channel Python
routing callbacks ever running; Python becomes the slow-path exception
handler (``PUNT``).

Match semantics (the contract shared by the kernel, the jnp oracle, the
numpy fast path, and :meth:`PolicyTable.interpret` — the naive Python
interpreter the property tests compare against):

* a condition ``(offset, lo, hi)`` with ``offset >= 0`` holds iff
  ``offset < meta_len`` and ``lo <= meta[offset] <= hi`` (padding slots,
  ``offset == -1``, always hold);
* a *payload-prefix* condition — ``offset <= -2``, encoding position
  ``-offset - 2`` of the message's **first anchored page** (built with
  :func:`payload_at` / :func:`payload_prefix`) — holds iff that position
  is inside both the page window and the payload and the *plaintext*
  payload token is in ``[lo, hi]``. The fused device round evaluates it
  directly against the page tokens it is anchoring (still in registers);
  the host paths peek the first page window;
* a rule matches iff all its conditions hold;
* the verdict row is the FIRST matching rule (rule order is priority);
  ``R`` (the row count) is the no-match sentinel.

Action semantics (resolved host-side from the matched row — the stateful
O(B) part; matching is the O(B·R·K) data-plane part):

* ``FORWARD(backend_k)`` — route to the channel's ``dsts[k]``.
* ``REWRITE(slot, value, backend)`` — patch metadata token ``slot`` then
  forward. A slot outside the metadata PUNTs (``rewrite-overflow``); a
  rewrite on an encrypted record PUNTs too (``rewrite-crypto``: patching
  sealed metadata would break the record's auth tag).
* ``RATE_LIMIT(rate, burst, backend, per)`` — token bucket (``rate``
  tokens/tick refill, ``burst`` capacity, milli-token granularity so the
  dense encoding round-trips), keyed per rule or — ``per=offset`` — per
  tenant token ``meta[offset]``. A debit forwards; an empty bucket PUNTs
  (``rate-limited``) so Python decides what an over-limit flow deserves.
* ``DROP`` — consume the message and free its anchored pages
  (:meth:`LibraStack.drop_message`), nothing transmitted.
* ``PUNT`` — explicit slow-path escape.

``PUNT`` verdicts (no match, rewrite overflow, rate-limited, malformed
header, unknown backend) always fall back to the channel's existing
``rewrite``/``router`` callback path; per-verdict counters live in
:class:`~repro.core.stream.CopyCounters` (``policy_hits`` /
``policy_punts`` / ``policy_drops`` / ``policy_rate_debits`` — event
counters, excluded from the Fig. 9 copy-identity snapshot, summed by
``LibraCluster.counters_aggregate``) and in :attr:`PolicyTable.stats`.

:class:`PythonPolicyRouter` is the contrast baseline: the SAME table
evaluated message-by-message by the naive interpreter, exposed through the
classic per-channel callback slots — what the offload bypasses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sync import ClusterLock

#: action kinds (dense ``act_kind`` encoding, stable across compile/decode)
ACT_FORWARD, ACT_REWRITE, ACT_RATE_LIMIT, ACT_DROP, ACT_PUNT = range(5)

#: milli-token fixed point for rates/bursts in the dense int32 encoding
_MILLI = 1000

#: PUNT reasons (Verdict.reason / stats keys)
PUNT_NO_MATCH = "no-match"
PUNT_RULE = "rule-punt"
PUNT_RATE_LIMITED = "rate-limited"
PUNT_REWRITE_OVERFLOW = "rewrite-overflow"
PUNT_REWRITE_CRYPTO = "rewrite-crypto"
PUNT_MALFORMED = "malformed"
PUNT_BAD_BACKEND = "bad-backend"
PUNT_UNHEALTHY = "unhealthy"

#: HealthTable backend states
HEALTHY, UNHEALTHY, HALF_OPEN = range(3)

#: condition-offset encoding shared with the device plane
#: (repro.kernels.selective_copy.PAD_COND / PAYLOAD_COND_BASE): ``-1`` is
#: the dense-array padding slot; ``offset <= -2`` encodes first-anchored-
#: page position ``-offset - 2``
PAD_COND = -1
PAYLOAD_COND_BASE = -2


class HealthTable:
    """Per-backend health consulted by the match pass as a dense column.

    Classic circuit-breaker shape on the channel's backend index space
    (``dsts[k]``): ``fail_threshold`` *consecutive* failures trip a backend
    to UNHEALTHY; after ``probe_after`` ticks it goes HALF_OPEN (traffic
    allowed again — the probe); the first success closes the circuit
    (HEALTHY), the first failure re-trips it. All transitions are driven by
    the deterministic stack tick, never the wall clock.

    The data-plane view is :meth:`column` — ``[n_backends]`` int32, 1 where
    traffic may flow (HEALTHY or HALF_OPEN) — which
    :meth:`PolicyTable.rule_live` folds into the per-rule live mask the
    vectorized match consumes. Backend indices outside the table are
    treated as healthy (unknown backends are the PUNT path's problem, not
    the breaker's)."""

    def __init__(self, n_backends: int, *, fail_threshold: int = 3,
                 probe_after: int = 8):
        assert n_backends >= 1 and fail_threshold >= 1 and probe_after >= 1
        self.n_backends = n_backends
        self.fail_threshold = fail_threshold
        self.probe_after = probe_after
        self.state = np.zeros(n_backends, np.int32)       # HEALTHY
        self.fails = np.zeros(n_backends, np.int64)       # consecutive
        self.probe_at = np.full(n_backends, -1, np.int64)
        self.stats = {"trips": 0, "recoveries": 0, "probes": 0,
                      "failures": 0, "successes": 0}
        # one HealthTable is shared by every worker's PolicyTable clone
        # (PolicyTable.clone keeps the health reference): self-locking,
        # per the repro.core.sync discipline
        self.lock = ClusterLock("health")

    def _in_range(self, k: int) -> bool:
        return 0 <= k < self.n_backends

    def healthy(self, k: int) -> bool:
        """May traffic flow to backend ``k``? (HEALTHY or HALF_OPEN.)"""
        return not self._in_range(k) or int(self.state[k]) != UNHEALTHY

    def column(self) -> np.ndarray:
        """Dense [n_backends] int32 health column (1 = traffic allowed)."""
        return (self.state != UNHEALTHY).astype(np.int32)

    def note_failure(self, k: int, now: int) -> None:
        """One failed send to ``k`` at tick ``now``. HALF_OPEN re-trips
        immediately; HEALTHY trips at ``fail_threshold`` consecutive."""
        if not self._in_range(k):
            return
        with self.lock:
            self.stats["failures"] += 1
            self.fails[k] += 1
            st = int(self.state[k])
            if st == UNHEALTHY:
                return
            if st == HALF_OPEN or self.fails[k] >= self.fail_threshold:
                self.state[k] = UNHEALTHY
                self.probe_at[k] = now + self.probe_after
                self.stats["trips"] += 1

    def note_success(self, k: int) -> None:
        """One completed send to ``k`` — closes the circuit."""
        if not self._in_range(k):
            return
        with self.lock:
            self.stats["successes"] += 1
            self.fails[k] = 0
            if int(self.state[k]) != HEALTHY:
                self.state[k] = HEALTHY
                self.probe_at[k] = -1
                self.stats["recoveries"] += 1

    def tick(self, now: int) -> None:
        """Advance probe deadlines: UNHEALTHY backends whose deadline
        passed go HALF_OPEN (one probe's worth of traffic re-admitted)."""
        with self.lock:
            due = (self.state == UNHEALTHY) & (self.probe_at >= 0) \
                & (self.probe_at <= now)
            n = int(due.sum())
            if n:
                self.state[due] = HALF_OPEN
                self.probe_at[due] = -1
                self.stats["probes"] += n

    def mark_down(self, k: int, now: int = 0) -> None:
        """Administratively trip ``k`` (fault injection / known-dead)."""
        if not self._in_range(k):
            return
        with self.lock:
            self.state[k] = UNHEALTHY
            self.fails[k] = max(int(self.fails[k]), self.fail_threshold)
            self.probe_at[k] = now + self.probe_after
            self.stats["trips"] += 1

    def mark_up(self, k: int) -> None:
        """Administratively close ``k``'s circuit."""
        self.note_success(k)

    def summary(self) -> Dict[str, object]:
        out = dict(self.stats)
        out["state"] = self.state.tolist()
        return out


@dataclasses.dataclass(frozen=True)
class MatchCond:
    """``lo <= meta[offset] <= hi`` (and ``offset < meta_len``) for
    ``offset >= 0``; ``offset <= -2`` matches first-anchored-page position
    ``-offset - 2`` instead (see :func:`payload_at`)."""
    offset: int
    lo: int
    hi: int

    def __post_init__(self):
        assert self.offset != PAD_COND, \
            "-1 is the dense padding slot, not a condition offset"
        assert self.lo <= self.hi, (self.lo, self.hi)

    @property
    def payload_pos(self) -> int:
        """Payload position for a payload-prefix condition, ``-1`` for a
        metadata condition."""
        return PAYLOAD_COND_BASE - self.offset if self.offset < 0 else -1


def eq(offset: int, value: int) -> MatchCond:
    """Equality matcher on one metadata token."""
    return MatchCond(offset, value, value)


def between(offset: int, lo: int, hi: int) -> MatchCond:
    """Inclusive byte-range matcher on one metadata token."""
    return MatchCond(offset, lo, hi)


def prefix(*values: int) -> Tuple[MatchCond, ...]:
    """Header-prefix matcher: tokens 0..n-1 must equal ``values``."""
    return tuple(eq(i, v) for i, v in enumerate(values))


def payload_at(pos: int, lo: int, hi: int) -> MatchCond:
    """Inclusive byte-range matcher on *payload* position ``pos`` of the
    message's first anchored page (plaintext). Only positions inside the
    first page can match — the window the data plane has in registers."""
    assert pos >= 0, pos
    return MatchCond(PAYLOAD_COND_BASE - pos, lo, hi)


def payload_prefix(*values: int) -> Tuple[MatchCond, ...]:
    """Payload-prefix matcher: payload tokens 0..n-1 must equal
    ``values`` (the L7 'first bytes of the body' classifier)."""
    return tuple(payload_at(i, v, v) for i, v in enumerate(values))


@dataclasses.dataclass(frozen=True)
class Action:
    kind: int
    backend: int = 0          # FORWARD / REWRITE / RATE_LIMIT target
    slot: int = 0             # REWRITE metadata position
    value: int = 0            # REWRITE replacement token
    rate_millis: int = 0      # RATE_LIMIT refill (milli-tokens / tick)
    burst_millis: int = 0     # RATE_LIMIT bucket capacity (milli-tokens)
    key_offset: int = -1      # RATE_LIMIT bucket key meta[offset]; -1 = rule
    failover: int = -1        # FORWARD fallback backend when primary is down


def forward(backend: int = 0, failover: int = -1) -> Action:
    """Route to ``backend``; if a :class:`HealthTable` says it is down,
    re-verdict in-plane to ``failover`` (``-1`` = none: the rule goes
    non-live instead and the match falls through to later rules)."""
    return Action(ACT_FORWARD, backend=backend, failover=failover)


def rewrite(slot: int, value: int, backend: int = 0) -> Action:
    return Action(ACT_REWRITE, backend=backend, slot=slot, value=value)


def rate_limit(rate: float, burst: float = 1.0, *, backend: int = 0,
               per: int = -1) -> Action:
    """``rate`` tokens/tick refill, ``burst`` capacity (both rounded to
    milli-tokens); ``per`` keys the bucket on ``meta[per]`` (per-tenant)."""
    return Action(ACT_RATE_LIMIT, backend=backend,
                  rate_millis=int(round(rate * _MILLI)),
                  burst_millis=int(round(burst * _MILLI)), key_offset=per)


def drop() -> Action:
    return Action(ACT_DROP)


def punt() -> Action:
    return Action(ACT_PUNT)


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    conds: Tuple[MatchCond, ...]
    action: Action
    name: str = dataclasses.field(default="", compare=False)


def rule(action: Action, *conds, name: str = "") -> PolicyRule:
    """Build a rule; conds may be :class:`MatchCond` or tuples of them
    (so :func:`prefix` splices in directly)."""
    flat: List[MatchCond] = []
    for c in conds:
        flat.extend(c if isinstance(c, (tuple, list)) else (c,))
    return PolicyRule(tuple(flat), action, name=name)


@dataclasses.dataclass
class Verdict:
    """One message's resolved policy outcome."""
    kind: str                 # 'forward' | 'drop' | 'punt'
    backend: int = 0
    rule: int = -1            # matched row (R = no match)
    reason: str = ""          # punt reason
    rewrites: Tuple[Tuple[int, int], ...] = ()
    epoch: int = 0            # table epoch the verdict was resolved under
    failover: bool = False    # True iff re-verdicted to the failover backend


class PolicyTable:
    """Ordered policy rules compiled to dense int32 arrays.

    The dense form is ``(cond_off, cond_lo, cond_hi)`` — each ``[R, K]``
    int32, ``-1`` offsets padding always-true slots — plus the action
    columns ``(act_kind, act_a, act_b, act_c, act_d)`` (each ``[R]``
    int32). :meth:`decode` reconstructs the source rows from the dense
    arrays alone (rule names excepted), so compilation is lossless —
    the property tests round-trip it.

    ``health`` (a :class:`HealthTable`, optional) makes backend liveness a
    data-plane input: :meth:`rule_live` folds it into a per-rule int32
    mask that the match pass consumes, and FORWARD rules with a
    ``failover`` re-verdict to it host-side. :meth:`swap` replaces the
    rule set under live traffic: the dense arrays are recompiled in place
    and :attr:`epoch` bumps — verdicts stamp the epoch they were resolved
    under, and in-flight messages keep their already-resolved verdicts
    (resolution is eager at match time), so a swap never re-routes a
    message mid-round.
    """

    def __init__(self, rules: Sequence[PolicyRule],
                 health: Optional[HealthTable] = None):
        self.health = health
        self.epoch = 0
        # token buckets: (rule, key) -> [milli-tokens, last refill tick]
        self._buckets: Dict[Tuple[int, int], List[int]] = {}
        self.stats: Dict[str, object] = {
            "rounds": 0, "matched": 0, "no_match": 0, "forwards": 0,
            "drops": 0, "punts": 0, "rate_debits": 0, "failovers": 0,
            "swaps": 0, "rule_hits": [],
            "punts_by_reason": {},
        }
        self._compile(rules)

    def _compile(self, rules: Sequence[PolicyRule]) -> None:
        """(Re)build the dense arrays from ``rules`` — the one copy of the
        compiler shared by ``__init__`` and :meth:`swap`."""
        self.rules: Tuple[PolicyRule, ...] = tuple(rules)
        assert self.rules, "a PolicyTable needs at least one rule"
        r = len(self.rules)
        k = max(max((len(ru.conds) for ru in self.rules), default=1), 1)
        self.cond_off = np.full((r, k), -1, np.int32)
        self.cond_lo = np.zeros((r, k), np.int32)
        self.cond_hi = np.zeros((r, k), np.int32)
        acts = np.zeros((5, r), np.int32)   # kind, a, b, c, d
        for i, ru in enumerate(self.rules):
            for j, c in enumerate(ru.conds):
                for v in (c.offset, c.lo, c.hi):
                    assert -(1 << 31) <= v < (1 << 31), \
                        "conditions must fit the int32 device plane"
                self.cond_off[i, j] = c.offset
                self.cond_lo[i, j] = c.lo
                self.cond_hi[i, j] = c.hi
            a = ru.action
            acts[0, i] = a.kind
            if a.kind == ACT_FORWARD:
                acts[1, i] = a.backend
                acts[2, i] = a.failover
            if a.kind == ACT_REWRITE:
                acts[1, i] = a.backend
                acts[2, i] = a.slot
                acts[3, i] = a.value
            if a.kind == ACT_RATE_LIMIT:
                acts[1, i] = a.backend
                acts[2, i] = a.rate_millis
                acts[3, i] = a.burst_millis
                acts[4, i] = a.key_offset
        (self.act_kind, self.act_a, self.act_b,
         self.act_c, self.act_d) = acts
        self.stats["rule_hits"] = [0] * r

    def swap(self, rules: Sequence[PolicyRule]) -> int:
        """Hot-swap the rule set under live traffic: recompile the dense
        arrays in place, reset the token buckets (bucket rows are keyed by
        row index, which the swap renumbers), and bump :attr:`epoch`.
        Health state survives (it describes backends, not rules). Returns
        the new epoch. In-flight messages — already matched and resolved —
        keep their old-epoch verdicts; only rounds matched *after* the
        swap see the new table."""
        self._compile(rules)
        self._buckets.clear()
        self.epoch += 1
        self.stats["swaps"] += 1
        return self.epoch

    @property
    def n_rules(self) -> int:
        return len(self.rules)

    @property
    def has_payload_conds(self) -> bool:
        """True iff any rule peeks the payload — callers only build (and
        ship) first-page windows when this is set, so metadata-only tables
        keep their exact pre-payload operand shapes."""
        return bool((self.cond_off <= PAYLOAD_COND_BASE).any())

    def clone(self) -> "PolicyTable":
        """Same rules, fresh buckets/stats (per-worker tables). The
        :class:`HealthTable` instance is SHARED — backend health is a
        cluster-wide fact, not per-worker state."""
        return PolicyTable(self.rules, health=self.health)

    # -- dense form --------------------------------------------------------
    def dense(self) -> Tuple[np.ndarray, ...]:
        return (self.cond_off, self.cond_lo, self.cond_hi, self.act_kind,
                self.act_a, self.act_b, self.act_c, self.act_d)

    @classmethod
    def decode(cls, cond_off, cond_lo, cond_hi, act_kind, act_a, act_b,
               act_c, act_d) -> "PolicyTable":
        """Rebuild the source rows from the dense arrays (names lost)."""
        rules = []
        for i in range(len(act_kind)):
            conds = tuple(
                MatchCond(int(cond_off[i, j]), int(cond_lo[i, j]),
                          int(cond_hi[i, j]))
                for j in range(cond_off.shape[1]) if cond_off[i, j] != -1)
            kind = int(act_kind[i])
            if kind == ACT_FORWARD:
                a = Action(kind, backend=int(act_a[i]),
                           failover=int(act_b[i]))
            elif kind == ACT_REWRITE:
                a = Action(kind, backend=int(act_a[i]), slot=int(act_b[i]),
                           value=int(act_c[i]))
            elif kind == ACT_RATE_LIMIT:
                a = Action(kind, backend=int(act_a[i]),
                           rate_millis=int(act_b[i]),
                           burst_millis=int(act_c[i]),
                           key_offset=int(act_d[i]))
            else:
                a = Action(kind)
            rules.append(PolicyRule(conds, a))
        return cls(rules)

    # -- matching ----------------------------------------------------------
    def rule_live(self) -> Optional[np.ndarray]:
        """Per-rule liveness column for the match pass: ``[R]`` int32,
        ``0`` for a routing rule (FORWARD/REWRITE/RATE_LIMIT) whose primary
        backend is down with no healthy failover — such a rule is skipped
        by the match so priority falls through to the next rule (or the
        PUNT tail). Returns ``None`` when every rule is live (no health
        table, or nothing tripped) so the kernel paths stay operand-free
        on the fault-free fast path."""
        h = self.health
        if h is None:
            return None
        col = h.column()
        nb = h.n_backends

        def _ok(idx: np.ndarray) -> np.ndarray:
            out_of = (idx < 0) | (idx >= nb)
            return out_of | (col[np.clip(idx, 0, nb - 1)] > 0)

        routing = np.isin(self.act_kind,
                          (ACT_FORWARD, ACT_REWRITE, ACT_RATE_LIMIT))
        primary_ok = _ok(self.act_a)
        fo = np.where(self.act_kind == ACT_FORWARD, self.act_b, -1)
        failover_ok = (fo >= 0) & _ok(fo)
        live = (~routing) | primary_ok | failover_ok
        if live.all():
            return None
        return live.astype(np.int32)

    def interpret(self, meta: np.ndarray, meta_len: int,
                  live: Optional[np.ndarray] = None,
                  payload: Optional[np.ndarray] = None,
                  payload_len: int = 0) -> int:
        """Naive Python interpreter of the rows — the oracle the vectorized
        pass (and the kernel) must agree with. Returns the first matching
        row, or ``n_rules``. ``live`` (the :meth:`rule_live` column) skips
        dead rows exactly as the vectorized paths do. ``payload`` is the
        plaintext first-page window (payload-prefix conditions never hold
        without one)."""
        for i, ru in enumerate(self.rules):
            if live is not None and not live[i]:
                continue
            if all(self._cond_holds(c, meta, meta_len, payload, payload_len)
                   for c in ru.conds):
                return i
        return self.n_rules

    @staticmethod
    def _cond_holds(c: MatchCond, meta, meta_len: int, payload,
                    payload_len: int) -> bool:
        if c.offset >= 0:
            return c.offset < meta_len and c.lo <= int(meta[c.offset]) <= c.hi
        pos = c.payload_pos
        return (payload is not None and pos < payload_len
                and pos < len(payload)
                and c.lo <= int(payload[pos]) <= c.hi)

    def match_rows(self, metas: np.ndarray, meta_lens: np.ndarray,
                   keystreams: Optional[np.ndarray] = None,
                   live: Optional[np.ndarray] = None,
                   payload: Optional[np.ndarray] = None,
                   payload_lens: Optional[np.ndarray] = None) -> np.ndarray:
        """Vectorized numpy first-match over a round: ``metas`` [B, M]
        (int64-exact host truth), ``meta_lens`` [B] → [B] row indices.
        ``keystreams`` (same shape, 0 where plaintext) is XORed in first —
        matching against *decrypted* metadata without a separate pass.
        ``live`` ([R] int32) masks out rules whose backends are down.
        ``payload`` ([B, W] plaintext first-page windows, with
        ``payload_lens``) serves payload-prefix conditions."""
        m = metas if keystreams is None else np.bitwise_xor(
            metas, keystreams.astype(metas.dtype))
        mm = m.shape[1]
        off = self.cond_off.astype(np.int64)                 # [R, K]
        vals = m[:, np.clip(off, 0, mm - 1)]                 # [B, R, K]
        pad = off == PAD_COND
        present = (off >= 0) & (off < meta_lens[:, None, None]) & (off < mm)
        ok = pad[None] | (present & (vals >= self.cond_lo) &
                          (vals <= self.cond_hi))
        if payload is not None:
            w = payload.shape[1]
            ppos = PAYLOAD_COND_BASE - off                   # [R, K]
            pvals = payload[:, np.clip(ppos, 0, w - 1)]      # [B, R, K]
            pay_ok = (off <= PAYLOAD_COND_BASE)[None] \
                & (ppos[None] < payload_lens[:, None, None]) \
                & (ppos < w)[None] \
                & (pvals >= self.cond_lo) & (pvals <= self.cond_hi)
            ok = ok | pay_ok
        rule_ok = ok.all(axis=2)                             # [B, R]
        if live is not None:
            rule_ok &= live[None, :] > 0
        return np.where(rule_ok.any(axis=1), rule_ok.argmax(axis=1),
                        self.n_rules).astype(np.int32)

    def match_batch(self, metas: np.ndarray, meta_lens: np.ndarray, *,
                    keystreams: Optional[np.ndarray] = None,
                    impl: str = "host",
                    payload: Optional[np.ndarray] = None,
                    payload_lens: Optional[np.ndarray] = None) -> np.ndarray:
        """One vectorized match pass for a whole batched round.
        ``impl='host'`` is the int64-exact numpy path; anything else goes
        through :func:`repro.kernels.ops.policy_match` (the fused kernel /
        its jnp oracle) on the int32 device plane — rounds whose tokens do
        not survive int32 bounce back to the numpy path (the same rule as
        the anchoring pass). The :meth:`rule_live` health column rides
        along as an extra dense operand on every path, as does the
        plaintext first-page ``payload`` window when the table has
        payload-prefix conditions."""
        self.stats["rounds"] += 1
        live = self.rule_live()
        if impl != "host":
            vals = [int(metas.min(initial=0)), int(metas.max(initial=0))]
            if payload is not None and payload.size:
                vals += [int(payload.min()), int(payload.max())]
            if -(1 << 31) <= min(vals) and max(vals) < (1 << 31):
                from repro.kernels import ops

                ks = (None if keystreams is None
                      else np.asarray(keystreams, np.int32))
                pw = (None if payload is None
                      else np.asarray(payload, np.int32))
                pln = (None if payload_lens is None
                       else np.asarray(payload_lens, np.int32))
                rids = ops.policy_match(
                    np.asarray(metas, np.int32),
                    np.asarray(meta_lens, np.int32),
                    self.cond_off, self.cond_lo, self.cond_hi,
                    impl=impl, keystream=ks, live=live,
                    payload=pw, payload_len=pln)
                return np.asarray(rids, np.int32)
        return self.match_rows(metas, meta_lens, keystreams, live,
                               payload=payload, payload_lens=payload_lens)

    # -- action resolution (host-side, stateful) ---------------------------
    def _bucket_debit(self, row: int, key: int, now: int) -> bool:
        """Token bucket for RATE_LIMIT rows: refill by rate·Δtick (capped
        at burst), then try to debit one token. Milli-token integer math —
        deterministic for identical (trace, tick) schedules."""
        b = self._buckets.get((row, key))
        if b is None:
            b = [int(self.act_c[row]), now]    # start full
            self._buckets[(row, key)] = b
        tokens, last = b
        tokens = min(int(self.act_c[row]),
                     tokens + (now - last) * int(self.act_b[row]))
        if tokens >= _MILLI:
            b[0], b[1] = tokens - _MILLI, now
            return True
        b[0], b[1] = tokens, now
        return False

    def failover_for(self, rid: int) -> int:
        """The failover backend of FORWARD row ``rid`` (``-1`` if none /
        not a FORWARD row) — consulted by held-send retries without
        re-running :meth:`decide` (which would double-debit buckets)."""
        if 0 <= rid < self.n_rules and int(self.act_kind[rid]) == ACT_FORWARD:
            return int(self.act_b[rid])
        return -1

    def _resolve_one(self, rid: int, meta: np.ndarray, meta_len: int,
                     crypto: bool, now: int, counters=None) -> Verdict:
        v = self._resolve_inner(rid, meta, meta_len, crypto, now, counters)
        v.epoch = self.epoch
        return v

    def _resolve_inner(self, rid: int, meta: np.ndarray, meta_len: int,
                       crypto: bool, now: int, counters=None) -> Verdict:
        st = self.stats
        if rid >= self.n_rules:
            st["no_match"] += 1
            return Verdict("punt", rule=self.n_rules, reason=PUNT_NO_MATCH)
        st["matched"] += 1
        st["rule_hits"][rid] += 1
        kind = int(self.act_kind[rid])
        if kind == ACT_FORWARD:
            backend = int(self.act_a[rid])
            if self.health is not None and not self.health.healthy(backend):
                fo = int(self.act_b[rid])
                if fo >= 0 and self.health.healthy(fo):
                    st["failovers"] += 1
                    if counters is not None:
                        counters.policy_failovers += 1
                    return Verdict("forward", backend=fo, rule=rid,
                                   failover=True)
                # matched before the trip landed (or raced the live mask):
                # nothing healthy to route to — the slow path decides
                return Verdict("punt", rule=rid, reason=PUNT_UNHEALTHY)
            return Verdict("forward", backend=backend, rule=rid)
        if kind == ACT_REWRITE:
            slot = int(self.act_b[rid])
            if crypto:
                # patching sealed metadata would break the record's auth
                # tag downstream — only the slow path may re-frame it
                return Verdict("punt", rule=rid, reason=PUNT_REWRITE_CRYPTO)
            if slot >= meta_len:
                return Verdict("punt", rule=rid,
                               reason=PUNT_REWRITE_OVERFLOW)
            return Verdict("forward", backend=int(self.act_a[rid]), rule=rid,
                           rewrites=((slot, int(self.act_c[rid])),))
        if kind == ACT_RATE_LIMIT:
            key_off = int(self.act_d[rid])
            key = int(meta[key_off]) if 0 <= key_off < meta_len else -1
            if self._bucket_debit(rid, key, now):
                st["rate_debits"] += 1
                if counters is not None:
                    counters.policy_rate_debits += 1
                return Verdict("forward", backend=int(self.act_a[rid]),
                               rule=rid)
            return Verdict("punt", rule=rid, reason=PUNT_RATE_LIMITED)
        if kind == ACT_DROP:
            return Verdict("drop", rule=rid)
        return Verdict("punt", rule=rid, reason=PUNT_RULE)

    def resolve(self, rids: np.ndarray, metas: np.ndarray,
                meta_lens: np.ndarray, *, crypto: Sequence[bool],
                now: int, counters=None) -> List[Verdict]:
        """Resolve a round's matched rows to verdicts, in round order
        (token-bucket debits are sequential, mirroring the scalar
        schedule). ``metas`` must be the *plaintext* metadata."""
        return [self._resolve_one(int(rid), metas[i], int(meta_lens[i]),
                                  bool(crypto[i]), now, counters)
                for i, rid in enumerate(rids)]

    def decide(self, buf: np.ndarray, *, parser, crypto: bool = False,
               now: int = 0, counters=None,
               payload: Optional[np.ndarray] = None,
               payload_len: int = 0) -> Verdict:
        """Scalar-path verdict for one delivered message (``[meta...,
        VPI]`` or a full copy): parse for the metadata boundary, run the
        naive interpreter, resolve. Unparseable frames PUNT
        (``malformed``). ``payload``/``payload_len`` is the plaintext
        first-page window for payload-prefix conditions (callers peek it
        only when :attr:`has_payload_conds`)."""
        buf = np.asarray(buf)
        res = parser.parse(buf)
        if not res.ok or res.meta_len > len(buf):
            self.stats["rounds"] += 1
            return Verdict("punt", rule=self.n_rules, reason=PUNT_MALFORMED,
                           epoch=self.epoch)
        self.stats["rounds"] += 1
        rid = self.interpret(buf, res.meta_len, self.rule_live(),
                             payload=payload, payload_len=payload_len)
        return self._resolve_one(rid, buf, res.meta_len, crypto, now,
                                 counters)

    # -- verdict accounting (apply side) -----------------------------------
    def note_outcome(self, verdict: Verdict) -> None:
        """Count the outcome a channel actually applied (forwards vs punts
        may diverge from resolution when e.g. the backend index is out of
        range for the channel)."""
        st = self.stats
        if verdict.kind == "forward":
            st["forwards"] += 1
        elif verdict.kind == "drop":
            st["drops"] += 1
        else:
            st["punts"] += 1
            by = st["punts_by_reason"]
            by[verdict.reason] = by.get(verdict.reason, 0) + 1

    def summary(self) -> Dict[str, object]:
        """JSON-friendly telemetry snapshot."""
        out = dict(self.stats)
        out["rule_hits"] = list(self.stats["rule_hits"])
        out["punts_by_reason"] = dict(self.stats["punts_by_reason"])
        out["buckets"] = len(self._buckets)
        out["epoch"] = self.epoch
        if self.health is not None:
            out["health"] = self.health.summary()
        return out


class PythonPolicyRouter:
    """The per-channel Python slow path the offload bypasses, as a
    baseline: the SAME :class:`PolicyTable` rules evaluated message-by-
    message by the naive interpreter, exposed through the classic
    ``rewrite``/``router`` callback slots of :class:`ProxyChannel`.

    Wire it as ``ProxyChannel(..., rewrite=r.rewrite, router=r.router)``
    (``rewrite`` runs first and caches the verdict the immediately
    following ``router`` call consumes — the channel calls them back to
    back per message). A DROP verdict returns ``None`` from ``router``,
    which the channel treats as "consume and free" — the same
    :meth:`LibraStack.drop_message` path the offloaded verdict takes. Byte
    and Fig. 9 counter streams are identical to the offloaded table on the
    same trace; only the policy_* event counters (which the baseline does
    not touch) differ.
    """

    def __init__(self, table: PolicyTable, dsts: Sequence, *, parser,
                 crypto: bool = False, stack=None, src=None,
                 punt_router=None, punt_rewrite=None):
        self.table = table
        self.dsts = list(dsts)
        self.parser = parser
        self.crypto = crypto
        self.stack = stack
        # the channel's source socket — needed (with ``stack``) to peek the
        # anchored first-page window when the table has payload conditions
        self.src = src
        self.punt_router = punt_router
        self.punt_rewrite = punt_rewrite
        self._verdict: Optional[Verdict] = None

    def _now(self) -> int:
        return self.stack.now_tick if self.stack is not None else 0

    def rewrite(self, buf: np.ndarray, logical: int) -> np.ndarray:
        payload, plen = None, 0
        if self.table.has_payload_conds and self.stack is not None \
                and self.src is not None:
            payload, plen = self.stack._policy_window(buf, self.src)
        v = self.table.decide(buf, parser=self.parser, crypto=self.crypto,
                              now=self._now(), payload=payload,
                              payload_len=plen)
        if v.kind == "forward" and v.backend >= len(self.dsts):
            v = Verdict("punt", rule=v.rule, reason=PUNT_BAD_BACKEND)
        self._verdict = v
        if v.kind == "forward" and v.rewrites:
            out = np.array(buf)
            for slot, value in v.rewrites:
                out[slot] = value
            return out
        if v.kind == "punt" and self.punt_rewrite is not None:
            return self.punt_rewrite(buf, logical)
        return buf

    def router(self, buf: np.ndarray, logical: int):
        v, self._verdict = self._verdict, None
        assert v is not None, "router called without a preceding rewrite"
        self.table.note_outcome(v)
        if v.kind == "forward":
            return self.dsts[v.backend]
        if v.kind == "drop":
            return None
        if self.punt_router is not None:
            return self.punt_router(buf, logical)
        return self.dsts[0]
