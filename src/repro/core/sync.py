"""Cluster-plane synchronization primitives.

The single-process repro is cooperatively scheduled today, but the
ROADMAP's worker-per-thread executor makes every object reachable from two
workers a data race: grant pins and freelists (``AnchorPool``), the grant
tables (``VpiRegistry``), steering placements (``SteeringPolicy``) and the
shared circuit-breaker (``HealthTable``). This module provides the locks
that discipline those objects *now*, so the lockset checker
(:mod:`repro.analysis.lockset`) can statically verify every cross-worker
mutation site is guarded before any thread ever exists:

* :class:`ClusterLock` — a reentrant lock that additionally exposes
  :attr:`~ClusterLock.held` (is the *current thread* inside it?), which is
  what the test-time ``LocksetMonitor`` interrogates at each mutation.
* :func:`plane_lock` — the lock guarding an object's cluster plane, or a
  shared no-op when the object is single-stack (no ``.lock`` attached):
  the scalar datapath pays one ``getattr`` and nothing else.

Locking discipline (coarse by design — one plane lock per cluster, taken
around whole cross-worker operations; fine-graining is follow-up work once
the executor lands):

1. ``LibraCluster`` owns one :class:`ClusterLock` and attaches it to every
   worker's ``alloc`` and ``registry``.
2. Cross-worker operations (``grant_into``, grant completion in
   ``libra_send``, policy-DROP of a grant, ``reclaim_abandoned_grants``,
   ``kill_worker``) hold the plane lock end to end.
3. ``SteeringPolicy`` and ``HealthTable`` are self-locking: their mutators
   take their own per-object lock internally (they are shared through
   ``PolicyTable.clone()`` across every worker's table).
4. Lock order (statically enforced by the DEAD pass of
   :mod:`repro.analysis.concurrency` against the committed
   ``lock_hierarchy_manifest.json``) — acquisition must follow strictly
   increasing rank:

   ====================  ====  ===================================
   lock class            rank  acquired as
   ====================  ====  ===================================
   plane                 0     ``with cluster.lock`` / ``*_locked``
   registry              1     ``with plane_lock(<registry>)``
   alloc                 2     ``with plane_lock(<pool>.alloc)``
   steering / health     3     ``with self.lock`` (leaf, self-locking)
   ====================  ====  ===================================

   Same-class re-acquisition is always fine (``ClusterLock`` is
   reentrant, and in a cluster the plane/registry/alloc classes are
   today the *same* lock object — the ranking is the contract that
   keeps a future per-island fine-graining deadlock-free). Leaves never
   nest with each other.
"""
from __future__ import annotations

import threading


class ClusterLock:
    """Reentrant lock with an observable held-by-this-thread state.

    ``threading.RLock`` cannot be asked "does the current thread hold
    you?" — the lockset instrumentation needs exactly that question, so
    this wrapper tracks the owning thread id and the reentry depth itself.
    """

    __slots__ = ("name", "_lock", "_owner", "_depth", "acquires")

    def __init__(self, name: str = "cluster-plane"):
        self.name = name
        self._lock = threading.RLock()
        self._owner: int | None = None
        self._depth = 0
        self.acquires = 0

    def acquire(self) -> None:
        self._lock.acquire()
        self._owner = threading.get_ident()
        self._depth += 1
        self.acquires += 1

    def release(self) -> None:
        assert self._depth > 0 and self._owner == threading.get_ident(), \
            f"{self.name}: release without matching acquire"
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
        self._lock.release()

    @property
    def held(self) -> bool:
        """True iff the *current thread* is inside this lock."""
        return self._depth > 0 and self._owner == threading.get_ident()

    def __enter__(self) -> "ClusterLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ClusterLock({self.name!r}, depth={self._depth})"


class _NullLock:
    """No-op stand-in for single-stack objects (no cluster, no sharing)."""

    held = False

    def __enter__(self) -> "_NullLock":
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_LOCK = _NullLock()


def plane_lock(obj) -> object:
    """The cluster-plane lock attached to ``obj`` (by ``LibraCluster``),
    or the shared no-op lock for single-stack objects."""
    lock = getattr(obj, "lock", None)
    return NULL_LOCK if lock is None else lock
