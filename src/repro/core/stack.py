"""``LibraStack`` — one Libra "kernel" instance.

The stack owns everything the paper's kernel half owns, so that socket
call-sites carry zero plumbing:

* the anchored payload pool (:class:`AnchorPool` allocator +
  :class:`TokenPool` payload store — the kernel-retained skb pages),
* the global ``<VPI, payload>`` map (:class:`VpiRegistry`),
* the parser-policy registry (named eBPF RX/TX-Prog analogues),
* a monotonic tick clock driving §A.4 deferred-teardown expiry,
* the global :class:`CopyCounters` telemetry block (paper Fig. 9).

Sockets are created with :meth:`socket` / :meth:`socket_pair`; a single
stack multiplexes any number of connections with heterogeneous parser
policies (see :mod:`repro.core.runtime` for the event loop on top).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.anchor_pool import AnchorPool, PageRef
from repro.core.crypto import (
    REC_HEADER,
    TAG_SLOT,
    CryptoRecordParser,
    keystream_batch,
)
from repro.core.device_pool import DevicePool, DeviceRangeError
from repro.core.egress import expire_teardowns
from repro.core.ingress import reset_rx_from_tx
from repro.core.parser import BUILTIN_PARSERS, LengthPrefixedParser, ParserPolicy
from repro.core.socket import Events, LibraSocket
from repro.core.state_machine import MIN_PAYLOAD, St
from repro.core.stream import Connection, CopyCounters, TokenPool
from repro.core.sync import plane_lock
from repro.core.vpi import VpiRegistry

ParserLike = Union[str, ParserPolicy]

#: forward_batch outcome tags
SEND_OK = "ok"
SEND_EAGAIN = "eagain"

#: below this many rows a batched gather skips the device plane: the
#: per-launch overhead exceeds the copy cost of a handful of pages, and
#: the host gather reads the same bytes (device-truth rows materialize
#: row-wise). This is what keeps the fused round's rare speculation-miss
#: gathers (typically 1-3 rows) from costing a full extra launch.
_SMALL_GATHER_ROWS = 4


@dataclasses.dataclass
class _BatchItem:
    """One admissible message in a batched recv round."""
    sock: LibraSocket
    buf_len: int
    meta_len: int
    payload_len: int
    pages: List[PageRef]
    meta: np.ndarray = None
    payload: np.ndarray = None   # zero-copy rx window (valid until advance)
    ks: np.ndarray = None        # hw-kTLS RX keystream (fused into the scatter)
    plain: np.ndarray = None     # payload plaintext the auth sweep produced
    # policy-offload operands (captured only when a policy rides the round):
    # the pre-decrypt inner metadata and its keystream span, so the device
    # match pass can run on ciphertext + keystream exactly like the kernel's
    # other crypto operands (host rounds match the plaintext directly)
    cmeta: np.ndarray = None
    meta_ks: np.ndarray = None
    # one-kernel round speculation: the forward-time cache descriptor the
    # fused gather output lands in (parked on the socket after the VPI is
    # registered; forward_batch validates the guess before consuming it)
    fused_tx: dict = None


def _fits_int32(a: np.ndarray) -> bool:
    """True when every token survives the int32 device stream round-trip."""
    return len(a) == 0 or (int(a.min()) >= -(1 << 31)
                           and int(a.max()) < (1 << 31))


def _fused_base(impl: str) -> Optional[str]:
    """The device impl underlying a fused-round dispatch string:
    ``'fused-round'`` -> ``'auto'``, ``'fused-round:ref'`` -> ``'ref'``
    (same for ``:interpret``/``:pallas``); ``None`` for a non-fused impl.
    The base impl also serves ineligible/bounced rounds through the
    classic three-launch path."""
    if impl == "fused-round":
        return "auto"
    if impl.startswith("fused-round:"):
        return impl.split(":", 1)[1]
    return None


class LibraStack:
    """Shared selective-copy state for a set of :class:`LibraSocket`\\ s."""

    def __init__(self, *, n_shards: int = 4, pages_per_shard: int = 64,
                 page_size: int = 16, max_pages_per_seq: int = 0,
                 grace_ticks: int = 5, secret: Optional[bytes] = None,
                 alloc: Optional[AnchorPool] = None,
                 registry: Optional[VpiRegistry] = None,
                 parsers: Optional[Dict[str, type]] = None,
                 device_pool: bool = True):
        self.alloc = alloc or AnchorPool(n_shards, pages_per_shard, page_size,
                                         max_pages_per_seq=max_pages_per_seq)
        # device_pool=True (default): the payload pool stays resident on the
        # device across batched rounds (dirty-row-tracked host mirror for the
        # scalar paths — residency itself is lazy, so host-only workloads pay
        # nothing). device_pool=False keeps the legacy host pool that bounces
        # the whole pool per device-impl round (pool_syncs telemetry).
        self.pool = (DevicePool(self.alloc) if device_pool
                     else TokenPool(self.alloc))
        self.registry = registry or VpiRegistry(secret=secret,
                                                grace_ticks=grace_ticks)
        self.counters = CopyCounters()
        self.parsers: Dict[str, type] = dict(BUILTIN_PARSERS)
        self.parsers.setdefault("crypto-record", CryptoRecordParser)
        if parsers:
            self.parsers.update(parsers)
        self.now_tick = 0
        self.sockets: Dict[int, LibraSocket] = {}
        # vpi -> anchoring socket (the kernel finds this through the global
        # eBPF map; the facade keeps an explicit owner index)
        self._vpi_owner: Dict[int, LibraSocket] = {}
        self._null_conn: Optional[Connection] = None
        # multi-worker awareness (set by repro.core.cluster.LibraCluster):
        # this stack's slot in the cluster, the cluster itself (the VPI
        # interconnect consulted when a transmit meets a handle that does
        # not resolve locally), and the peer workers' pools by pool_id so
        # egress can route cross-worker grant entries to the pool that
        # actually owns their pages. All stay inert for a standalone stack.
        self.worker_id: Optional[int] = None
        self.interconnect = None
        self._peer_pools: Dict[str, Union[TokenPool, DevicePool]] = {}
        # chaos harness: a repro.core.faults.FaultPlan consulted by the
        # socket delivery and channel send paths (None = no faults)
        self.fault_plan = None

    # -- socket lifecycle ----------------------------------------------------
    def make_parser(self, parser: ParserLike, **kw) -> ParserPolicy:
        """Resolve a registered parser name (or pass a policy through)."""
        if isinstance(parser, str):
            return self.parsers[parser](**kw)
        return parser

    def socket(self, parser: ParserLike = "length-prefixed", *,
               min_payload: int = MIN_PAYLOAD,
               send_budget: Optional[int] = None,
               tls: Optional[str] = None) -> LibraSocket:
        """Open a connection on this stack. ``min_payload`` above any real
        message size forces the native full-copy path (a standard-stack
        baseline socket); ``send_budget`` models a bounded send buffer.

        ``tls='sw'|'hw'`` runs the connection through the kTLS-analogue
        record layer: ``parser`` becomes the *inner* protocol and the wire
        carries encrypted records (the given parser is wrapped in a
        :class:`CryptoRecordParser`; session keys derive from the stack's
        registry secret). ``'sw'`` models software kTLS — separate
        decrypt/encrypt-and-copy passes at the RX/TX boundary, no fused
        batching; ``'hw'`` models NIC-inline kTLS — the cipher fused into
        the selective-copy scatter/gather, zero extra passes."""
        pol = self.make_parser(parser)
        if tls is not None and not isinstance(pol, CryptoRecordParser):
            pol = CryptoRecordParser(inner=pol)
        sock = LibraSocket(self, pol, min_payload=min_payload,
                           send_budget=send_budget, tls=tls)
        self.sockets[sock.fileno()] = sock
        return sock

    def socket_pair(self, parser: ParserLike = "length-prefixed",
                    **kw) -> Tuple[LibraSocket, LibraSocket]:
        """A (client-side, backend-side) pair sharing one parser policy —
        the two halves of one proxied flow."""
        return self.socket(parser, **kw), self.socket(parser, **kw)

    def close_all(self) -> int:
        """Close every open socket; returns total anchors deferred."""
        return sum(s.close() for s in list(self.sockets.values()))

    # -- clock ---------------------------------------------------------------
    def tick(self, n: int = 1) -> int:
        """Advance the monotonic clock ``n`` ticks, expiring §A.4 grace
        periods each tick. Returns the number of pages reclaimed."""
        freed = 0
        for _ in range(max(n, 1)):
            self.now_tick += 1
            freed += expire_teardowns(self.pool, self.registry, self.now_tick)
        self._gc_anchor_owners()
        return freed

    def drain(self) -> int:
        """Tick through a full grace period (teardown flush for tests and
        orderly shutdown)."""
        return self.tick(self.registry.grace_ticks + 1)

    # -- telemetry -----------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.alloc.total_pages - self.alloc.free_pages

    def utilization(self) -> float:
        return self.alloc.used_fraction

    @property
    def high_watermark(self) -> float:
        """§A.1 receive-window watermark (fraction of pool pages in use at
        which ingress backpressure engages)."""
        return self.alloc.high_watermark

    @high_watermark.setter
    def high_watermark(self, frac: float) -> None:
        self.alloc.high_watermark = frac

    def above_watermark(self) -> bool:
        """Backpressure signal: the pool is nearly full — pausing selective
        ingress now avoids overflowing into the §A.1 drain path."""
        return self.alloc.above_watermark()

    def poll(self) -> Dict[int, Events]:
        """Stack-wide readiness snapshot (epoll_wait analogue)."""
        return {fd: s.poll() for fd, s in self.sockets.items()}

    # -- batched datapath ----------------------------------------------------
    def recv_batch(
        self,
        socks: Sequence[LibraSocket],
        buf_len: Union[int, Dict[int, int]] = 1 << 20,
        *,
        impl: str = "host",
        policy=None,
        tx_hints: Optional[Dict[int, LibraSocket]] = None,
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Batched instrumented recvmsg (§3.3) across many sockets.

        Gathers every socket whose next frame is admissible to the
        selective path in one shot (RX machine in DEFAULT, parseable frame,
        whole payload resident, room for metadata + VPI in the buffer, pool
        pages available), runs the selective-copy data plane ONCE for the
        whole batch, and scatters the results back through each socket's RX
        state machine — batched data movement, unchanged per-socket control
        flow and counters.

        ``impl='host'`` executes the single-pass placement as one fused
        numpy scatter directly into the pool (allocation-free, exact int64).
        Any other value is forwarded to :func:`repro.kernels.ops.selective_copy`
        (``'auto'``/``'ref'``/``'interpret'``/``'pallas'``): the round is
        flattened into one ``[B, S]`` int32 batch and the fused kernel runs
        over the pool's reserved scratch row. With the default
        :class:`DevicePool` the pool is **resident across rounds** — only
        the round's O(batch) operands cross the host/device boundary and
        nothing syncs back (rows materialize lazily for scalar readers);
        the legacy host pool (``device_pool=False``) pays one whole-pool
        bounce per round (``pool.xfer['pool_syncs']``).

        ``impl='fused-round'`` (or ``'fused-round:ref'`` /
        ``':interpret'`` / ``':pallas'`` to pin the backend) runs the
        whole round as ONE device launch — anchoring, hw-kTLS decrypt, the
        L7 first-match AND the egress gather fused into a single kernel
        against the resident pool (``pool.xfer['fused_rounds']``), instead
        of the three launches the multi-pass path costs. ``tx_hints``
        (src fd -> likely destination socket) lets the fused round
        speculatively TX-encrypt the gather output for hw-kTLS
        destinations; ``forward_batch`` validates each guess and consumes
        the prefetched payload (``pool.xfer['tx_spec_hits']``), falling
        back to its own gather on a miss. Ineligible or bounced rounds
        (host pool, int64-only tokens, non-contiguous pages,
        DeviceRangeError) are served by the classic multi-pass path on the
        underlying impl and counted as ``device_fallbacks``.

        ``policy`` (a :class:`~repro.core.policy.PolicyTable`) fuses the
        L7 routing decision into this same metadata pass: ONE vectorized
        first-match sweep over the round's metadata block resolves every
        message's verdict (token-bucket debits included, in round order)
        and leaves it on ``sock._policy_verdict`` for the runtime to apply
        — matched messages go straight to ``forward_batch`` without the
        per-channel Python routing callbacks. hw-kTLS rows are matched as
        ciphertext + keystream on the device plane (the kernel's fused
        decrypt), plaintext on the host plane — identical verdicts.

        ``buf_len`` is one size for all sockets or a per-fd mapping.
        Returns ``{fd: (buffer, logical_len)}`` for the serviced sockets;
        a socket absent from the result was not batchable this round (mid
        message, drain mode, unparseable/short frame, buffer too small for
        metadata + VPI, pool exhausted, ...) and should fall back to scalar
        ``recv`` — every edge state keeps its §3.3/§A.1 semantics there.
        """
        def _bl(sock: LibraSocket) -> int:
            if isinstance(buf_len, dict):
                return buf_len.get(sock.fileno(), 1 << 20)
            return buf_len

        cands: List[Tuple[LibraSocket, object, int]] = []
        for sock in socks:
            conn = sock.connection
            if conn.closed or conn.rx_drain_remaining > 0:
                continue
            if conn.crypto is not None and conn.crypto.mode == "sw":
                # sw-kTLS: the software record layer must run between the
                # socket queue and the pool, per message — such sockets are
                # not admissible to the fused batch and pay the scalar
                # decrypt-and-copy path (the §B.1 penalty: software crypto
                # forfeits the batched-datapath amortization)
                continue
            sm = conn.rx_machine
            if sm.state is not St.DEFAULT:
                continue
            if conn.rx_available() == 0:
                continue
            parsed = sock.parse_pending()
            if not parsed.ok or parsed.payload_len < sm.min_payload:
                continue  # full-copy / unparseable: scalar path
            if conn.rx_available() < parsed.meta_len + parsed.payload_len:
                continue  # NIC DMA incomplete: never anchor holes
            bl = _bl(sock)
            if bl < parsed.meta_len + parsed.payload_len:
                # the WHOLE logical message must fit the user buffer: a
                # buf_len-capped round would hand back a truncated logical
                # length and leave a FAST_PATH continuation straddling the
                # batch/scalar boundary — scalar ``recv`` owns truncated
                # delivery end to end (§3.3), the batch services only
                # complete messages (every result below is machine-complete)
                continue
            cands.append((sock, parsed, bl))
        if not cands:
            return {}

        # ONE freelist pass allocates the whole round (placement identical
        # to per-item alloc_sequence calls, so the pool layout — and every
        # downstream byte — matches the scalar schedule exactly)
        with plane_lock(self.alloc):
            page_lists = self.alloc.alloc_batch(
                [parsed.payload_len for _, parsed, _ in cands])
        # every page list the round still owns, keyed by identity: entries
        # leave as they are freed in-band (reject/overflow) or handed off to
        # the registry; a fault anywhere below hands the rest back (OWN001)
        round_owned = {id(pl): pl for pl in page_lists if pl is not None}
        try:
            return self._recv_batch_round(cands, page_lists, round_owned,
                                          policy, impl, tx_hints)
        except BaseException:
            if round_owned:
                with plane_lock(self.alloc):
                    self.alloc.free_batch(list(round_owned.values()))
            raise

    def _recv_batch_round(self, cands, page_lists, round_owned, policy,
                          impl, tx_hints=None
                          ) -> Dict[int, Tuple[np.ndarray, int]]:
        items: List[_BatchItem] = []
        leaked: List[List[PageRef]] = []
        for (sock, parsed, bl), pages in zip(cands, page_lists):
            if pages is None:
                continue  # §A.1 overflow is the scalar path's business
            sm = sock.connection.rx_machine
            # drive the existing state machine: DEFAULT -> ... -> WRITE_VPI
            decision = sm.on_recv(sock.connection.rx_window(sm.parser.lookahead),
                                  bl, parsed=parsed)
            if decision.state is not St.WRITE_VPI:
                # should be unreachable given the admission checks above,
                # but a machine that lands anywhere else must not leak the
                # pages we just allocated: hand everything back and let the
                # scalar path re-evaluate the socket from a clean state
                # (nothing has been consumed from the ring yet)
                leaked.append(pages)
                sm.reset()
                continue
            items.append(_BatchItem(sock, bl, decision.copy_meta,
                                    sm.payload_len, pages))
        if leaked:
            with plane_lock(self.alloc):
                self.alloc.free_batch(leaked)
            for pl in leaked:
                round_owned.pop(id(pl), None)
        if not items:
            return {}

        # -- selective copy of metadata (host buffers stay int64-exact) -----
        crypt: List[_BatchItem] = []
        for it in items:
            conn = it.sock.connection
            it.meta = conn.rx_peek(it.meta_len).copy()
            conn.rx_advance(it.meta_len)
            self.counters.meta_copied += it.meta_len
            it.payload = conn.rx_peek(it.payload_len)
            if conn.crypto is not None:
                crypt.append(it)
        if crypt:
            # hw-kTLS (sw never reaches the batch): ONE vectorized keystream
            # sweep covers every encrypted record of the round, inner
            # metadata + payload. The metadata span decrypts right here
            # (those bytes are being copied to user space anyway); the
            # payload span is fused into the batched anchoring pass below —
            # no per-message crypto work survives in the fused round.
            kss = keystream_batch(
                [it.sock.connection.crypto.rx_key for it in crypt],
                [int(it.meta[1]) for it in crypt],
                [it.meta_len - REC_HEADER + it.payload_len for it in crypt])
            rejected = set()
            for it, ks in zip(crypt, kss):
                imeta = it.meta_len - REC_HEADER
                crypto = it.sock.connection.crypto
                if policy is not None:
                    # keep the ciphertext inner metadata + its keystream
                    # span: the device match pass consumes them as the
                    # kernel's keystream operand (fused decrypt-and-match)
                    it.cmeta = it.meta.copy()
                    it.meta_ks = ks[:imeta]
                it.meta[REC_HEADER:] = np.bitwise_xor(it.meta[REC_HEADER:],
                                                      ks[:imeta])
                it.ks = ks[imeta:]
                # per-record auth, folded into this same sweep (the NIC
                # verifies while it DMAs): a tag mismatch rejects the
                # record before the fused anchoring pass — pages back to
                # the freelist, record consumed, nothing charged, nothing
                # delivered (scalar ``recv`` raises RecordAuthError for
                # the same wire bytes; the batch drops the slot so one
                # tampered flow cannot poison the round). The plaintext
                # the check produces is kept: the host scatter anchors it
                # directly (one cipher pass total); the device plane still
                # ships ciphertext + keystream operands (the kernel's XOR
                # is its fused decrypt).
                it.plain = np.bitwise_xor(it.payload, it.ks)
                if not crypto.verify_record(
                        int(it.meta[1]), it.meta[TAG_SLOT],
                        np.concatenate([it.meta[REC_HEADER:], it.plain])):
                    self.counters.meta_copied -= it.meta_len
                    with plane_lock(self.alloc):
                        self.alloc.free_batch([it.pages])
                    round_owned.pop(id(it.pages), None)
                    it.sock.connection.rx_advance(it.payload_len)
                    it.sock.connection.rx_machine.reset()
                    it.sock._auth_rejected = True
                    rejected.add(id(it))
                    continue
                crypto.stats["records_opened"] += 1
            if rejected:
                items = [it for it in items if id(it) not in rejected]
                if not items:
                    return {}

        # -- one-kernel round: anchor + decrypt + match + gather, 1 launch --
        base = _fused_base(impl)
        if base is not None:
            if self._recv_batch_fused(items, policy, base, tx_hints):
                return self._recv_batch_scatter(items, round_owned)
            # not device-eligible (or bounced): the classic three-launch
            # path serves the round on the same underlying impl
            self.counters.device_fallbacks += 1
            impl = base

        # -- L7 policy: ONE vectorized match pass for the round -------------
        if policy is not None:
            self._policy_match_round(items, policy, impl)

        # -- payload anchoring: ONE fused pass for the whole round ----------
        if impl != "host" and not all(
                _fits_int32(it.meta) and _fits_int32(it.payload)
                for it in items):
            # the device data plane rides an int32 stream; out-of-range
            # int64 tokens would truncate silently — serve this round from
            # the int64-exact host scatter instead and count the bounce
            self.counters.device_fallbacks += 1
            impl = "host"
        if impl != "host" and not self._recv_batch_device(items, impl):
            # the round's destination rows hold host-truth content that
            # does not survive the int32 device dtype: int64-exact host path
            self.counters.device_fallbacks += 1
            impl = "host"
        if impl == "host":
            self.pool.write_payload_batch(
                [(it.pages, it.plain if it.plain is not None else it.payload)
                 for it in items],
                keystreams=[None if it.plain is not None else it.ks
                            for it in items])

        return self._recv_batch_scatter(items, round_owned)

    def _recv_batch_scatter(self, items: List[_BatchItem], round_owned
                            ) -> Dict[int, Tuple[np.ndarray, int]]:
        """The round's per-socket bookkeeping tail, shared by the fused and
        multi-pass data planes: register each anchor, advance the RX
        machine, and hand back the ``[meta..., VPI]`` user buffers."""
        results: Dict[int, Tuple[np.ndarray, int]] = {}
        for it in items:
            conn = it.sock.connection
            sm = conn.rx_machine
            self.counters.anchored += it.payload_len
            self.counters.allocs += 1
            conn.rx_advance(it.payload_len)
            with plane_lock(self.registry):
                vpi = self.registry.register(
                    self.pool.pool_id,
                    [(p.shard, p.local_pid, p.base_pos) for p in it.pages],
                    it.payload_len,
                )
            round_owned.pop(id(it.pages), None)
            conn.anchored[vpi] = (it.pages, it.payload_len)
            buf = np.concatenate(
                [it.meta, np.array([VpiRegistry.to_token(vpi)], np.int64)])
            self.counters.vpi_injected += 1
            # admission guaranteed logical room for the whole message, so
            # the credit always completes the machine (scalar ``recv`` owns
            # buf_len-truncated logical delivery)
            logical = it.meta_len + it.payload_len
            sm.on_payload_consumed(it.payload_len)
            self._note_anchor_owner(it.sock)
            # park (or clear) the fused round's speculative TX descriptor:
            # unconditional, so a stale guess from an earlier round can
            # never alias a recycled VPI
            if it.fused_tx is not None:
                it.fused_tx["vpi"] = vpi
            it.sock._fused_tx = it.fused_tx
            results[it.sock.fileno()] = (buf, logical)
        return results

    def _policy_match_round(self, items: List[_BatchItem], policy,
                            impl: str) -> None:
        """The fused L7 routing decision for one batched round: flatten the
        round's (already materialized) metadata into one [B, M] block, run
        the table's vectorized first-match pass once, resolve actions in
        round order (token buckets debit here), and park each verdict on
        its socket for the runtime to consume. Device impls match hw-kTLS
        rows as ciphertext + keystream (the kernel's fused decrypt); the
        host impl matches the plaintext the crypt sweep already produced —
        the verdicts are identical either way. Payload-prefix conditions
        get the plaintext first-page window (built only when the table has
        any — metadata-only tables keep their exact operand shapes)."""
        pmetas, mlens = self._round_meta_block(items)
        b = len(items)
        mm = pmetas.shape[1]
        pw = plens = None
        if getattr(policy, "has_payload_conds", False):
            pw, plens = self._round_payload_windows(items)
        if impl == "host":
            rids = policy.match_batch(pmetas, mlens, payload=pw,
                                      payload_lens=plens)
        else:
            cmetas = pmetas
            ksm = None
            if any(it.cmeta is not None for it in items):
                cmetas = pmetas.copy()
                ksm = np.zeros((b, mm), np.int64)
                for i, it in enumerate(items):
                    if it.cmeta is not None:
                        cmetas[i, : it.meta_len] = it.cmeta
                        ksm[i, REC_HEADER : it.meta_len] = it.meta_ks
            rids = policy.match_batch(cmetas, mlens, keystreams=ksm,
                                      impl=impl, payload=pw,
                                      payload_lens=plens)
            # launch accounting for the 3-vs-1 claim: a device-impl
            # multi-pass round dispatches its match as its own launch
            self.pool.xfer["policy_match_rounds"] += 1
        self._park_verdicts(items, policy, rids, pmetas, mlens)

    def _round_meta_block(self, items: List[_BatchItem]):
        """The round's plaintext metadata flattened to [B, M] int64 (+ [B]
        lengths) — the block both match paths and verdict resolution share."""
        mm = max(it.meta_len for it in items)
        b = len(items)
        pmetas = np.zeros((b, mm), np.int64)
        mlens = np.empty((b,), np.int32)
        for i, it in enumerate(items):
            pmetas[i, : it.meta_len] = it.meta
            mlens[i] = it.meta_len
        return pmetas, mlens

    def _round_payload_windows(self, items: List[_BatchItem]):
        """[B, page] plaintext first-page windows + [B] payload lengths for
        payload-prefix policy conditions — the host mirror of the window
        the fused kernel matches while the page is still in registers."""
        page = self.alloc.page_size
        pw = np.zeros((len(items), page), np.int64)
        plens = np.empty((len(items),), np.int32)
        for i, it in enumerate(items):
            src = it.plain if it.plain is not None else it.payload
            w = min(page, it.payload_len)
            pw[i, :w] = src[:w]
            plens[i] = it.payload_len
        return pw, plens

    def _park_verdicts(self, items: List[_BatchItem], policy, rids,
                       pmetas, mlens) -> None:
        """Resolve a round's matched rows host-side (token buckets debit in
        round order) and park each verdict on its socket for the runtime."""
        verdicts = policy.resolve(
            rids, pmetas, mlens,
            crypto=[it.sock.connection.crypto is not None for it in items],
            now=self.now_tick, counters=self.counters)
        for it, v in zip(items, verdicts):
            it.sock._policy_verdict = v

    def _policy_window(self, buf: np.ndarray, sock: LibraSocket
                       ) -> Tuple[Optional[np.ndarray], int]:
        """The plaintext first-page payload window of one delivered message
        (``[meta..., VPI]`` or a full copy), for scalar payload-prefix
        policy decisions — the host mirror of the window the fused kernel
        matches in registers. Anchored messages peek the pool (which holds
        plaintext in every kTLS mode — ingress decrypts before anchoring);
        full copies slice the inline buffer. Returns ``(window,
        payload_len)``, ``(None, 0)`` when there is nothing to peek."""
        page = self.alloc.page_size
        buf64 = np.asarray(buf, np.int64)
        _meta_len, _vpi, entry, res = sock._peek_message(buf64)
        if entry is not None:
            w = min(page, entry.payload_len)
            if w <= 0:
                return None, 0
            if entry.stash is not None:
                win = np.asarray(entry.stash, np.int64)[:w]
            else:
                pages = [PageRef(*pg) for pg in entry.pages]
                win = self.pool_for_entry(entry).read_payload(pages[:1], w)
            return win, entry.payload_len
        if res.ok and res.payload_len > 0:
            avail = min(res.payload_len, max(len(buf64) - res.meta_len, 0))
            w = min(page, avail)
            if w <= 0:
                return None, 0
            return buf64[res.meta_len : res.meta_len + w], avail
        return None, 0

    def drop_message(self, msg: np.ndarray, sock: LibraSocket) -> bool:
        """Policy ``DROP``: consume a delivered ``[meta..., VPI]`` message
        without transmitting it — the registry reference is released and
        the anchored pages go straight back to the freelist (no §A.4 grace:
        the verdict is an explicit discard, not a dangling close). ``sock``
        supplies the parser that framed the message. Full-copy messages
        (no live anchor) have nothing below the boundary to free. Returns
        True when an anchor was released.

        Dropping plays the egress-completion role end to end: the socket's
        RX machine is parked awaiting Post-Send cleanup (§3.4) after a
        selective delivery, so the drop performs the same
        :func:`reset_rx_from_tx` a completed transmit would — without it
        the connection would wedge in FAST_PATH forever."""
        buf64 = np.asarray(msg, np.int64)
        try:
            # the peek→release pair is one atomic region: a grantee
            # completing a forward of the same anchor releases the owner
            # VPI concurrently, and VpiRegistry.release() on an already-
            # gone entry reports "last reference" — peeking outside the
            # lock would double-free the pages (lock order: registry
            # before the owner's alloc, per the committed hierarchy)
            with plane_lock(self.registry):
                _meta_len, vpi, entry, _res = sock._peek_message(buf64)
                if entry is None:
                    return False
                if entry.stash is not None:
                    # one-copy handoff entry: payload rides the entry itself
                    self.registry.release(vpi)
                    return True
                pages = [PageRef(*pg) for pg in entry.pages]
                if entry.grant is not None:
                    # cross-worker grant: release our entry and the pin on
                    # the owner's pages — a peer pool's grant state, so the
                    # drop holds the cluster-plane lock (no-op single-stack)
                    owner_alloc = self.pool_for_entry(entry).alloc
                    with plane_lock(owner_alloc):
                        if self.registry.release(vpi):
                            owner_alloc.release_export(pages)
                    return True
                owner = self._anchor_owner(vpi)
                with plane_lock(self.alloc):
                    if self.registry.release(vpi):
                        self.alloc.free_pages_list(pages)
            if owner is not None:
                owner.connection.anchored.pop(vpi, None)
            self._gc_anchor_owners()
            return True
        finally:
            reset_rx_from_tx(sock.connection)

    def _recv_batch_fused(self, items: List[_BatchItem], policy, impl: str,
                          tx_hints) -> bool:
        """The one-kernel scheduling round: flatten the round into the same
        [B, S] operands as :meth:`_recv_batch_device` and run
        :meth:`DevicePool.fused_round_device` ONCE — payload anchoring,
        hw-kTLS RX decrypt, the L7 first-match (payload-prefix conditions
        evaluated against the page tokens still in registers) and the
        egress gather all in a single device launch, instead of the three
        the multi-pass path costs. The gather output is parked per message
        in a :attr:`_BatchItem.fused_tx` descriptor (the scatter tail moves
        it onto the socket once the VPI exists): a speculative TX —
        ``tx_hints`` names each flow's likely destination so hw-kTLS TX
        encryption is fused in too, and ``forward_batch`` validates the
        guess before consuming it. Returns False when the round is not
        device-eligible (host pool, int64-only tokens, non-contiguous page
        lists) or bounced (DeviceRangeError) — the caller then serves it
        through the classic three-launch path."""
        if not isinstance(self.pool, DevicePool):
            return False
        page = self.alloc.page_size
        for it in items:
            if not (_fits_int32(it.meta) and _fits_int32(it.payload)):
                return False
            if any(pg.base_pos != j * page
                   for j, pg in enumerate(it.pages)):
                # the in-register gather addresses payload position
                # [j*page, (j+1)*page) through table slot j — only the
                # allocator's contiguous layout qualifies
                return False
        b = len(items)
        pps = max(max(len(it.pages) for it in items), 1)
        meta_max = max(max(it.meta_len for it in items), 1)
        s = max(it.meta_len + len(it.pages) * page for it in items)
        s = max(-(-max(s, meta_max) // page) * page, page)
        stream = np.zeros((b, s), np.int32)
        meta_len = np.zeros((b,), np.int32)
        total_len = np.zeros((b,), np.int32)
        tables = np.full((b, pps), -1, np.int32)
        ks = np.zeros((b, s), np.int32) if any(
            it.ks is not None for it in items) else None
        for i, it in enumerate(items):
            msg = it.meta_len + it.payload_len
            stream[i, : it.meta_len] = it.meta
            stream[i, it.meta_len : msg] = it.payload
            meta_len[i] = it.meta_len
            total_len[i] = msg
            if it.ks is not None:
                ks[i, it.meta_len : msg] = it.ks
            for j, pg in enumerate(it.pages):
                tables[i, j] = self.alloc.flat_pid(pg)
        txks = self._speculate_tx(items, tx_hints, pps * page)
        off = lo = hi = live = None
        if policy is not None:
            off, lo, hi = policy.cond_off, policy.cond_lo, policy.cond_hi
            live = policy.rule_live()
        try:
            verdict, gathered = self.pool.fused_round_device(
                stream, meta_len, total_len, tables, meta_max=meta_max,
                impl=impl, keystream=ks, tx_keystream=txks,
                cond_off=off, cond_lo=lo, cond_hi=hi, live=live,
                n_buffers=getattr(self.pool, "fused_buffers", 0))
        except DeviceRangeError:
            return False
        if policy is not None:
            # the fused launch IS this round's match pass; resolution stays
            # host-side exactly as in _policy_match_round
            policy.stats["rounds"] += 1
            pmetas, mlens = self._round_meta_block(items)
            self._park_verdicts(items, policy, verdict, pmetas, mlens)
        for i, it in enumerate(items):
            if it.fused_tx is not None:
                it.fused_tx["payload"] = gathered[i, : it.payload_len]
        return True

    def _speculate_tx(self, items: List[_BatchItem], tx_hints,
                      width: int) -> Optional[np.ndarray]:
        """Speculative TX operands for the fused round: each message whose
        likely destination (``tx_hints``: src fd -> socket) is known gets a
        forward-time cache descriptor on its :class:`_BatchItem`; hw-kTLS
        destinations additionally contribute rows to the returned
        [B, width] TX-keystream operand (ONE vectorized sweep, exactly the
        forward_batch schedule) so the fused gather emits ciphertext and
        the metadata span is stashed for seal_meta at forward time. Wrong
        guesses cost nothing — forward_batch validates the descriptor and
        falls back to its own gather."""
        txks = None
        enc: List[Tuple[int, object, int, int]] = []
        for i, it in enumerate(items):
            dst = tx_hints.get(it.sock.fileno()) if tx_hints else None
            if dst is None or dst.closed:
                continue
            crypto = dst.connection.crypto
            if crypto is None:
                it.fused_tx = {"dst_fd": dst.fileno(), "crypto": None,
                               "plen": it.payload_len, "seq": None,
                               "meta_ks": None, "payload": None}
            elif crypto.mode == "hw" and it.ks is not None:
                # encrypted record toward an hw session: the record seq
                # rides the header (slot 1), so the whole TX keystream is
                # computable before the destination ever sees the message
                enc.append((i, crypto, int(it.meta[1]),
                            it.meta_len - REC_HEADER))
            # sw destinations: scalar encrypt-and-copy, never speculated
        if enc:
            kss = keystream_batch(
                [crypto.tx_key for _, crypto, _, _ in enc],
                [seq for _, _, seq, _ in enc],
                [imeta + items[i].payload_len for i, _, _, imeta in enc])
            txks = np.zeros((len(items), width), np.int32)
            for (i, crypto, seq, imeta), ksr in zip(enc, kss):
                it = items[i]
                txks[i, : it.payload_len] = ksr[imeta:]
                it.fused_tx = {
                    "dst_fd": tx_hints[it.sock.fileno()].fileno(),
                    "crypto": crypto, "plen": it.payload_len, "seq": seq,
                    "meta_ks": ksr[:imeta], "payload": None}
        return txks

    def _recv_batch_device(self, items: List[_BatchItem], impl: str) -> bool:
        """Flatten the round into one [B, S] batch and run the fused
        selective-copy kernel once through the pool's device entry point
        (resident :class:`DevicePool` by default: O(batch) up, nothing
        back; legacy host pool: one whole-pool bounce). hw-kTLS rows ship
        their RX keystream as the kernel's ``keystream`` operand, so
        decryption is fused into the payload placement. Returns False when
        the round must bounce to the int64-exact host scatter."""
        page = self.alloc.page_size
        b = len(items)
        pps = max(len(it.pages) for it in items)
        meta_max = max(max(it.meta_len for it in items), 1)
        s = max(it.meta_len + len(it.pages) * page for it in items)
        s = max(-(-max(s, meta_max) // page) * page, page)
        stream = np.zeros((b, s), np.int32)
        meta_len = np.zeros((b,), np.int32)
        total_len = np.zeros((b,), np.int32)
        tables = np.full((b, pps), -1, np.int32)
        ks = np.zeros((b, s), np.int32) if any(
            it.ks is not None for it in items) else None
        for i, it in enumerate(items):
            msg = it.meta_len + it.payload_len
            # int64 host tokens ride the int32 device stream; recv_batch
            # pre-checked the range (out-of-range rounds fall back to host)
            stream[i, : it.meta_len] = it.meta
            stream[i, it.meta_len : msg] = it.payload
            meta_len[i] = it.meta_len
            total_len[i] = msg
            if it.ks is not None:
                ks[i, it.meta_len : msg] = it.ks
            for j, pg in enumerate(it.pages):
                tables[i, j] = self.alloc.flat_pid(pg)
        try:
            self.pool.anchor_batch_device(stream, meta_len, total_len,
                                          tables, meta_max=meta_max,
                                          impl=impl, keystream=ks)
        except DeviceRangeError:
            return False
        return True

    def forward_batch(
        self,
        sends: Sequence[Tuple[Optional[LibraSocket], LibraSocket,
                              np.ndarray, Optional[int]]],
        *,
        impl: str = "host",
    ) -> List[Tuple[str, int]]:
        """Batched proxy forwarding: ``sends`` is a list of
        ``(src_sock, dst_sock, buf, budget)``. The anchored payloads of all
        FAST_PATH-eligible messages are fetched with ONE fused gather
        (:meth:`TokenPool.read_payload_batch`, or — ``impl`` other than
        ``'host'`` on the resident :class:`DevicePool` — the fused
        :func:`~repro.kernels.ops.selective_gather` kernel reading the
        anchored pages on-device) and handed to each socket's normal
        transmit path, so counters, staging, partial-send resume and
        cross-datapath cleanup behave exactly as scalar ``forward``.

        Returns one ``(status, accepted)`` per send, in order:
        ``(SEND_OK, n)`` or ``(SEND_EAGAIN, 0)`` (backend busy with another
        flow's truncated message — retry next round, as scalar).

        Encrypted hw-mode destinations get their TX keystream fused into
        the batched gather (NIC-inline encrypt, still one pass); sw-mode
        destinations are excluded from the prefetch — their encrypt pass
        runs per message inside the scalar transmit (the §B.1 penalty).
        Messages a fused recv round already gathered
        (``recv_batch(impl='fused-round', tx_hints=...)``) skip even that
        single launch: the speculative descriptor parked on the source
        socket is validated (same VPI, destination session and payload
        length, plain local anchor) and consumed directly
        (``pool.xfer['tx_spec_hits']``); misses fall back to the gather.

        Cross-worker sends work here too: a VPI that does not resolve on
        the destination's stack is adopted through the cluster interconnect
        (zero-copy grant or counted one-copy stash) before prefetch
        eligibility is decided, and the fused gathers are grouped by the
        pool that owns each entry's pages — a grant's payload is gathered
        straight off the owning worker's (device-resident) pool."""
        sends = list(sends)
        # under one-kernel rounds, sends the fused recv did not speculate
        # (or whose guess missed) gather on the same underlying device impl
        base = _fused_base(impl)
        if base is not None:
            impl = base
        prefetch: List[Optional[np.ndarray]] = [None] * len(sends)
        peeks: List[Optional[Tuple]] = [None] * len(sends)
        # (send slot, entry, (pages, len), ksinfo) per prefetch-eligible send
        gather: List[Tuple[int, object, Tuple, Optional[Tuple]]] = []
        for k, (src, dst, buf, budget) in enumerate(sends):
            if dst.pending_send is not None or dst.closed:
                continue
            buf64 = np.asarray(buf, np.int64)
            peek = dst._peek_message(buf64)
            if peek[2] is None and peek[1] is not None:
                # unresolved handle: in a cluster it may be anchored on a
                # peer worker — adopt (grant/copy) and re-peek so the rest
                # of the round treats it exactly like a local message
                adopted = dst.stack._adopt_message(buf64, peek[1], peek[3])
                if adopted is not None:
                    buf64 = adopted
                    peek = dst._peek_message(buf64)
                    sends[k] = (src, dst, buf64, budget)
            peeks[k] = peek
            entry = peek[2]
            if entry is None or \
                    entry.payload_len < dst.connection.tx_machine.min_payload:
                continue
            crypto = dst.connection.crypto
            if crypto is not None and crypto.mode == "sw":
                continue  # software record layer: scalar encrypt-and-copy
            spec = getattr(src, "_fused_tx", None) if src is not None \
                else None
            if spec is not None and spec.get("vpi") == peek[1]:
                # the fused round speculated this send: its gather output
                # (TX-encrypted for an hw destination) is already in hand.
                # Validate the guess — right destination session, same
                # payload, a plain local anchor — and skip the gather; a
                # miss just falls through to the classic path below.
                src._fused_tx = None
                if spec["payload"] is not None \
                        and spec["dst_fd"] == dst.fileno() \
                        and spec["crypto"] is crypto \
                        and spec["plen"] == entry.payload_len \
                        and entry.stash is None and entry.grant is None:
                    if spec["meta_ks"] is not None:
                        crypto.stash_tx_meta_ks(spec["seq"],
                                                spec["meta_ks"])
                    prefetch[k] = np.asarray(spec["payload"], np.int64)
                    self.pool.xfer["tx_spec_hits"] += 1
                    continue
            ksinfo = None
            if crypto is not None:
                # hw-kTLS: (session, seq, inner-meta length) — the whole
                # record keystream is generated below in one vectorized
                # sweep for the round (metadata span stashed for the
                # seal_meta this transmit is about to trigger, payload span
                # fused into the batched gather)
                ksinfo = (crypto, int(buf64[1]), peek[0] - REC_HEADER)
            gather.append((k, entry, ([PageRef(*pg) for pg in entry.pages],
                                      entry.payload_len), ksinfo))
        if gather:
            keystreams: List[Optional[np.ndarray]] = [None] * len(gather)
            enc = [(i, info) for i, (_, _, _, info) in enumerate(gather)
                   if info is not None]
            if enc:
                kss = keystream_batch(
                    [info[0].tx_key for _, info in enc],
                    [info[1] for _, info in enc],
                    [info[2] + gather[i][2][1] for i, info in enc])
                for (i, (crypto, seq, imeta)), ks in zip(enc, kss):
                    crypto.stash_tx_meta_ks(seq, ks[:imeta])
                    keystreams[i] = ks[imeta:]
            # one-copy stash entries carry their payload already; pool
            # entries are gathered per owning pool (grants read the peer
            # worker's pool, local anchors read ours) — one fused gather
            # per pool touched by the round
            groups: Dict[int, Tuple[TokenPool, List[int]]] = {}
            for i, (k, entry, seq_info, _) in enumerate(gather):
                if entry.stash is not None:
                    pv = np.asarray(entry.stash, np.int64)
                    if keystreams[i] is not None:
                        pv = np.bitwise_xor(pv, keystreams[i])
                    prefetch[k] = pv
                    continue
                owner = sends[k][1].stack.pool_for_entry(entry)
                groups.setdefault(id(owner), (owner, []))[1].append(i)
            for owner, idxs in groups.values():
                payloads = self._gather_payloads(
                    [gather[i][2] for i in idxs],
                    [keystreams[i] for i in idxs], impl, pool=owner)
                for i, pv in zip(idxs, payloads):
                    prefetch[gather[i][0]] = pv
        out: List[Tuple[str, int]] = []
        for k, (src, dst, buf, budget) in enumerate(sends):
            peeked, pf = peeks[k], prefetch[k]
            if peeked is not None and peeked[2] is not None and \
                    dst.stack.registry.peek(peeked[1]) is not peeked[2]:
                # an earlier send in this round invalidated the peek (e.g.
                # it released or tore down the same VPI): transmitting
                # against the stale entry would mis-size the pending
                # message and wedge the socket — drop the prefetch and let
                # the transmit re-evaluate, exactly as scalar ``forward``
                peeked, pf = None, None
            try:
                n = dst._transmit(src, buf, budget,
                                  payload_prefetched=pf, peeked=peeked)
            except BlockingIOError:
                out.append((SEND_EAGAIN, 0))
                continue
            out.append((SEND_OK, n))
        return out

    def _gather_payloads(
        self,
        seqs: List[Tuple[List[PageRef], int]],
        keystreams: List[Optional[np.ndarray]],
        impl: str,
        pool: Optional[TokenPool] = None,
    ) -> List[np.ndarray]:
        """Fetch one round's anchored payloads: the fused device gather off
        the resident pool when eligible, the host gather otherwise.
        Byte-identical either way (the gather oracle mirrors
        ``read_payload``); ineligible/bounced rounds stay int64-exact.
        ``pool`` routes the gather to the pool that owns the pages (a peer
        worker's, for cross-worker grant entries); default = our own."""
        pool = self.pool if pool is None else pool
        page = pool.alloc.page_size
        if impl != "host" and isinstance(pool, DevicePool) \
                and len(seqs) > _SMALL_GATHER_ROWS and all(
                all(pg.base_pos == j * page for j, pg in enumerate(pages))
                for pages, _ in seqs):
            # the kernel addresses payload position [j*page, (j+1)*page)
            # through table slot j — only contiguously-anchored sequences
            # (the allocator's invariant layout) are device-ELIGIBLE; a
            # non-contiguous page list (exotic registry contents) is not a
            # bounce and does not count a device_fallback, it simply never
            # qualifies for the device plane
            try:
                return self._forward_batch_device(seqs, keystreams, impl,
                                                  pool)
            except DeviceRangeError:
                # a requested row holds host-truth tokens outside int32:
                # the int64-exact host gather serves the round
                self.counters.device_fallbacks += 1
        return pool.read_payload_batch(seqs, keystreams=keystreams)

    def _forward_batch_device(
        self,
        seqs: List[Tuple[List[PageRef], int]],
        keystreams: List[Optional[np.ndarray]],
        impl: str,
        pool: TokenPool,
    ) -> List[np.ndarray]:
        """Flatten the round into [B, pps] tables + [B] lengths and run the
        fused egress gather once against ``pool``'s resident device array.
        TX keystreams (payload-relative, 31-bit) ride the kernel's
        ``keystream`` operand — NIC-inline encrypt, zero extra passes."""
        page = pool.alloc.page_size
        b = len(seqs)
        pps = max((len(pages) for pages, _ in seqs), default=1) or 1
        tables = np.full((b, pps), -1, np.int32)
        lengths = np.zeros((b,), np.int32)
        ks = (np.zeros((b, pps * page), np.int32)
              if any(k is not None for k in keystreams) else None)
        for i, (pages, ln) in enumerate(seqs):
            lengths[i] = ln
            for j, pg in enumerate(pages):
                tables[i, j] = pool.alloc.flat_pid(pg)
            if ks is not None and keystreams[i] is not None:
                ks[i, :ln] = keystreams[i]
        block = pool.gather_batch_device(tables, lengths, impl=impl,
                                         keystream=ks)
        return [block[i, :ln] for i, (_, ln) in enumerate(seqs)]

    # -- multi-worker plumbing (driven by repro.core.cluster) ----------------
    def register_peer_pool(self, pool: TokenPool) -> None:
        """Make a peer worker's pool addressable by its ``pool_id`` so this
        stack's egress can compose grant entries straight out of it."""
        self._peer_pools[pool.pool_id] = pool

    def pool_for_entry(self, entry) -> TokenPool:
        """The pool that owns ``entry``'s pages: this stack's own pool for
        local anchors (and stash entries, which never touch a pool), the
        registered peer pool for cross-worker grants."""
        if entry is None or entry.pool_id == self.pool.pool_id:
            return self.pool
        return self._peer_pools.get(entry.pool_id, self.pool)

    def _adopt_message(self, msg: np.ndarray, vpi: Optional[int],
                       parsed) -> Optional[np.ndarray]:
        """A transmit met a framed message whose VPI does not resolve in
        THIS stack's registry. In a cluster the handle may belong to a peer
        worker: ask the interconnect to hand the anchored payload over (a
        zero-copy grant, or the counted one-copy fallback) and return the
        message with the granted VPI patched into its VPI slot. None when
        the handle is unknown cluster-wide (stale/garbage: the normal
        FALLBACK_BYPASS path takes it from here)."""
        if self.interconnect is None or vpi is None:
            return None
        if parsed is None or not parsed.ok or \
                len(msg) < parsed.meta_len + 1:
            return None
        granted = self.interconnect.grant_into(self, vpi)
        if granted is None:
            return None
        out = np.asarray(msg, np.int64).copy()
        out[parsed.meta_len] = VpiRegistry.to_token(granted)
        return out

    # -- facade bookkeeping (called by LibraSocket) --------------------------
    def _note_anchor_owner(self, sock: LibraSocket) -> None:
        for vpi in sock.connection.anchored:
            self._vpi_owner.setdefault(vpi, sock)

    def _anchor_owner(self, vpi: int) -> Optional[LibraSocket]:
        return self._vpi_owner.get(vpi)

    def _null_source(self) -> Connection:
        """Inert connection used as the nominal source of sends with no
        live anchor owner, so cross-path cleanup never resets a real RX
        machine (its state machines carry no traffic)."""
        if self._null_conn is None:
            self._null_conn = Connection(LengthPrefixedParser(), self.registry)
        return self._null_conn

    def _gc_anchor_owners(self) -> None:
        dead = [v for v in self._vpi_owner if v not in self.registry]
        for v in dead:
            del self._vpi_owner[v]

    def _detach(self, sock: LibraSocket) -> None:
        self.sockets.pop(sock.fileno(), None)
        self._gc_anchor_owners()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LibraStack(sockets={len(self.sockets)}, "
                f"pages={self.alloc.free_pages}/{self.alloc.total_pages} free, "
                f"vpis={len(self.registry)}, tick={self.now_tick})")
