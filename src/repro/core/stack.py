"""``LibraStack`` — one Libra "kernel" instance.

The stack owns everything the paper's kernel half owns, so that socket
call-sites carry zero plumbing:

* the anchored payload pool (:class:`AnchorPool` allocator +
  :class:`TokenPool` payload store — the kernel-retained skb pages),
* the global ``<VPI, payload>`` map (:class:`VpiRegistry`),
* the parser-policy registry (named eBPF RX/TX-Prog analogues),
* a monotonic tick clock driving §A.4 deferred-teardown expiry,
* the global :class:`CopyCounters` telemetry block (paper Fig. 9).

Sockets are created with :meth:`socket` / :meth:`socket_pair`; a single
stack multiplexes any number of connections with heterogeneous parser
policies (see :mod:`repro.core.runtime` for the event loop on top).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

from repro.core.anchor_pool import AnchorPool
from repro.core.egress import expire_teardowns
from repro.core.parser import BUILTIN_PARSERS, LengthPrefixedParser, ParserPolicy
from repro.core.socket import Events, LibraSocket
from repro.core.state_machine import MIN_PAYLOAD
from repro.core.stream import Connection, CopyCounters, TokenPool
from repro.core.vpi import VpiRegistry

ParserLike = Union[str, ParserPolicy]


class LibraStack:
    """Shared selective-copy state for a set of :class:`LibraSocket`\\ s."""

    def __init__(self, *, n_shards: int = 4, pages_per_shard: int = 64,
                 page_size: int = 16, max_pages_per_seq: int = 0,
                 grace_ticks: int = 5, secret: Optional[bytes] = None,
                 alloc: Optional[AnchorPool] = None,
                 registry: Optional[VpiRegistry] = None,
                 parsers: Optional[Dict[str, type]] = None):
        self.alloc = alloc or AnchorPool(n_shards, pages_per_shard, page_size,
                                         max_pages_per_seq=max_pages_per_seq)
        self.pool = TokenPool(self.alloc)
        self.registry = registry or VpiRegistry(secret=secret,
                                                grace_ticks=grace_ticks)
        self.counters = CopyCounters()
        self.parsers: Dict[str, type] = dict(BUILTIN_PARSERS)
        if parsers:
            self.parsers.update(parsers)
        self.now_tick = 0
        self.sockets: Dict[int, LibraSocket] = {}
        # vpi -> anchoring socket (the kernel finds this through the global
        # eBPF map; the facade keeps an explicit owner index)
        self._vpi_owner: Dict[int, LibraSocket] = {}
        self._null_conn: Optional[Connection] = None

    # -- socket lifecycle ----------------------------------------------------
    def make_parser(self, parser: ParserLike, **kw) -> ParserPolicy:
        """Resolve a registered parser name (or pass a policy through)."""
        if isinstance(parser, str):
            return self.parsers[parser](**kw)
        return parser

    def socket(self, parser: ParserLike = "length-prefixed", *,
               min_payload: int = MIN_PAYLOAD,
               send_budget: Optional[int] = None) -> LibraSocket:
        """Open a connection on this stack. ``min_payload`` above any real
        message size forces the native full-copy path (a standard-stack
        baseline socket); ``send_budget`` models a bounded send buffer."""
        sock = LibraSocket(self, self.make_parser(parser),
                           min_payload=min_payload, send_budget=send_budget)
        self.sockets[sock.fileno()] = sock
        return sock

    def socket_pair(self, parser: ParserLike = "length-prefixed",
                    **kw) -> Tuple[LibraSocket, LibraSocket]:
        """A (client-side, backend-side) pair sharing one parser policy —
        the two halves of one proxied flow."""
        return self.socket(parser, **kw), self.socket(parser, **kw)

    def close_all(self) -> int:
        """Close every open socket; returns total anchors deferred."""
        return sum(s.close() for s in list(self.sockets.values()))

    # -- clock ---------------------------------------------------------------
    def tick(self, n: int = 1) -> int:
        """Advance the monotonic clock ``n`` ticks, expiring §A.4 grace
        periods each tick. Returns the number of pages reclaimed."""
        freed = 0
        for _ in range(max(n, 1)):
            self.now_tick += 1
            freed += expire_teardowns(self.pool, self.registry, self.now_tick)
        self._gc_anchor_owners()
        return freed

    def drain(self) -> int:
        """Tick through a full grace period (teardown flush for tests and
        orderly shutdown)."""
        return self.tick(self.registry.grace_ticks + 1)

    # -- telemetry -----------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.alloc.total_pages - self.alloc.free_pages

    def utilization(self) -> float:
        return self.alloc.used_fraction

    def poll(self) -> Dict[int, Events]:
        """Stack-wide readiness snapshot (epoll_wait analogue)."""
        return {fd: s.poll() for fd, s in self.sockets.items()}

    # -- facade bookkeeping (called by LibraSocket) --------------------------
    def _note_anchor_owner(self, sock: LibraSocket) -> None:
        for vpi in sock.connection.anchored:
            self._vpi_owner.setdefault(vpi, sock)

    def _anchor_owner(self, vpi: int) -> Optional[LibraSocket]:
        return self._vpi_owner.get(vpi)

    def _null_source(self) -> Connection:
        """Inert connection used as the nominal source of sends with no
        live anchor owner, so cross-path cleanup never resets a real RX
        machine (its state machines carry no traffic)."""
        if self._null_conn is None:
            self._null_conn = Connection(LengthPrefixedParser(), self.registry)
        return self._null_conn

    def _gc_anchor_owners(self) -> None:
        dead = [v for v in self._vpi_owner if v not in self.registry]
        for v in dead:
            del self._vpi_owner[v]

    def _detach(self, sock: LibraSocket) -> None:
        self.sockets.pop(sock.fileno(), None)
        self._gc_anchor_owners()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"LibraStack(sockets={len(self.sockets)}, "
                f"pages={self.alloc.free_pages}/{self.alloc.total_pages} free, "
                f"vpis={len(self.registry)}, tick={self.now_tick})")
