"""Event-driven multi-connection proxy runtime (the epoll loop analogue).

This is the piece that lets one :class:`LibraStack` behave like the proxies
the paper evaluates: an event loop multiplexing N client↔backend flows with
heterogeneous parser policies, bounded send buffers, and a periodic tick
that drives deferred-teardown expiry — all through the POSIX-shaped
:class:`LibraSocket` facade (no pool/registry/counter plumbing at any
call-site).

Model:

* :class:`ProxyChannel` — one proxied flow. ``recv`` on the client-side
  socket, optionally rewrite the metadata (L7 policy), route to one of the
  backend sockets, ``forward`` with this channel's send budget. A
  budget-truncated message stays "in flight" and is continued on later
  quanta before new data is read (TCP ordering per flow).
* :class:`ProxyRuntime` — readiness-set scheduler. ``step()`` is one
  scheduling round: poll all channels, service the ready ones (round-robin
  rotation or strict priority order), and advance the stack clock every
  ``tick_every`` rounds. ``run()`` loops until idle.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Union

import numpy as np

from repro.core.socket import Events, LibraSocket
from repro.core.stack import LibraStack
from repro.core.state_machine import St

Router = Callable[[np.ndarray, int], LibraSocket]
Rewrite = Callable[[np.ndarray, int], np.ndarray]


@dataclasses.dataclass
class ChannelStats:
    # frames fully handed to the backend socket; a chunked application
    # message counts one frame per chunk plus its terminator
    messages: int = 0
    logical_bytes: int = 0     # logical bytes accepted by sends
    recv_calls: int = 0
    send_calls: int = 0
    partial_sends: int = 0     # sends truncated by the budget
    quanta: int = 0            # scheduling quanta consumed


class ProxyChannel:
    """One proxied flow through the L7 proxy."""

    def __init__(self, src: LibraSocket,
                 dst: Union[LibraSocket, Sequence[LibraSocket]], *,
                 router: Optional[Router] = None,
                 rewrite: Optional[Rewrite] = None,
                 recv_buf: int = 1 << 20,
                 budget: Optional[int] = None,
                 priority: int = 0,
                 name: Optional[str] = None):
        self.src = src
        self.dsts: List[LibraSocket] = (
            list(dst) if isinstance(dst, (list, tuple)) else [dst])
        self.router = router      # (buf, logical) -> backend socket
        self.rewrite = rewrite    # (buf, logical) -> outgoing buffer
        self.recv_buf = recv_buf
        self.budget = budget
        self.priority = priority
        self.name = name or f"ch{src.fileno()}"
        self.stats = ChannelStats()
        self._inflight: Optional[LibraSocket] = None
        # reassembly of a selective-copy message that needed several recv
        # calls (recv_buf smaller than metadata+VPI, or capped logical)
        self._rx_parts: List[np.ndarray] = []
        self._rx_logical = 0
        # message routed to a backend whose send buffer was busy with
        # another flow's truncated message (EAGAIN): retried next quantum
        self._held: Optional[tuple] = None

    def ready(self) -> bool:
        # outbound work (a truncated or held message) outlives the client
        # connection — §A.4 teardown lets the frame finish transmitting
        if self._inflight is not None or self._held is not None:
            return True
        if self.src.closed:
            return False
        if self._rx_parts:
            return True
        if not self.src.poll() & Events.READABLE:
            return False
        # L7 policy: wait for a parseable frame rather than forwarding the
        # unframed prefix of a message still arriving (raw unparseable
        # streams — need_more False — still flow through as full copies)
        return not self.src.needs_more_data()

    def _mid_message(self) -> bool:
        """True while the RX machine is inside one selective-copy message
        (deferred VPI, or logical length capped by recv_buf)."""
        sm = self.src.connection.rx_machine
        if sm.state is St.METADATA_PARSED:
            return True
        return sm.state is St.FAST_PATH and not sm.complete()

    def service(self) -> bool:
        """One quantum of work; returns True if progress was made."""
        self.stats.quanta += 1
        if self._inflight is not None:
            return self._continue_send()
        if self._held is not None:
            out, dst = self._held
            self._held = None
            return self._start_send(out, dst)
        buf, logical = self.src.recv(self.recv_buf)
        self.stats.recv_calls += 1
        if logical == 0 and len(buf) == 0:
            return False
        if self._mid_message():
            # fragment of one message: reassemble before routing, so the
            # whole message goes to ONE backend in one send
            self._rx_parts.append(buf)
            self._rx_logical += logical
            return True
        if self._rx_parts:
            self._rx_parts.append(buf)
            buf = np.concatenate(self._rx_parts)
            logical += self._rx_logical
            self._rx_parts, self._rx_logical = [], 0
        if logical == 0:
            return False
        out = self.rewrite(buf, logical) if self.rewrite else buf
        dst = self.router(buf, logical) if self.router else self.dsts[0]
        return self._start_send(out, dst)

    def _start_send(self, out, dst: LibraSocket) -> bool:
        try:
            n = self.src.forward(dst, out, budget=self.budget)
        except BlockingIOError:
            # backend busy with another flow's truncated message: hold the
            # routed message and retry once that send completes
            self._held = (out, dst)
            return False
        self.stats.send_calls += 1
        self.stats.logical_bytes += n
        if dst.pending_send is not None:
            self._inflight = dst
            self.stats.partial_sends += 1
        else:
            self.stats.messages += 1
        return True

    def _continue_send(self) -> bool:
        dst = self._inflight
        n = dst.send(budget=self.budget)
        self.stats.send_calls += 1
        self.stats.logical_bytes += n
        if dst.pending_send is None:
            self._inflight = None
            self.stats.messages += 1
        else:
            self.stats.partial_sends += 1
        return n > 0


class ProxyRuntime:
    """Readiness-set scheduler over one stack's channels."""

    SCHEDULERS = ("round-robin", "priority")

    def __init__(self, stack: LibraStack, *, scheduler: str = "round-robin",
                 tick_every: int = 16):
        assert scheduler in self.SCHEDULERS, scheduler
        self.stack = stack
        self.scheduler = scheduler
        self.tick_every = tick_every
        self.channels: List[ProxyChannel] = []
        self.rounds = 0
        self._rr = 0

    # -- registration --------------------------------------------------------
    def register(self, channel: ProxyChannel) -> ProxyChannel:
        self.channels.append(channel)
        return channel

    def channel(self, src: LibraSocket, dst, **kw) -> ProxyChannel:
        """Create and register a channel in one call."""
        return self.register(ProxyChannel(src, dst, **kw))

    # -- scheduling ----------------------------------------------------------
    def poll(self) -> List[ProxyChannel]:
        """The ready set, ordered by the active scheduling policy."""
        ready = [c for c in self.channels if c.ready()]
        if not ready:
            return ready
        if self.scheduler == "priority":
            return sorted(ready, key=lambda c: -c.priority)
        k = self._rr % len(ready)
        return ready[k:] + ready[:k]

    def step(self) -> int:
        """One scheduling round: give each ready channel one quantum.
        Returns the number of channels that made progress."""
        progressed = 0
        for ch in self.poll():
            progressed += bool(ch.service())
        self.rounds += 1
        self._rr += 1
        if self.tick_every and self.rounds % self.tick_every == 0:
            self.stack.tick()
        return progressed

    def run(self, max_rounds: int = 10 ** 6) -> int:
        """Loop until no channel is ready (or ``max_rounds``). Returns the
        total number of messages forwarded across all channels."""
        rounds = 0
        while rounds < max_rounds:
            if self.step() == 0:
                break
            rounds += 1
        return self.messages_forwarded()

    def shutdown(self) -> int:
        """Close every channel endpoint and flush all grace periods.
        Returns the number of pages reclaimed by deferred teardown."""
        for ch in self.channels:
            ch.src.close()
            for d in ch.dsts:
                d.close()
        return self.stack.drain()

    # -- telemetry -----------------------------------------------------------
    def messages_forwarded(self) -> int:
        return sum(c.stats.messages for c in self.channels)

    def logical_bytes(self) -> int:
        return sum(c.stats.logical_bytes for c in self.channels)
