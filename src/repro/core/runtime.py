"""Event-driven multi-connection proxy runtime (the epoll loop analogue).

This is the piece that lets one :class:`LibraStack` behave like the proxies
the paper evaluates: an event loop multiplexing N client↔backend flows with
heterogeneous parser policies, bounded send buffers, and a periodic tick
that drives deferred-teardown expiry — all through the POSIX-shaped
:class:`LibraSocket` facade (no pool/registry/counter plumbing at any
call-site).

Model:

* :class:`ProxyChannel` — one proxied flow. ``recv`` on the client-side
  socket, optionally rewrite the metadata (L7 policy), route to one of the
  backend sockets, ``forward`` with this channel's send budget. A
  budget-truncated message stays "in flight" and is continued on later
  quanta before new data is read (TCP ordering per flow). Channels apply
  pool **backpressure**: when the stack is above its watermark, a channel
  whose next frame would anchor pauses instead of overflowing into the
  §A.1 drain path (disable per channel with ``backpressure=False``).
* :class:`ProxyRuntime` — readiness-set scheduler. ``step()`` is one
  scheduling round: poll all channels, service the ready ones (round-robin
  rotation or strict priority order), and advance the stack clock every
  ``tick_every`` rounds. With ``batched=True`` a round gathers every ready
  channel's admissible frame into ONE ``LibraStack.recv_batch`` /
  ``forward_batch`` pair (a single data-plane pass for the whole round);
  channels in edge states (mid-message, drain, held/in-flight sends, pool
  exhaustion, unparseable frames) transparently fall back to their scalar
  quantum, so semantics and counters match the scalar scheduler exactly.
  ``run()`` loops until idle.

Every channel records a per-quantum latency histogram
(:class:`LatencyHistogram`, log₂ buckets) — ``ProxyRuntime.latency_summary``
reports p50/p99 per channel; batched rounds charge each participant the
amortized share of the round's data-plane time.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import struct
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.crypto import RecordAuthError
from repro.core.policy import PUNT_BAD_BACKEND, Verdict
from repro.core.socket import Events, LibraSocket
from repro.core.stack import SEND_EAGAIN, LibraStack
from repro.core.state_machine import St

Router = Callable[[np.ndarray, int], LibraSocket]
Rewrite = Callable[[np.ndarray, int], np.ndarray]

#: sentinel: a quantum consumed input but produced nothing to transmit
_IDLE = object()
#: policy verdict said PUNT: fall through to the channel's Python callbacks
_PUNT = object()


class LatencyHistogram:
    """Log₂-bucketed latency histogram (quantum-scale timings).

    Bucket k covers [lo·2ᵏ, lo·2ᵏ⁺¹); percentiles report the geometric
    midpoint of the covering bucket — cheap, allocation-free telemetry
    (no per-sample storage)."""

    __slots__ = ("lo", "counts", "count", "total")

    def __init__(self, lo: float = 1e-7, n_buckets: int = 40):
        self.lo = lo
        self.counts = [0] * n_buckets
        self.count = 0
        self.total = 0.0

    def record(self, seconds: float) -> None:
        b = 0 if seconds <= self.lo else int(math.log2(seconds / self.lo)) + 1
        self.counts[min(max(b, 0), len(self.counts) - 1)] += 1
        self.count += 1
        self.total += seconds

    def percentile(self, q: float) -> float:
        """q in [0, 1] -> seconds (0.0 when empty)."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for b, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                if b == 0:
                    return self.lo
                return self.lo * (2.0 ** (b - 1)) * math.sqrt(2.0)
        return self.lo * 2.0 ** (len(self.counts) - 1)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count,
                "mean": self.total / max(self.count, 1),
                "p50": self.percentile(0.50),
                "p99": self.percentile(0.99)}


@dataclasses.dataclass
class ChannelStats:
    # frames fully handed to the backend socket; a chunked application
    # message counts one frame per chunk plus its terminator
    messages: int = 0
    logical_bytes: int = 0     # logical bytes accepted by sends
    recv_calls: int = 0
    send_calls: int = 0
    partial_sends: int = 0     # sends truncated by the budget
    quanta: int = 0            # scheduling quanta consumed
    bp_pauses: int = 0         # quanta skipped by pool backpressure
    auth_rejects: int = 0      # tampered records rejected by the tag check
    drops: int = 0             # messages consumed by a DROP verdict (or a
                               # router callback returning None)
    retries: int = 0           # unexplained-EAGAIN retry attempts (backend
                               # fault, not a busy continuation)
    timeouts: int = 0          # held messages that exhausted their retry
                               # budget (or met a dead backend with no
                               # failover): dropped with pages freed
    failovers: int = 0         # held messages re-routed to their rule's
                               # failover backend after the primary tripped
    # deficit-round-robin state (scheduler="drr"): the channel's current
    # byte deficit — grows by quantum_bytes per round while backlogged,
    # shrinks by the logical bytes each serviced message accepted, resets
    # when the channel goes idle (classic DRR)
    deficit: float = 0.0
    # per-quantum wall-clock latency (batched rounds charge the amortized
    # share of the round's single data-plane pass)
    latency: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram)


def _jitter(name: str, tries: int, spread: int = 4) -> int:
    """Deterministic backoff jitter in [0, spread): keyed blake2b over the
    (channel name, attempt) pair, so concurrent channels de-synchronise
    their retry storms without a shared RNG stream. Keyed on the *name*
    (stable across runs), not a process-global fileno — chaos runs must
    replay identically."""
    h = hashlib.blake2b(struct.pack("<q", tries) + name.encode(),
                        digest_size=2)
    return struct.unpack("<H", h.digest())[0] % spread


@dataclasses.dataclass
class _HeldSend:
    """One routed message whose transmit could not start (backend EAGAIN,
    reset, or an injected fault): held on the channel and retried on later
    quanta. ``tries``/``wait``/``age`` drive the bounded-retry loop —
    *organic* EAGAINs (the backend is busy with another flow's truncated
    message, which provably drains) retry every quantum forever, exactly
    as the pre-fault-tolerance runtime did; *unexplained* EAGAINs (the
    socket is writable yet the send failed — a fault) are counted against
    ``max_retries`` with exponential backoff."""
    out: object                    # the composed outgoing buffer
    dst: LibraSocket               # current destination (failover may move it)
    logical: int                   # logical size (the DRR cost peek)
    rule: int = -1                 # policy row that routed it (failover lookup)
    tries: int = 0                 # unexplained attempts so far
    wait: int = 0                  # backoff quanta before the next attempt
    age: int = 0                   # quanta since first held (retry_timeout)


class ProxyChannel:
    """One proxied flow through the L7 proxy."""

    def __init__(self, src: LibraSocket,
                 dst: Union[LibraSocket, Sequence[LibraSocket]], *,
                 router: Optional[Router] = None,
                 rewrite: Optional[Rewrite] = None,
                 policy=None,
                 recv_buf: int = 1 << 20,
                 budget: Optional[int] = None,
                 priority: int = 0,
                 name: Optional[str] = None,
                 backpressure: bool = True,
                 max_retries: Optional[int] = 8,
                 retry_timeout: Optional[int] = None):
        self.src = src
        self.dsts: List[LibraSocket] = (
            list(dst) if isinstance(dst, (list, tuple)) else [dst])
        self.router = router      # (buf, logical) -> backend socket
        self.rewrite = rewrite    # (buf, logical) -> outgoing buffer
        # offloaded L7 routing: a PolicyTable whose verdicts replace the
        # rewrite/router callbacks for matched messages. Batched rounds
        # compute verdicts in recv_batch's fused match pass; scalar quanta
        # (and batched fallbacks) resolve through the same table in Python.
        # PUNT verdicts fall through to the callbacks above — they are the
        # slow path the offload keeps, not a competing mechanism.
        self.policy = policy
        self._pending_verdict = None   # verdict parked by the fused pass
        self.recv_buf = recv_buf
        self.budget = budget
        self.priority = priority
        self.name = name or f"ch{src.fileno()}"
        self.backpressure = backpressure
        self.stats = ChannelStats()
        self._inflight: Optional[LibraSocket] = None
        # reassembly of a selective-copy message that needed several recv
        # calls (recv_buf smaller than metadata+VPI, or capped logical)
        self._rx_parts: List[np.ndarray] = []
        self._rx_logical = 0
        # message routed to a backend whose send buffer was busy with
        # another flow's truncated message (EAGAIN): retried next quantum
        self._held: Optional[_HeldSend] = None
        # bounded-retry knobs for UNEXPLAINED send failures (faults) —
        # organic busy-backend EAGAINs stay hold-forever (they drain):
        # after max_retries unexplained attempts (or retry_timeout held
        # quanta, when set) the message is dropped with its pages freed
        # and counted in ChannelStats.timeouts
        self.max_retries = max_retries
        self.retry_timeout = retry_timeout
        self._dst_index = {d.fileno(): i for i, d in enumerate(self.dsts)}
        self._route_rule = -1    # policy row behind the message being sent
        # set by ready() when backpressure (alone) kept the channel out of
        # the ready set this round — the scheduler's liveness fallback
        self._bp_paused = False

    def ready(self) -> bool:
        self._bp_paused = False
        # outbound work (a truncated or held message) outlives the client
        # connection — §A.4 teardown lets the frame finish transmitting
        if self._inflight is not None or self._held is not None:
            return True
        if self.src.closed:
            return False
        if self._rx_parts:
            return True
        if not self.src.poll() & Events.READABLE:
            return False
        # L7 policy: wait for a parseable frame rather than forwarding the
        # unframed prefix of a message still arriving (raw unparseable
        # streams — need_more False — still flow through as full copies)
        if self.src.needs_more_data():
            return False
        # pool backpressure: a frame that would anchor waits while the pool
        # sits above its watermark — egress quanta drain it — instead of
        # overflowing into the §A.1 full-copy drain path
        if self.backpressure and self.src.next_frame_selective() \
                and self.src.stack.above_watermark():
            self._bp_paused = True
            self.stats.bp_pauses += 1
            return False
        return True

    def next_cost(self) -> Optional[int]:
        """Logical size of the head-of-line work item — the DRR "packet
        size" peek: the remaining pending message on a continuation, the
        held (EAGAIN) message, the capped logical remainder of a message
        mid-delivery, or the next parseable frame's logical length
        (memoised parse; no extra window scan). A channel that is ready
        always gets a finite cost (None only when nothing is pending), so
        credit accumulation always converges on an affordable message."""
        if self._inflight is not None:
            p = self._inflight.pending_send
            return max(p.logical - p.accepted, 1) if p is not None else 1
        if self._held is not None:
            # the logical size recorded at hold time — the composed buffer
            # is [meta..., VPI], far smaller than the bytes the transmit
            # will be charged
            return max(self._held.logical, 1)
        if self.src.closed:
            return None
        sm = self.src.connection.rx_machine
        if sm.state is St.FAST_PATH and not sm.complete():
            # recv_buf-capped logical remainder (reassembly in progress)
            return max(sm.payload_len - sm.payload_consumed, 1)
        res = self.src.parse_pending()
        if res.ok:
            return max(res.meta_len + max(res.payload_len, 0), 1)
        avail = self.src.rx_available()
        if avail:
            return min(avail, self.recv_buf)
        return 1 if self._rx_parts else None

    def _mid_message(self) -> bool:
        """True while the RX machine is inside one selective-copy message
        (deferred VPI, or logical length capped by recv_buf)."""
        sm = self.src.connection.rx_machine
        if sm.state is St.METADATA_PARSED:
            return True
        return sm.state is St.FAST_PATH and not sm.complete()

    def service(self) -> bool:
        """One quantum of work; returns True if progress was made."""
        t0 = time.perf_counter()
        try:
            return self._service()
        finally:
            self.stats.latency.record(time.perf_counter() - t0)

    def _service(self) -> bool:
        self.stats.quanta += 1
        if self._inflight is not None:
            return self._continue_send()
        if self._held is not None:
            h = self._held
            if h.wait > 0:
                # waiting out an exponential-backoff window IS progress
                # toward the bounded retry (and keeps run() alive while
                # every other channel is also waiting out a fault)
                h.wait -= 1
                h.age += 1
                return True
            if self.retry_timeout is not None and h.age >= self.retry_timeout:
                self._held = None
                return self._expire_held(h)
            self._held = None
            nd = self._failover_dst(h)
            if nd is not None:
                h.dst = nd
                h.tries = 0          # a healthy failover gets a fresh budget
                self.stats.failovers += 1
            return self._start_send(h.out, h.dst, h.logical, held=h)
        try:
            buf, logical = self.src.recv(self.recv_buf)
        except RecordAuthError:
            # a tampered record was rejected (consumed, nothing anchored):
            # one bad flow must not abort the event loop — mirror the
            # batched path, which drops the bad slot and keeps the round
            # alive. Direct socket users still see the raise.
            self.stats.auth_rejects += 1
            return True
        self.stats.recv_calls += 1
        if logical == 0 and len(buf) == 0:
            return False
        intent = self._ingest(buf, logical)
        if intent is None:
            return True          # fragment absorbed: progress
        if intent is _IDLE:
            return False
        return self._start_send(*intent)

    def _ingest(self, buf: np.ndarray, logical: int):
        """Post-recv half of a quantum: reassembly, rewrite, routing.
        Returns ``(out, dst, logical)`` when a whole message is ready to
        transmit, ``None`` when a fragment was absorbed, ``_IDLE`` on no
        progress."""
        if self._mid_message():
            # fragment of one message: reassemble before routing, so the
            # whole message goes to ONE backend in one send
            self._rx_parts.append(buf)
            self._rx_logical += logical
            return None
        if self._rx_parts:
            self._rx_parts.append(buf)
            buf = np.concatenate(self._rx_parts)
            logical += self._rx_logical
            self._rx_parts, self._rx_logical = [], 0
        if logical == 0:
            return _IDLE
        self._route_rule = -1
        if self.policy is not None:
            v, self._pending_verdict = self._pending_verdict, None
            if v is None:
                # scalar quantum (or batched fallback): same table, Python
                # resolution — the slow path the offload keeps. Payload-
                # prefix conditions peek the anchored first page through
                # the host mirror, matching the fused kernel's window.
                st = self.src.stack
                payload, plen = (None, 0)
                if getattr(self.policy, "has_payload_conds", False):
                    payload, plen = st._policy_window(buf, self.src)
                v = self.policy.decide(
                    buf, parser=self.src.parser,
                    crypto=self.src.connection.crypto is not None,
                    now=st.now_tick, counters=st.counters,
                    payload=payload, payload_len=plen)
            intent = self._apply_verdict(v, buf, logical)
            if intent is not _PUNT:
                return intent
        out = self.rewrite(buf, logical) if self.rewrite else buf
        dst = self.router(buf, logical) if self.router else self.dsts[0]
        if dst is None:
            # the router declined the message (the Python baseline's DROP):
            # consume it and free its anchored pages — the same path a
            # DROP verdict takes, so baselines stay byte/page-identical
            return self._drop(buf)
        return out, dst, logical

    def _apply_verdict(self, v: Verdict, buf: np.ndarray, logical: int):
        """Turn a fused-pass (or scalar-path) policy verdict into a
        transmit intent: FORWARD → ``(out, dst, logical)`` with REWRITE
        patches applied to a copy, DROP → consume and free, PUNT (including
        a backend index this channel does not have) → the ``_PUNT``
        sentinel, handing the message to the classic callbacks."""
        counters = self.src.stack.counters
        if v.kind == "forward" and v.backend >= len(self.dsts):
            v = Verdict("punt", rule=v.rule, reason=PUNT_BAD_BACKEND)
        self.policy.note_outcome(v)
        if v.kind == "forward":
            counters.policy_hits += 1
            self._route_rule = v.rule   # held-send failover consults the row
            out = buf
            if v.rewrites:
                out = np.array(buf)
                for slot, value in v.rewrites:
                    out[slot] = value
            return out, self.dsts[v.backend], logical
        if v.kind == "drop":
            counters.policy_drops += 1
            return self._drop(buf)
        counters.policy_punts += 1
        return _PUNT

    def _drop(self, buf: np.ndarray):
        """Consume a delivered message without transmitting: release its
        anchor (pages straight back to the freelist) and report the
        fragment-absorbed intent (``None`` = progress, nothing to send)."""
        self.src.stack.drop_message(buf, self.src)
        self.stats.drops += 1
        return None

    # -- fault-tolerant send path --------------------------------------------
    def _fault_for(self, dst: LibraSocket) -> Optional[str]:
        """Consult the stack's installed FaultPlan (if any) for an injected
        send fault toward this destination. Deterministic within a step, so
        the batched tile and the scalar path agree."""
        plan = getattr(self.src.stack, "fault_plan", None)
        if plan is None:
            return None
        return plan.send_fault(self._backend_index(dst), self.name)

    def _backend_index(self, dst: LibraSocket) -> int:
        return self._dst_index.get(dst.fileno(), -1)

    def _health(self):
        return getattr(self.policy, "health", None) \
            if self.policy is not None else None

    def _note_backend_failure(self, dst: LibraSocket) -> None:
        h = self._health()
        if h is not None:
            h.note_failure(self._backend_index(dst), self.src.stack.now_tick)

    def _note_backend_success(self, dst: LibraSocket) -> None:
        h = self._health()
        if h is not None:
            h.note_success(self._backend_index(dst))

    def _failover_dst(self, h: _HeldSend) -> Optional[LibraSocket]:
        """The healthy failover destination for a held message whose
        primary backend has tripped (or died); None when the primary is
        still allowed, or no usable failover exists."""
        pol = self.policy
        health = self._health()
        if health is None or h.rule is None or h.rule < 0:
            return None
        cur = self._backend_index(h.dst)
        if cur >= 0 and health.healthy(cur) and not h.dst.closed:
            return None              # primary still admissible: keep it
        fo = pol.failover_for(h.rule)
        if fo < 0 or fo >= len(self.dsts) or fo == cur:
            return None
        d = self.dsts[fo]
        if d.closed or not health.healthy(fo):
            return None
        return d

    def _expire_held(self, h: _HeldSend) -> bool:
        """Bounded-retry expiry: the message is undeliverable — free its
        anchored pages and count the timeout (the alternative, the classic
        hold-forever EAGAIN loop, wedges the channel and leaks the pages
        against a permanently dead backend)."""
        self.src.stack.drop_message(np.asarray(h.out, np.int64), self.src)
        self.stats.timeouts += 1
        return True

    def _dead_dst(self, out, dst: LibraSocket, logical: Optional[int],
                  held: Optional[_HeldSend]) -> bool:
        """A send met a closed backend (connection reset, or its worker
        was killed): note the failure, re-route to the rule's healthy
        failover when one exists, otherwise drop with pages freed."""
        self._note_backend_failure(dst)
        h = held if held is not None else _HeldSend(
            out, dst, logical if logical is not None else len(out),
            rule=self._route_rule)
        nd = self._failover_dst(h)
        if nd is not None:
            h.dst = nd
            h.tries = 0
            self.stats.failovers += 1
            return self._start_send(h.out, nd, h.logical, held=h)
        return self._expire_held(h)

    def _start_send(self, out, dst: LibraSocket,
                    logical: Optional[int] = None,
                    held: Optional[_HeldSend] = None) -> bool:
        fault = self._fault_for(dst)
        if fault == "reset" and not dst.closed:
            # injected connection reset: the first send finds the backend
            # gone — close it so every later attempt (any channel) agrees
            dst.close()
        if dst.closed:
            return self._dead_dst(out, dst, logical, held)
        if fault == "eagain" and dst.pending_send is None:
            # injected stall: the socket is writable, so this EAGAIN has no
            # organic cause — counted against the retry budget
            return self._note_send_outcome(dst, 0, out, eagain=True,
                                           logical=logical, held=held,
                                           injected=True)
        try:
            n = self.src.forward(dst, out, budget=self.budget)
        except BlockingIOError:
            return self._note_send_outcome(dst, 0, out, eagain=True,
                                           logical=logical, held=held)
        return self._note_send_outcome(dst, n, out, held=held)

    def _note_send_outcome(self, dst: LibraSocket, n: int, out,
                           eagain: bool = False,
                           logical: Optional[int] = None,
                           held: Optional[_HeldSend] = None,
                           injected: bool = False) -> bool:
        """Shared bookkeeping for scalar and batched transmits."""
        if eagain:
            h = held if held is not None else _HeldSend(
                out, dst, logical if logical is not None else len(out),
                rule=self._route_rule)
            h.out, h.dst = out, dst
            h.age += 1
            if injected or (dst.pending_send is None and not dst.closed):
                # unexplained EAGAIN — no busy continuation to wait out: a
                # backend fault. Bounded retries with exponential backoff;
                # organic EAGAINs below stay hold-forever (they drain).
                h.tries += 1
                self.stats.retries += 1
                self._note_backend_failure(dst)
                if self.max_retries is not None \
                        and h.tries > self.max_retries:
                    nd = self._failover_dst(h)
                    if nd is not None:
                        h.dst, h.tries, h.wait = nd, 0, 0
                        self.stats.failovers += 1
                        self._held = h
                        return True
                    return self._expire_held(h)
                h.wait = min(1 << (h.tries - 1), 64) \
                    + _jitter(self.name, h.tries)
                # scheduling the bounded retry IS progress — without it a
                # round where every channel meets an injected fault would
                # look idle and run() would exit with messages still held
                self._held = h
                return True
            self._held = h
            return False
        self.stats.send_calls += 1
        self.stats.logical_bytes += n
        if dst.pending_send is not None:
            self._inflight = dst
            self.stats.partial_sends += 1
        else:
            self.stats.messages += 1
            self._note_backend_success(dst)
        return True

    def _continue_send(self) -> bool:
        dst = self._inflight
        if dst.closed:
            # the backend died mid-continuation (reset / worker kill): the
            # partially-accepted message cannot complete — abandon it (the
            # destination's teardown already entered its grace period; the
            # source anchor drains at close)
            self._inflight = None
            self.stats.timeouts += 1
            self._note_backend_failure(dst)
            return True
        n = dst.send(budget=self.budget)
        self.stats.send_calls += 1
        self.stats.logical_bytes += n
        if dst.pending_send is None:
            self._inflight = None
            self.stats.messages += 1
            self._note_backend_success(dst)
        else:
            self.stats.partial_sends += 1
        return n > 0


class ProxyRuntime:
    """Readiness-set scheduler over one stack's channels.

    Scheduling policies: ``round-robin`` (rotating fairness over ready
    channels), ``priority`` (strict order by ``ProxyChannel.priority``),
    and ``drr`` — weighted-fair deficit round robin: every ready channel
    earns ``quantum_bytes`` of deficit per round and services head-of-line
    messages while its deficit covers them, so flows with 10:1 message
    sizes still converge to ~equal *byte* shares (a pure quantum-per-round
    scheduler gives them 10:1 bytes). DRR is a scalar-quanta policy —
    batched rounds fuse the whole ready set into one data-plane pass and
    have no per-message service order to weight."""

    SCHEDULERS = ("round-robin", "priority", "drr")

    def __init__(self, stack: LibraStack, *, scheduler: str = "round-robin",
                 tick_every: int = 16, batched: bool = False,
                 batch_impl: str = "host",
                 batch_tile: Optional[int] = None,
                 quantum_bytes: int = 1024,
                 policy=None,
                 fault_plan=None):
        assert scheduler in self.SCHEDULERS, scheduler
        assert not (batched and scheduler == "drr"), \
            "drr is a scalar-quanta policy (batched rounds fuse the ready set)"
        self.stack = stack
        # runtime-wide L7 PolicyTable: channels registered without their own
        # table inherit it, and batched rounds whose whole tile shares it
        # fuse the match into recv_batch's data-plane pass
        self.policy = policy
        self.scheduler = scheduler
        self.quantum_bytes = quantum_bytes
        self.tick_every = tick_every
        self.batched = batched
        # recv_batch/forward_batch data plane ('host', a kernel impl, or
        # 'fused-round[:impl]' for one-kernel scheduling rounds)
        self.batch_impl = batch_impl
        # channels fused per recv/forward pass: one round is processed in
        # tiles so a tile's anchored pages are transmitted while still
        # cache-hot. None (default) = adaptive — the tile is sized each
        # round from the ready set's live footprint (message pages ×
        # page_size vs the pool's cache budget), so tiny messages fuse by
        # the hundred while page-heavy rounds fall back to small tiles;
        # an int pins the tile (0 = whole round in one pass)
        self.batch_tile = batch_tile
        # chaos harness: a FaultPlan driven once per scheduling round (and
        # installed on the stack so the socket/channel hooks see it)
        self.fault_plan = fault_plan
        if fault_plan is not None:
            fault_plan.install(stack)
        self.channels: List[ProxyChannel] = []
        self.rounds = 0
        self._rr = 0

    # -- registration --------------------------------------------------------
    def register(self, channel: ProxyChannel) -> ProxyChannel:
        if channel.policy is None:
            channel.policy = self.policy
        self.channels.append(channel)
        return channel

    def channel(self, src: LibraSocket, dst, **kw) -> ProxyChannel:
        """Create and register a channel in one call. The default name is
        the registration ordinal (stable across identical runs — fault
        coins and backoff jitter key on it), not the process-global fd."""
        kw.setdefault("name", f"ch{len(self.channels)}")
        return self.register(ProxyChannel(src, dst, **kw))

    # -- scheduling ----------------------------------------------------------
    def poll(self, skip=None) -> List[ProxyChannel]:
        """The ready set, ordered by the active scheduling policy.
        ``skip`` excludes channels already serviced elsewhere this round
        (cluster work stealing)."""
        ready = [c for c in self.channels if c.ready()
                 and (skip is None or c not in skip)]
        if not ready:
            return ready
        if self.scheduler == "priority":
            return sorted(ready, key=lambda c: -c.priority)
        k = self._rr % len(ready)
        return ready[k:] + ready[:k]

    def step(self, skip=None, ready=None) -> int:
        """One scheduling round: give each ready channel one quantum (with
        ``batched=True``, one fused recv/forward pass for the whole ready
        set; with ``scheduler='drr'``, as many head-of-line messages as
        the channel's byte deficit covers). Returns the number of channels
        that made progress. ``skip`` excludes channels a cluster thief
        already serviced this round; ``ready`` supplies a ready set the
        caller already polled (ClusterRuntime), so channels are not
        readiness-evaluated twice per round."""
        if ready is None:
            ready = self.poll(skip)
        progressed = (self._step_batched(ready) if self.batched
                      else self._step_scalar(ready))
        if progressed == 0:
            # liveness: if backpressure alone paused the remaining work and
            # nothing else can free pool pages, admit the paused channels —
            # worst case they overflow into §A.1 drain, exactly as without
            # backpressure
            for ch in self.channels:
                if ch._bp_paused and (skip is None or ch not in skip):
                    ch._bp_paused = False
                    progressed += bool(ch.service())
        self.rounds += 1
        self._rr += 1
        if self.tick_every and self.rounds % self.tick_every == 0:
            self.stack.tick()
            h = getattr(self.policy, "health", None) \
                if self.policy is not None else None
            if h is not None:
                # advance the circuit-breaker clock with the stack's: due
                # UNHEALTHY backends move to HALF_OPEN (probe allowed)
                h.tick(self.stack.now_tick)
        if self.fault_plan is not None:
            self.fault_plan.on_tick(self)
        return progressed

    def _step_scalar(self, ready) -> int:
        if self.scheduler == "drr":
            return self._step_drr(ready)
        progressed = 0
        for ch in ready:
            progressed += bool(ch.service())
        return progressed

    def _step_drr(self, ready) -> int:
        """Deficit round robin: each ready channel earns ``quantum_bytes``
        and services whole head-of-line messages while the deficit covers
        their logical size — byte-fair across heterogeneous message
        sizes."""
        progressed = 0
        accumulating = 0
        for ch in ready:
            st = ch.stats
            st.deficit += self.quantum_bytes
            serviced = False
            while True:
                cost = ch.next_cost()
                if cost is None or cost > st.deficit:
                    break
                before = st.logical_bytes
                ok = ch.service()
                serviced = True
                charged = st.logical_bytes - before
                # charge ONLY bytes actually accepted: an EAGAIN-held or
                # fragment-absorbing quantum keeps its credit and pays the
                # real bytes when the message finally transmits (charging
                # the estimate here would bill such messages twice and
                # starve EAGAIN-prone flows of their byte-fair share) —
                # but a zero-byte quantum ends the inner loop, so the
                # deficit always drains across rounds
                if charged > 0:
                    st.deficit -= charged
                progressed += bool(ok)
                if not ok or charged == 0 or not ch.ready():
                    break
            if not ch.ready():
                st.deficit = 0.0   # classic DRR: going idle forfeits credit
            elif not serviced:
                accumulating += 1
        if progressed == 0 and accumulating:
            # a head-of-line message larger than quantum_bytes needs
            # several rounds of credit before it becomes affordable —
            # accumulating deficit IS forward progress (the deficit grows
            # by a positive quantum per round, so the message is reached
            # in finitely many rounds); without this, run()'s idle
            # detection would stop on the first credit-only round and
            # never forward it
            progressed = 1
        return progressed

    def _step_batched(self, ready) -> int:
        progressed = 0
        batch: List[ProxyChannel] = []
        for ch in ready:
            # edge states keep their scalar quantum (continuations, held
            # messages, reassembly in progress)
            if ch._inflight is not None or ch._held is not None \
                    or ch._rx_parts or ch.src.closed:
                progressed += bool(ch.service())
            else:
                batch.append(ch)
        # one fused recv/forward pass per tile: a tile's anchored pages are
        # forwarded while still cache-hot instead of after the whole round
        if self.batch_tile is None:
            tile = self._adaptive_tile(batch)
        else:
            tile = self.batch_tile if self.batch_tile > 0 else len(batch)
        tile = max(tile, 1)
        for i in range(0, len(batch), tile):
            progressed += self._service_tile(batch[i : i + tile])
        return progressed

    def _adaptive_tile(self, batch: List[ProxyChannel]) -> int:
        """Tile size from the round's live footprint, via the pool's one
        footprint→tile policy (:meth:`TokenPool.tile_for_footprint`), so
        round tiling and the pool's internal scatter/gather tiling never
        desynchronize. Uses the memoised parse results, so sizing costs no
        extra window scans."""
        page = self.stack.alloc.page_size
        pages = n = 0
        for ch in batch:
            res = ch.src.parse_pending()
            if res.ok and res.payload_len > 0:
                pages += -(-res.payload_len // page)
                n += 1
        if n == 0:
            return max(len(batch), 1)
        return self.stack.pool.tile_for_footprint(pages, n,
                                                  cap=max(len(batch), 1))

    def _service_tile(self, batch: List[ProxyChannel]) -> int:
        if not batch:
            return 0
        progressed = 0
        # fuse the L7 match into the recv pass only when the whole tile
        # shares ONE table (mixed tables would double-debit token buckets);
        # channels with their own tables still resolve in _ingest
        pol = self.policy
        if pol is not None and not all(ch.policy is pol for ch in batch):
            pol = None
        # fused one-kernel rounds speculate each flow's egress: hint the
        # primary destination so the fused gather TX-encrypts in the same
        # launch (forward_batch validates the guess — policy reroutes and
        # failovers simply miss the cache and pay the classic gather)
        hints = None
        if self.batch_impl.startswith("fused-round"):
            hints = {ch.src.fileno(): ch.dsts[0] for ch in batch if ch.dsts}
        t0 = time.perf_counter()
        results = self.stack.recv_batch(
            [ch.src for ch in batch],
            {ch.src.fileno(): ch.recv_buf for ch in batch},
            impl=self.batch_impl, policy=pol, tx_hints=hints)
        # data-plane time only: scalar fallbacks below record their own
        # quanta and must not inflate the batched channels' share
        dp_elapsed = time.perf_counter() - t0
        sends, senders, logicals = [], [], []
        n_batched = 0
        for ch in batch:
            r = results.get(ch.src.fileno())
            # pop the fused pass's verdict (if any); messages mid-
            # reassembly keep it parked on the channel until the last
            # fragment arrives — the match ran on the full metadata
            v = ch.src._policy_verdict
            ch.src._policy_verdict = None
            if r is not None and v is not None:
                ch._pending_verdict = v
            if r is None:
                if ch.src._auth_rejected:
                    # the auth sweep dropped this channel's record: count
                    # the reject on the channel, exactly as the scalar
                    # path's RecordAuthError handling does
                    ch.src._auth_rejected = False
                    ch.stats.auth_rejects += 1
                    progressed += 1
                    continue
                # the batch filled the pool past the watermark before this
                # channel's turn: pause it (backpressure) instead of letting
                # the scalar fallback overflow into §A.1 drain
                if ch.backpressure and self.stack.above_watermark() \
                        and ch.src.next_frame_selective():
                    ch._bp_paused = True
                    ch.stats.bp_pauses += 1
                    continue
                # not admissible this round (drain, short/unparseable frame,
                # exhaustion, tiny recv_buf, ...): scalar fallback quantum
                progressed += bool(ch.service())
                continue
            n_batched += 1
            ch.stats.quanta += 1
            ch.stats.recv_calls += 1
            intent = ch._ingest(*r)
            if intent is None:
                progressed += 1          # capped fragment absorbed
                continue
            if intent is _IDLE:
                continue
            out, dst, logical = intent
            if dst.closed or ch._fault_for(dst) is not None:
                # faulted or dead backend: the scalar send path owns the
                # retry/failover machinery (the fault coin is keyed per
                # step, so this consult and _start_send's agree)
                progressed += bool(ch._start_send(out, dst, logical))
                continue
            sends.append((ch.src, dst, out, ch.budget))
            senders.append(ch)
            logicals.append(logical)
        if sends:
            t1 = time.perf_counter()
            outcomes = self.stack.forward_batch(sends, impl=self.batch_impl)
            dp_elapsed += time.perf_counter() - t1
            for (ch, (_src, dst, out, _b), (status, n), logical) in zip(
                    senders, sends, outcomes, logicals):
                progressed += bool(
                    ch._note_send_outcome(dst, n, out,
                                          eagain=(status == SEND_EAGAIN),
                                          logical=logical))
        if n_batched:
            # charge each participant its amortized share of the tile's
            # fused recv/forward passes
            share = dp_elapsed / n_batched
            for ch in batch:
                if results.get(ch.src.fileno()) is not None:
                    ch.stats.latency.record(share)
        return progressed

    def run(self, max_rounds: int = 10 ** 6) -> int:
        """Loop until no channel is ready (or ``max_rounds``). Returns the
        total number of messages forwarded across all channels."""
        rounds = 0
        while rounds < max_rounds:
            if self.step() == 0:
                break
            rounds += 1
        return self.messages_forwarded()

    def shutdown(self) -> int:
        """Close every channel endpoint and flush all grace periods.
        Returns the number of pages reclaimed by deferred teardown."""
        if self.fault_plan is not None:
            self.fault_plan.release_all()
        for ch in self.channels:
            ch.src.close()
            for d in ch.dsts:
                d.close()
        return self.stack.drain()

    # -- telemetry -----------------------------------------------------------
    def messages_forwarded(self) -> int:
        return sum(c.stats.messages for c in self.channels)

    def logical_bytes(self) -> int:
        return sum(c.stats.logical_bytes for c in self.channels)

    def latency_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-channel quantum latency summary: name -> {count, mean, p50,
        p99} (seconds)."""
        return {c.name: c.stats.latency.summary() for c in self.channels}
