"""Stream-level substrate for the Libra core: connections + token payload pool.

This is the protocol-agnostic layer the paper's Figure 3(b) describes,
expressed over int64 token streams (1 token = 8 bytes, so a VPI occupies
exactly one stream slot). The serving engine reuses the same machinery with
KV pages as the anchored payload; this layer anchors raw token payloads so
the core can be tested and benchmarked in isolation.

Datapath invariants kept allocation-free:

* :class:`RxRing` — the receive queue is an amortized growable ring, not a
  reallocate-on-every-deliver array: ``push`` appends into spare tail
  capacity, the dead prefix is reclaimed by sliding (never by reallocating)
  once it dominates the live region, and ``peek``/``window`` hand out
  zero-copy views.
* :class:`TokenPool` — payload placement/readback are single reshaped
  scatter/gather ops (no per-page Python loop), with batched variants that
  fuse a whole recv/forward round into one indexed assignment, tiled
  adaptively by live footprint (:meth:`TokenPool.batch_tile`). The pool
  carries the one scratch row :attr:`AnchorPool.scratch_page` reserves so
  the fused device kernel needs no per-call pool copy. The device-resident
  variant (:class:`repro.core.device_pool.DevicePool`, the stack default)
  keeps the pool on the device across batched rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.anchor_pool import AnchorPool, PageRef
from repro.core.parser import ParserPolicy
from repro.core.state_machine import RxStateMachine, St, TxStateMachine
from repro.core.vpi import VpiRegistry


class RxRing:
    """Amortized growable receive ring (the skb queue analogue).

    Tokens live in ``_buf[_head:_tail]``. ``push`` writes into the spare
    tail; when the tail hits capacity the live region slides to the front
    (reclaiming the dead prefix) and the buffer only reallocates — by
    doubling — when the live data itself outgrows it. ``advance`` also
    compacts once the dead prefix exceeds the live region (proportional
    policy: no fixed 64Ki threshold, so small-queue workloads never retain
    dead prefixes indefinitely; tune with ``min_compact``).

    ``peek``/views are zero-copy and remain valid until the next
    ``push``/``advance`` on this ring (both may slide the buffer).
    """

    __slots__ = ("_buf", "_head", "_tail", "consumed", "delivered",
                 "min_compact")

    def __init__(self, capacity: int = 256, min_compact: int = 64):
        self._buf = np.zeros((max(capacity, 16),), np.int64)
        self._head = 0
        self._tail = 0
        self.consumed = 0    # total tokens ever advanced past (monotonic)
        self.delivered = 0   # total tokens ever pushed (monotonic)
        self.min_compact = min_compact

    def __len__(self) -> int:
        return self._tail - self._head

    @property
    def capacity(self) -> int:
        return len(self._buf)

    def _slide(self) -> None:
        live = self._tail - self._head
        # numpy slice assignment buffers overlapping copies (>= 1.13)
        self._buf[:live] = self._buf[self._head : self._tail]
        self._head, self._tail = 0, live

    def push(self, data: np.ndarray) -> None:
        n = len(data)
        if n == 0:
            return
        if self._tail + n > len(self._buf):
            live = self._tail - self._head
            if live + n > len(self._buf):
                grown = np.zeros((max(len(self._buf) * 2, live + n),), np.int64)
                grown[:live] = self._buf[self._head : self._tail]
                self._buf = grown
                self._head, self._tail = 0, live
            else:
                self._slide()
        self._buf[self._tail : self._tail + n] = data
        self._tail += n
        self.delivered += n

    def peek(self, n: int) -> np.ndarray:
        """Zero-copy view of up to ``n`` buffered tokens."""
        return self._buf[self._head : min(self._head + n, self._tail)]

    def advance(self, n: int) -> None:
        assert self._head + n <= self._tail, (n, len(self))
        self._head += n
        self.consumed += n
        # proportional compaction: reclaim once the dead prefix dominates
        # the live region (each token moves at most O(1) times, amortized)
        if self._head >= self.min_compact and self._head > self._tail - self._head:
            self._slide()

    def fingerprint(self) -> Tuple[int, int]:
        """Content-stable identity of the unread region (survives slides/
        reallocations — used to memoise pure functions of the queue)."""
        return (self.consumed, self.delivered)


class TokenPool:
    """Device-side payload pool stand-in: [n_shards * pages_per_shard, page]
    int64 pages. Payload tokens are written once on ingress (DMA analogue)
    and never moved again.

    The backing array carries one extra row — the scratch page the fused
    selective-copy kernel routes dummy DMAs to (``alloc.scratch_page``) —
    so device dispatch never has to extend the pool per call."""

    def __init__(self, alloc: AnchorPool):
        self.alloc = alloc
        # registry pool-id this pool's anchors are registered under; a
        # multi-worker cluster renames each worker's pool so grant entries
        # can name (and egress can route to) the owning worker's pool
        self.pool_id = "token-pool"
        total = alloc.n_shards * alloc.pages_per_shard
        self._flat = np.zeros((total + 1, alloc.page_size), np.int64)
        # real pages view: writes through to the same storage
        self._data_view = self._flat[:total].reshape(
            alloc.n_shards, alloc.pages_per_shard, alloc.page_size)
        # host<->device traffic telemetry (tokens). ``pool_syncs`` counts
        # O(pool)-sized boundary crossings — the failure mode the resident
        # :class:`~repro.core.device_pool.DevicePool` eliminates; this host
        # pool pays one per device-impl round (see anchor_batch_device).
        self.xfer: Dict[str, int] = {"h2d_tokens": 0, "d2h_tokens": 0,
                                     "pool_syncs": 0, "device_rounds": 0,
                                     "resident_init_tokens": 0,
                                     # ingress (anchoring) device rounds,
                                     # and how many of them verifiably
                                     # consumed the donated input pool
                                     # buffer (outer-jit donate_argnums —
                                     # exactly one pool allocation stays
                                     # live per round): donated == anchor
                                     # on backends that honour donation
                                     # (CPU/TPU do)
                                     "anchor_rounds": 0,
                                     "donated_rounds": 0,
                                     # one-kernel rounds: fused_rounds
                                     # counts single-launch scheduling
                                     # rounds (anchor + crypto + policy +
                                     # gather in ONE device_rounds bump);
                                     # policy_match_rounds counts the
                                     # standalone device match launches
                                     # the fused path eliminates
                                     "fused_rounds": 0,
                                     "policy_match_rounds": 0,
                                     # forward_batch consumed a fused
                                     # round's speculative TX gather
                                     # output (no gather launch needed)
                                     "tx_spec_hits": 0}

    @property
    def data(self) -> np.ndarray:
        """[n_shards, pages_per_shard, page] view of the host pool (writes
        through to the same storage)."""
        return self._data_view

    @property
    def flat_with_scratch(self) -> np.ndarray:
        """[total_pages + 1, page] flat view; row ``alloc.scratch_page`` is
        the reserved kernel scratch row (contents undefined)."""
        return self._flat

    def _page_coords(self, pages: Sequence[PageRef], length: int,
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(dest flat indices, source payload positions) for every in-range
        token of ``pages`` — one vectorized index computation, no per-page
        loop on the data itself."""
        ps = self.alloc.page_size
        pps = self.alloc.pages_per_shard
        coords = np.array([(pg.shard * pps + pg.local_pid, pg.base_pos)
                           for pg in pages], np.int64).reshape(-1, 2)
        off = np.arange(ps)
        src = coords[:, 1:] + off[None, :]            # [n_pages, ps]
        mask = src < length
        dest = coords[:, :1] * ps + off[None, :]
        return dest[mask], src[mask]

    def write_payload(self, pages: List[PageRef], payload: np.ndarray,
                      keystream: Optional[np.ndarray] = None) -> None:
        """Anchor a payload with one reshaped scatter. ``keystream`` fuses
        the kTLS-analogue hw-mode cipher into that same pass: the XOR runs
        on the gathered values inside the placement (no decrypted copy of
        the payload ever exists outside the pool)."""
        n = len(payload)
        if n == 0 or not pages:
            return
        dest, src = self._page_coords(pages, n)
        vals = np.asarray(payload)[src]
        if keystream is not None:
            vals = np.bitwise_xor(vals, np.asarray(keystream)[src])
        self._flat.reshape(-1)[dest] = vals

    def read_payload(self, pages: List[PageRef], length: int,
                     keystream: Optional[np.ndarray] = None) -> np.ndarray:
        """Gather an anchored payload in one pass; ``keystream`` fuses the
        hw-mode TX cipher into the gather (the NIC-inline encrypt)."""
        out = np.zeros((length,), np.int64)
        if length and pages:
            dest, src = self._page_coords(pages, length)
            vals = self._flat.reshape(-1)[dest]
            if keystream is not None:
                vals = np.bitwise_xor(vals, np.asarray(keystream)[src])
            out[src] = vals
        return out

    # -- batched data plane (one fused pass per scheduling round) -----------

    #: bytes of cache one scatter/gather tile aims to stay inside: a tile's
    #: live footprint (page values + the int32 index temporaries, ~16 bytes
    #: per token) should remain L2-resident while it is built and consumed.
    #: The tile size adapts to the round's actual message footprint instead
    #: of a hardcoded message count (tiny messages fuse by the thousand,
    #: page-heavy ones fall back to small tiles).
    cache_budget = 1 << 20

    def tile_for_footprint(self, n_pages: int, n_msgs: int,
                           cap: int = 4096) -> int:
        """The one footprint→tile policy (shared by the pool's internal
        scatter/gather tiling and the runtime's round tiling): messages
        per tile such that one tile's pages stay inside
        :attr:`cache_budget` at ~16 bytes/token."""
        if n_msgs == 0 or n_pages == 0:
            return max(n_msgs, 1)
        per_msg = max(n_pages / n_msgs, 1.0) * self.alloc.page_size * 16
        return int(np.clip(self.cache_budget // per_msg, 1, cap))

    def batch_tile(self, seqs: Sequence[Tuple[Sequence[PageRef], object]],
                   ) -> int:
        """Messages fused per scatter/gather tile, sized from the round's
        live footprint (``pages × page_size`` per message vs
        :attr:`cache_budget`)."""
        return self.tile_for_footprint(
            sum(len(pages) for pages, _ in seqs), len(seqs))

    def _batch_coords(self, seqs: Sequence[Tuple[Sequence[PageRef], int]],
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """(dest flat pool indices, positions in the concatenated payload
        stream) for every in-range token of a batch — one pass over the
        page lists, then pure vectorized (int32) indexing."""
        ps = self.alloc.page_size
        pps = self.alloc.pages_per_shard
        lens = np.array([ln for _, ln in seqs], np.int32)
        offs = np.zeros((len(seqs),), np.int32)
        np.cumsum(lens[:-1], out=offs[1:])
        # one flat triple list over every page of the batch
        triples = np.array(
            [(pg.shard * pps + pg.local_pid, pg.base_pos, k)
             for k, (pages, _) in enumerate(seqs) for pg in pages],
            np.int32).reshape(-1, 3)
        rows, base, owner = triples[:, 0], triples[:, 1], triples[:, 2]
        off = np.arange(ps, dtype=np.int32)
        rel = base[:, None] + off[None, :]             # [n_pages, ps]
        mask = rel < lens[owner][:, None]
        dest = (rows[:, None] * ps + off[None, :])[mask]
        pos = (rel + offs[owner][:, None])[mask]
        return dest, pos

    def write_payload_batch(
        self, seqs: Sequence[Tuple[Sequence[PageRef], np.ndarray]],
        keystreams: Optional[Sequence[Optional[np.ndarray]]] = None) -> None:
        """Anchor a whole batch of payloads with one flattened scatter per
        cache-sized tile — the host mirror of the fused kernel's
        single-pass payload placement. ``keystreams`` (aligned with
        ``seqs``, None entries = plaintext) fuses per-message hw-mode
        decryption into the same scatter: one XOR over the concatenated
        batch, no per-message pass."""
        if keystreams is None:
            keystreams = [None] * len(seqs)
        pairs = [(pages, p, ks) for (pages, p), ks in zip(seqs, keystreams)
                 if len(p) and pages]
        flat = self._flat.reshape(-1)
        tile_n = self.batch_tile([(pages, p) for pages, p, _ in pairs])
        for i in range(0, len(pairs), tile_n):
            tile = pairs[i : i + tile_n]
            dest, pos = self._batch_coords(
                [(pages, len(p)) for pages, p, _ in tile])
            cat = np.concatenate([p for _, p, _ in tile])
            vals = cat[pos]
            if any(ks is not None for _, _, ks in tile):
                kcat = np.concatenate(
                    [ks if ks is not None else np.zeros(len(p), np.int64)
                     for _, p, ks in tile])
                vals = np.bitwise_xor(vals, kcat[pos])
            flat[dest] = vals

    def read_payload_batch(
        self, seqs: Sequence[Tuple[Sequence[PageRef], int]],
        keystreams: Optional[Sequence[Optional[np.ndarray]]] = None,
    ) -> List[np.ndarray]:
        """One fused gather per cache-sized tile of anchored payloads;
        returns one array per (pages, length) request. ``keystreams``
        fuses per-message hw-mode TX encryption into the gather."""
        if keystreams is None:
            keystreams = [None] * len(seqs)
        flat = self._flat.reshape(-1)
        outs: List[np.ndarray] = []
        tile_n = self.batch_tile(seqs)
        for i in range(0, len(seqs), tile_n):
            tile = list(seqs[i : i + tile_n])
            kss = list(keystreams[i : i + tile_n])
            lens = [ln for _, ln in tile]
            out = np.zeros((sum(lens),), np.int64)
            if any(ln and pages for pages, ln in tile):
                dest, pos = self._batch_coords(tile)
                vals = flat[dest]
                if any(ks is not None for ks in kss):
                    kcat = np.concatenate(
                        [ks if ks is not None else np.zeros(ln, np.int64)
                         for (_, ln), ks in zip(tile, kss)])
                    vals = np.bitwise_xor(vals, kcat[pos])
                out[pos] = vals
            outs.extend(np.split(out, np.cumsum(lens)[:-1]))
        return outs

    # -- device data plane (fused kernel entry points) -----------------------

    def anchor_batch_device(self, stream: np.ndarray, meta_len: np.ndarray,
                            total_len: np.ndarray, tables: np.ndarray, *,
                            meta_max: int, impl: str,
                            keystream: Optional[np.ndarray] = None) -> None:
        """Run one batched ingress round through the fused selective-copy
        kernel. This host-resident pool pays the legacy price the paper's
        kernel-resident design exists to avoid: the WHOLE pool crosses the
        host/device boundary up (``astype(int32)``) and the touched rows
        sync back — one ``pool_syncs`` event per round. The resident
        :class:`~repro.core.device_pool.DevicePool` overrides this with the
        zero-O(pool) path."""
        import jax.numpy as jnp

        from repro.kernels import ops

        pool = self.flat_with_scratch
        dev = jnp.asarray(pool.astype(np.int32))
        self.xfer["h2d_tokens"] += pool.size + stream.size + tables.size \
            + (keystream.size if keystream is not None else 0)
        new_meta, new_pool = ops.selective_copy(
            stream, meta_len, total_len, dev, tables,
            meta_max=meta_max, impl=impl, reserved_scratch=True,
            keystream=keystream)
        del new_meta  # host buffers keep the int64-exact metadata
        # sync back ONLY the rows this batch anchored: rows untouched by the
        # kernel keep their int64-exact host content
        touched = np.unique(tables[tables >= 0])
        host_pool = np.asarray(new_pool)
        self.xfer["d2h_tokens"] += host_pool.size
        pool[touched] = host_pool[touched]
        self.xfer["pool_syncs"] += 1
        self.xfer["device_rounds"] += 1
        self.xfer["anchor_rounds"] += 1


@dataclasses.dataclass
class CopyCounters:
    """Telemetry mirrored from the paper's Figure 9 categories."""
    meta_copied: int = 0        # Meta Sel-Copy
    full_copied: int = 0        # Std Copy (fallback/baseline path)
    anchored: int = 0           # payload tokens anchored (written once)
    zero_copied: int = 0        # Meta SKB-Trans: ownership-transferred tokens
    vpi_injected: int = 0
    allocs: int = 0             # Meta Alloc events
    # sw-kTLS-analogue tokens re-touched by SEPARATE crypto passes (§B.1
    # encrypt-and-copy / decrypt-and-copy); hw mode fuses the cipher into
    # the selective-copy pass and never increments this
    crypto_copied: int = 0
    # batched rounds bounced from the int32 device data plane back to the
    # int64-exact host scatter (out-of-range tokens detected pre-dispatch);
    # an event count, not a copy volume — excluded from snapshot()
    device_fallbacks: int = 0
    # cross-worker handoffs (multi-worker cluster). Grants are the zero-copy
    # path (an event count); cross_worker_copied is the token volume of the
    # one-copy fallback taken when the destination worker's pool sits above
    # its watermark. Both are counted SEPARATELY from the paper's Fig. 9
    # categories (excluded from snapshot()): a cluster run must remain
    # counter-identical to a single-stack run at any cross-worker fraction,
    # with the cross-worker machinery's own cost visible on the side.
    cross_worker_grants: int = 0
    cross_worker_copied: int = 0
    # L7 policy-offload verdicts (repro.core.policy). Event counters like
    # cross_worker_grants: an offloaded run must stay Fig.-9-identical to
    # the same trace routed by Python callbacks, so all four stay out of
    # snapshot() — and, as plain dataclass fields, flow into
    # LibraCluster.counters_aggregate() with everything else.
    policy_hits: int = 0         # messages routed by the table (no Python)
    policy_punts: int = 0        # verdicts bounced to the callback slow path
    policy_drops: int = 0        # messages consumed + pages freed by DROP
    policy_rate_debits: int = 0  # RATE_LIMIT token-bucket debits
    policy_failovers: int = 0    # FORWARD verdicts re-routed by HealthTable

    def total_user_copies(self) -> int:
        return self.meta_copied + self.full_copied + self.crypto_copied

    def snapshot(self) -> Tuple[int, ...]:
        """Copy-volume identity tuple (host/device impls and batched/scalar
        schedules must agree on it; event counters stay out)."""
        return (self.meta_copied, self.full_copied, self.anchored,
                self.zero_copied, self.vpi_injected, self.allocs,
                self.crypto_copied)


class Connection:
    """One proxied connection pair (client<->proxy or proxy<->backend)."""

    _next_id = 0

    def __init__(self, parser: ParserPolicy, registry: VpiRegistry,
                 min_payload: int = 1, rx_compact: Optional[int] = None):
        Connection._next_id += 1
        self.conn_id = Connection._next_id
        # socket receive queue: amortized ring, zero-copy windows;
        # ``rx_compact`` tunes the proportional dead-prefix reclamation
        self.rx_ring = RxRing(min_compact=rx_compact if rx_compact else 64)
        self.rx_machine = RxStateMachine(parser, min_payload=min_payload)
        self.tx_machine = TxStateMachine(parser, registry.resolve,
                                         min_payload=min_payload,
                                         vpi_torn_down=registry.torn_down)
        self.tx_stream: List[np.ndarray] = []     # what actually went out
        self.anchored: Dict[int, Tuple[List[PageRef], int]] = {}  # vpi -> (pages, len)
        self.closed = False
        # §A.1 drain mode: tokens of an overflowed message still owed to the
        # native copy path (set by the ingress datapath on pool exhaustion)
        self.rx_drain_remaining = 0
        # kTLS-analogue session (repro.core.crypto.TlsSession) — None for
        # plaintext connections; set by the socket facade when tls= is given
        self.crypto = None

    # -- socket plumbing -----------------------------------------------------
    def deliver(self, data: np.ndarray) -> None:
        """Network delivers bytes into the receive queue (NIC DMA analogue)."""
        self.rx_ring.push(np.asarray(data, np.int64))

    def rx_window(self, lookahead: int) -> np.ndarray:
        """Zero-copy parser window (valid until the next deliver/advance)."""
        return self.rx_ring.peek(lookahead)

    def rx_peek(self, n: int) -> np.ndarray:
        """Zero-copy view of up to ``n`` unread tokens."""
        return self.rx_ring.peek(n)

    def rx_advance(self, n: int) -> None:
        self.rx_ring.advance(n)

    def rx_available(self) -> int:
        return len(self.rx_ring)

    def rx_fingerprint(self) -> Tuple[int, int]:
        """Content-stable queue identity (for parse memoisation)."""
        return self.rx_ring.fingerprint()

    def tx_wire(self) -> np.ndarray:
        """Everything transmitted on this connection, concatenated — the
        byte stream a peer NIC would observe."""
        if not self.tx_stream:
            return np.zeros((0,), np.int64)
        return np.concatenate(self.tx_stream)
