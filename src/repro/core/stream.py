"""Stream-level substrate for the Libra core: connections + token payload pool.

This is the protocol-agnostic layer the paper's Figure 3(b) describes,
expressed over int64 token streams (1 token = 8 bytes, so a VPI occupies
exactly one stream slot). The serving engine reuses the same machinery with
KV pages as the anchored payload; this layer anchors raw token payloads so
the core can be tested and benchmarked in isolation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.anchor_pool import AnchorPool, PageRef
from repro.core.parser import ParserPolicy
from repro.core.state_machine import RxStateMachine, St, TxStateMachine
from repro.core.vpi import VpiRegistry


class TokenPool:
    """Device-side payload pool stand-in: [n_shards * pages_per_shard, page]
    int64 pages. Payload tokens are written once on ingress (DMA analogue)
    and never moved again."""

    def __init__(self, alloc: AnchorPool):
        self.alloc = alloc
        self.data = np.zeros((alloc.n_shards, alloc.pages_per_shard,
                              alloc.page_size), np.int64)

    def write_payload(self, pages: List[PageRef], payload: np.ndarray) -> None:
        ps = self.alloc.page_size
        for pg in pages:
            lo = pg.base_pos
            hi = min(lo + ps, len(payload))
            if lo >= len(payload):
                break
            self.data[pg.shard, pg.local_pid, : hi - lo] = payload[lo:hi]

    def read_payload(self, pages: List[PageRef], length: int) -> np.ndarray:
        ps = self.alloc.page_size
        out = np.zeros((length,), np.int64)
        for pg in pages:
            lo = pg.base_pos
            hi = min(lo + ps, length)
            if lo >= length:
                break
            out[lo:hi] = self.data[pg.shard, pg.local_pid, : hi - lo]
        return out


@dataclasses.dataclass
class CopyCounters:
    """Telemetry mirrored from the paper's Figure 9 categories."""
    meta_copied: int = 0        # Meta Sel-Copy
    full_copied: int = 0        # Std Copy (fallback/baseline path)
    anchored: int = 0           # payload tokens anchored (written once)
    zero_copied: int = 0        # Meta SKB-Trans: ownership-transferred tokens
    vpi_injected: int = 0
    allocs: int = 0             # Meta Alloc events

    def total_user_copies(self) -> int:
        return self.meta_copied + self.full_copied


class Connection:
    """One proxied connection pair (client<->proxy or proxy<->backend)."""

    _next_id = 0

    def __init__(self, parser: ParserPolicy, registry: VpiRegistry,
                 min_payload: int = 1):
        Connection._next_id += 1
        self.conn_id = Connection._next_id
        self.rx_queue = np.zeros((0,), np.int64)  # socket receive queue
        self.rx_read_off = 0
        self.rx_machine = RxStateMachine(parser, min_payload=min_payload)
        self.tx_machine = TxStateMachine(parser, registry.resolve,
                                         min_payload=min_payload,
                                         vpi_torn_down=registry.torn_down)
        self.tx_stream: List[np.ndarray] = []     # what actually went out
        self.anchored: Dict[int, Tuple[List[PageRef], int]] = {}  # vpi -> (pages, len)
        self.closed = False
        # §A.1 drain mode: tokens of an overflowed message still owed to the
        # native copy path (set by the ingress datapath on pool exhaustion)
        self.rx_drain_remaining = 0

    # -- socket plumbing -----------------------------------------------------
    def deliver(self, data: np.ndarray) -> None:
        """Network delivers bytes into the receive queue (NIC DMA analogue)."""
        self.rx_queue = np.concatenate([self.rx_queue, data.astype(np.int64)])

    def rx_window(self, lookahead: int) -> np.ndarray:
        return self.rx_queue[self.rx_read_off : self.rx_read_off + lookahead]

    def rx_advance(self, n: int) -> None:
        self.rx_read_off += n
        # periodically compact the queue (kernel would free skbs)
        if self.rx_read_off > 65536:
            self.rx_queue = self.rx_queue[self.rx_read_off :]
            self.rx_read_off = 0

    def rx_available(self) -> int:
        return len(self.rx_queue) - self.rx_read_off

    def tx_wire(self) -> np.ndarray:
        """Everything transmitted on this connection, concatenated — the
        byte stream a peer NIC would observe."""
        if not self.tx_stream:
            return np.zeros((0,), np.int64)
        return np.concatenate(self.tx_stream)
