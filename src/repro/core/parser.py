"""Programmable metadata parsers — the eBPF RX-Prog/TX-Prog analogue (§2.5 S1).

The framework supplies the *mechanism* (selective copy + anchoring); users
supply the *policy*: a parser that, given a bounded lookahead window over the
incoming stream, locates the metadata boundary and the payload length.

Parsers are restricted the way eBPF programs are:
  * bounded lookahead (default 256 tokens, configurable — the paper's
    256-byte window),
  * pure functions of (window, parser state) — no side effects,
  * deterministic O(N) scanning (KMP for delimiter search, as in the paper).

Each policy has a host form (numpy, drives the engine) and the same logic is
usable under tracing (jnp) for the in-step ``selective_copy`` kernel path.

Stream framing used by the proxy scenario (token-level mirror of HTTP):
  HTTP/1.0-like : [MAGIC, meta_len, payload_len, *meta] [*payload]
  chunked       : header, then repeated [CHUNK_MAGIC, chunk_len] [*chunk], 0-len ends
  delimiter     : metadata terminated by a delimiter motif (CRLFCRLF analogue)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Protocol, Sequence, Tuple

import numpy as np

MAGIC = 17          # start-of-message marker token
CHUNK_MAGIC = 19    # chunk header marker
DELIM = (13, 10, 13, 10)  # CRLF CRLF motif, token-level
DEFAULT_LOOKAHEAD = 256


@dataclasses.dataclass(frozen=True)
class ParseResult:
    ok: bool
    meta_len: int = 0        # metadata tokens (copied to user space)
    payload_len: int = 0     # opaque payload tokens (anchored)
    consumed: int = 0        # window tokens consumed by this parse
    need_more: bool = False  # window too small — wait for more data


class ParserPolicy(Protocol):
    name: str
    lookahead: int

    def parse(self, window: np.ndarray) -> ParseResult: ...


def kmp_table(pattern: Sequence[int]) -> List[int]:
    """Knuth–Morris–Pratt failure function (the paper's metadata scanner)."""
    t = [0] * len(pattern)
    k = 0
    for i in range(1, len(pattern)):
        while k > 0 and pattern[i] != pattern[k]:
            k = t[k - 1]
        if pattern[i] == pattern[k]:
            k += 1
        t[i] = k
    return t


def kmp_find(hay: np.ndarray, pattern: Sequence[int]) -> int:
    """First index of ``pattern`` in ``hay`` or -1. Deterministic O(N+M)."""
    t = kmp_table(pattern)
    k = 0
    for i in range(len(hay)):
        while k > 0 and hay[i] != pattern[k]:
            k = t[k - 1]
        if hay[i] == pattern[k]:
            k += 1
            if k == len(pattern):
                return i - k + 1
    return -1


@dataclasses.dataclass
class LengthPrefixedParser:
    """HTTP/1.0-like: fixed 3-token header [MAGIC, meta_len, payload_len]
    followed by ``meta_len`` metadata tokens, then the opaque payload."""

    name: str = "length-prefixed"
    lookahead: int = DEFAULT_LOOKAHEAD

    def parse(self, window: np.ndarray) -> ParseResult:
        if len(window) < 3:
            return ParseResult(False, need_more=True)
        if int(window[0]) != MAGIC:
            return ParseResult(False)
        meta_len = int(window[1])
        payload_len = int(window[2])
        if meta_len < 0 or payload_len < 0 or 3 + meta_len > self.lookahead:
            return ParseResult(False)
        if len(window) < 3 + meta_len:
            return ParseResult(False, need_more=True)
        return ParseResult(True, meta_len=3 + meta_len, payload_len=payload_len,
                           consumed=3 + meta_len)


@dataclasses.dataclass
class DelimiterParser:
    """HTTP-header-like: metadata runs until the DELIM motif; the payload
    length is encoded right after the delimiter (content-length analogue)."""

    name: str = "delimiter"
    lookahead: int = DEFAULT_LOOKAHEAD
    delim: Tuple[int, ...] = DELIM

    def parse(self, window: np.ndarray) -> ParseResult:
        idx = kmp_find(window[: self.lookahead], self.delim)
        if idx < 0:
            need = len(window) < self.lookahead
            return ParseResult(False, need_more=need)
        end = idx + len(self.delim)
        if len(window) < end + 1:
            return ParseResult(False, need_more=True)
        payload_len = int(window[end])
        if payload_len < 0:
            # corrupt/hostile content-length: a negative value would flow
            # into the RX machine as a negative skip_payload and rewind the
            # ring (re-delivering stream bytes) — unparseable, full copy
            return ParseResult(False)
        return ParseResult(True, meta_len=end + 1, payload_len=payload_len,
                           consumed=end + 1)


@dataclasses.dataclass
class ChunkedParser:
    """HTTP/1.1 chunked transfer: repeated [CHUNK_MAGIC, len] chunk headers;
    a zero-length chunk terminates the message (§2.4 Table 2)."""

    name: str = "chunked"
    lookahead: int = DEFAULT_LOOKAHEAD

    def parse(self, window: np.ndarray) -> ParseResult:
        if len(window) < 2:
            return ParseResult(False, need_more=True)
        if int(window[0]) != CHUNK_MAGIC:
            return ParseResult(False)
        clen = int(window[1])
        if clen < 0:
            # hostile chunk length: same negative-rewind hazard as the
            # delimiter parser — reject instead of passing it downstream
            return ParseResult(False)
        return ParseResult(True, meta_len=2, payload_len=clen, consumed=2)


@dataclasses.dataclass
class TokenStreamParser:
    """LLM-serving policy: the 'header' is the routing prefix of a request
    (system prompt / route tag of ``header_len`` tokens); everything after
    is opaque payload context. This is the policy the serving engine uses:
    header tokens surface to the router, payload KV is anchored."""

    header_len: int
    name: str = "token-stream"
    lookahead: int = DEFAULT_LOOKAHEAD

    def parse(self, window: np.ndarray) -> ParseResult:
        if len(window) < self.header_len:
            return ParseResult(False, need_more=True)
        return ParseResult(True, meta_len=self.header_len,
                           payload_len=-1,  # runs to end of request
                           consumed=self.header_len)


BUILTIN_PARSERS = {
    "length-prefixed": LengthPrefixedParser,
    "delimiter": DelimiterParser,
    "chunked": ChunkedParser,
}


def build_message(meta: np.ndarray, payload: np.ndarray) -> np.ndarray:
    """Encode a length-prefixed message (test/benchmark helper)."""
    hdr = np.array([MAGIC, len(meta), len(payload)], np.int64)
    return np.concatenate([hdr, meta.astype(np.int64), payload.astype(np.int64)])


def build_delimited_message(meta: np.ndarray, payload: np.ndarray) -> np.ndarray:
    hdr = np.concatenate([meta.astype(np.int64), np.array(DELIM, np.int64),
                          np.array([len(payload)], np.int64)])
    return np.concatenate([hdr, payload.astype(np.int64)])


def build_chunked_message(chunks: Sequence[np.ndarray]) -> np.ndarray:
    parts = []
    for c in chunks:
        parts.append(np.array([CHUNK_MAGIC, len(c)], np.int64))
        parts.append(c.astype(np.int64))
    parts.append(np.array([CHUNK_MAGIC, 0], np.int64))
    return np.concatenate(parts)
