"""Per-connection RX/TX lifecycle state machines — the paper's Figures 4 & 5.

The kernel (device) data-plane action is determined by the *final* state a
single RX-/TX-Prog evaluation reaches (footnote 4 of the paper): transitions
such as DEFAULT → METADATA_PARSED → WRITE_VPI may all happen within one
recv()/send() evaluation if buffer space allows.

States (shared by both machines):
  DEFAULT          — parsing metadata; small payloads stay here (full copy)
  METADATA_PARSED  — metadata located, VPI doesn't fit yet (deferred)
  WRITE_VPI        — inject the 8-byte VPI after the metadata (RX only)
  FAST_PATH        — payload bypass active (selective copy running)
  FALLBACK_BYPASS  — TX: VPI lookup missed; skip parsing, full-copy until
                     the current message completes (footnote 5)
"""
from __future__ import annotations

import dataclasses
from enum import Enum
from typing import Optional

from repro.core.parser import ParseResult, ParserPolicy
from repro.core.vpi import VPI_BYTES


class St(Enum):
    DEFAULT = 0
    METADATA_PARSED = 1
    WRITE_VPI = 2
    FAST_PATH = 3
    FALLBACK_BYPASS = 4


MIN_PAYLOAD = VPI_BYTES  # the ≥8-byte admission threshold (§3.2)


@dataclasses.dataclass
class RxDecision:
    """Data-plane action for one recv evaluation."""
    state: St
    copy_meta: int = 0        # metadata tokens to physically copy
    inject_vpi: bool = False
    skip_payload: int = 0     # payload tokens logically consumed, not copied
    full_copy: int = 0        # tokens copied via the native path


class RxStateMachine:
    """Mirrors the proxy's L7 parse state on the receive path (Fig. 4)."""

    def __init__(self, parser: ParserPolicy, min_payload: int = MIN_PAYLOAD,
                 vpi_slots: int = 1):
        self.parser = parser
        self.min_payload = min_payload
        self.vpi_slots = vpi_slots  # stream slots one VPI occupies (8 bytes)
        self.state = St.DEFAULT
        self.meta_len = 0
        self.payload_len = 0
        self.meta_copied = 0
        self.payload_consumed = 0
        self.vpi_written = False

    def reset(self) -> None:
        self.state = St.DEFAULT
        self.meta_len = self.payload_len = 0
        self.meta_copied = self.payload_consumed = 0
        self.vpi_written = False

    def on_recv(self, window, user_buf_space: int,
                parsed: Optional[ParseResult] = None) -> RxDecision:
        """Evaluate the machine for one recv call. ``window`` is the bounded
        lookahead over the socket queue; ``user_buf_space`` the free room in
        the application buffer (G2: arbitrary size). ``parsed`` lets the
        caller reuse a ParseResult it already computed for this window
        (parse() is pure, so the reuse is sound)."""
        if self.state == St.FAST_PATH:
            remaining = self.payload_len - self.payload_consumed
            return RxDecision(St.FAST_PATH, skip_payload=remaining)

        if self.state == St.DEFAULT:
            res: ParseResult = (parsed if parsed is not None
                                else self.parser.parse(window))
            if not res.ok:
                # unparseable or incomplete: native full-copy of what's there
                return RxDecision(St.DEFAULT, full_copy=min(len(window), user_buf_space))
            self.meta_len = res.meta_len
            self.payload_len = res.payload_len
            if 0 <= res.payload_len < self.min_payload:
                # short payload: stay DEFAULT, full copy (admission policy)
                return RxDecision(
                    St.DEFAULT, full_copy=min(res.meta_len + max(res.payload_len, 0),
                                              user_buf_space))
            self.state = St.METADATA_PARSED

        if self.state == St.METADATA_PARSED:
            need = self.meta_len - self.meta_copied + self.vpi_slots
            if user_buf_space < need:
                # copy as much metadata as fits; defer the VPI (Fig. 4 box 2)
                take = min(self.meta_len - self.meta_copied, user_buf_space)
                self.meta_copied += take
                return RxDecision(St.METADATA_PARSED, copy_meta=take)
            self.state = St.WRITE_VPI

        if self.state == St.WRITE_VPI:
            take = self.meta_len - self.meta_copied
            self.meta_copied = self.meta_len
            self.vpi_written = True
            self.state = St.FAST_PATH
            return RxDecision(St.WRITE_VPI, copy_meta=take, inject_vpi=True,
                              skip_payload=self.payload_len)
        raise AssertionError(self.state)

    def on_payload_consumed(self, n: int) -> None:
        self.payload_consumed += n

    def complete(self) -> bool:
        return (self.vpi_written
                and self.payload_consumed >= self.payload_len >= 0)


@dataclasses.dataclass
class TxDecision:
    state: St
    copy_meta: int = 0
    vpi: Optional[int] = None      # extracted VPI (FAST_PATH)
    full_copy: int = 0
    zero_copy_payload: int = 0     # anchored tokens ownership-transferred


class TxStateMachine:
    """Egress two-phase orchestration (Fig. 5): Pre-Send parse + VPI
    extraction, kernel action, Post-Send cumulative accounting."""

    def __init__(self, parser: ParserPolicy, resolve_vpi, min_payload: int = MIN_PAYLOAD,
                 vpi_slots: int = 1, vpi_torn_down=None):
        self.parser = parser
        self.resolve_vpi = resolve_vpi  # callable vpi -> entry | None
        self.vpi_torn_down = vpi_torn_down  # callable vpi -> bool (§A.4 grace)
        self.min_payload = min_payload
        self.vpi_slots = vpi_slots
        self.state = St.DEFAULT
        self.meta_len = 0
        self.payload_len = 0
        self.sent_cumulative = 0
        self.message_len = 0
        self.current_vpi: Optional[int] = None
        # composed [meta..., payload...] staged for transmission — kept
        # across budget-truncated sendmsg calls (the pending-skb analogue)
        self.staged_out = None

    def reset(self) -> None:
        self.state = St.DEFAULT
        self.meta_len = self.payload_len = 0
        self.sent_cumulative = 0
        self.message_len = 0
        self.current_vpi = None
        self.staged_out = None

    # -- Pre-Send ----------------------------------------------------------
    def pre_send(self, buf, extract_vpi,
                 parsed: Optional[ParseResult] = None) -> TxDecision:
        """``buf`` is the user's outgoing stream window; ``extract_vpi`` maps
        a buffer slice to the embedded 64-bit VPI (or None). ``parsed``
        reuses a ParseResult the caller already computed for ``buf``."""
        if self.state == St.FALLBACK_BYPASS:
            # skip parsing entirely (avoids KMP overhead — footnote 5)
            return TxDecision(St.FALLBACK_BYPASS, full_copy=len(buf))
        if self.state == St.FAST_PATH:
            return TxDecision(St.FAST_PATH, vpi=self.current_vpi,
                              zero_copy_payload=self.payload_len)

        res = parsed if parsed is not None else self.parser.parse(buf)
        if not res.ok:
            return TxDecision(St.DEFAULT, full_copy=len(buf))
        self.meta_len, self.payload_len = res.meta_len, res.payload_len
        self.message_len = res.meta_len + max(res.payload_len, 0)
        if 0 <= res.payload_len < self.min_payload:
            return TxDecision(St.DEFAULT, full_copy=self.message_len)
        if len(buf) < res.meta_len + self.vpi_slots:
            self.state = St.METADATA_PARSED
            return TxDecision(St.METADATA_PARSED, copy_meta=res.meta_len)
        vpi = extract_vpi(buf, res.meta_len)
        entry = self.resolve_vpi(vpi) if vpi is not None else None
        if entry is None:
            if (vpi is not None and self.vpi_torn_down is not None
                    and self.vpi_torn_down(vpi)):
                # the handle was real but its payload entered the §A.4 grace
                # period (anchoring socket closed before this send): the
                # frame is all that remains — transmit it and complete,
                # never waiting for payload bytes that cannot arrive
                self.state = St.FALLBACK_BYPASS
                self.message_len = len(buf)
                return TxDecision(St.FALLBACK_BYPASS, full_copy=len(buf))
            self.state = St.FALLBACK_BYPASS  # cache miss (Fig. 5)
            return TxDecision(St.FALLBACK_BYPASS, full_copy=len(buf))
        self.current_vpi = vpi
        self.state = St.FAST_PATH
        return TxDecision(St.FAST_PATH, copy_meta=res.meta_len, vpi=vpi,
                          zero_copy_payload=self.payload_len)

    # -- Post-Send ----------------------------------------------------------
    def post_send(self, actually_sent: int) -> bool:
        """Cumulative accounting in all states except DEFAULT; returns True
        when the message completed (triggers cross-path cleanup)."""
        if self.state == St.DEFAULT:
            return False
        self.sent_cumulative += actually_sent
        if self.message_len and self.sent_cumulative >= self.message_len:
            self.reset()
            return True
        return False
