"""Virtual Payload Identifier (VPI) — §3.2 of the paper.

A VPI is a 64-bit opaque, position-independent handle injected into the
control-plane-visible stream in place of an anchored payload. Properties
kept from the paper:

* **Secure mapping** — the VPI is a keyed blake2b hash (never a raw pool
  address), so control-plane code cannot learn pool layout (the KASLR
  argument transfers: handles must not leak device memory structure).
* **Position independence** — the handle survives arbitrary reshuffling of
  the metadata stream (it is just 8 bytes of payload to the proxy).
* **Admission policy** — payloads smaller than the VPI itself (or smaller
  than ``min_payload``) are not anchored; they take the full-copy path.
* **Refcounts + deferred teardown** (§A.4) — entries are refcounted (prefix
  sharing / multi-forwarding) and freed through a grace period.
* **Cross-worker grants** — a multi-worker cluster hands an anchored payload
  from one worker's registry to another's without moving bytes:
  :meth:`VpiRegistry.import_grant` registers a *grant entry* in the
  destination registry that references the owner's pages (and records the
  owner handle), while the owner's pages gain a pin ref
  (:meth:`~repro.core.anchor_pool.AnchorPool.export_grant`). When the grant
  completes, teardown forwards back to the owner (see
  :mod:`repro.core.egress`), so a grant safely outlives the owner socket's
  §A.4 grace period.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import struct
from typing import Dict, List, Optional, Tuple

VPI_BYTES = 8


@dataclasses.dataclass
class GrantRef:
    """Back-reference of a cross-worker grant entry to its owner: the
    registry that anchored the payload and the owner-side VPI. Teardown of
    the grant forwards through this handle (egress completion releases the
    owner entry when it is still live; a §A.4-torn-down owner keeps its own
    deferred-free schedule)."""
    owner_registry: "VpiRegistry"
    owner_vpi: int


@dataclasses.dataclass
class VpiEntry:
    vpi: int
    pool_id: str
    # pages: list of (shard, local_page_id, base_position)
    pages: List[Tuple[int, int, int]]
    payload_len: int           # logical payload length (tokens)
    refcount: int = 1
    state: str = "ANCHORED"    # ANCHORED | TEARDOWN
    teardown_deadline: Optional[int] = None  # engine tick for deferred free
    meta: Optional[dict] = None
    # cross-worker handoff state (see GrantRef): a zero-copy grant keeps the
    # owner back-reference; the one-copy fallback instead carries the
    # payload itself in ``stash`` (pages stay empty, pool never consulted)
    grant: Optional[GrantRef] = None
    stash: Optional[object] = None   # np.ndarray payload (cross_worker_copied)


class VpiRegistry:
    """The global <VPI, anchored-payload> map (the paper's global eBPF map)."""

    def __init__(self, secret: Optional[bytes] = None, grace_ticks: int = 5):
        self._secret = secret if secret is not None else os.urandom(16)
        self._entries: Dict[int, VpiEntry] = {}
        self._counter = 0
        self.grace_ticks = grace_ticks
        # telemetry (used by benchmarks & tests)
        self.stats = {"registered": 0, "hits": 0, "misses": 0, "released": 0,
                      "deferred": 0, "collisions": 0,
                      "grants_in": 0, "grants_out": 0}

    # -- key derivation ----------------------------------------------------
    def derive_key(self, label: bytes, *context: int) -> bytes:
        """Derive a subordinate secret (e.g. a kTLS-analogue session key)
        from the registry secret — same trust root as the VPI handles, so
        control-plane code can hold neither pool addresses nor keystreams."""
        h = hashlib.blake2b(key=self._secret, digest_size=16)
        h.update(label)
        for c in context:
            h.update(struct.pack("<q", int(c)))
        return h.digest()

    # -- handle generation ------------------------------------------------
    def _make_vpi(self) -> int:
        while True:
            self._counter += 1
            h = hashlib.blake2b(
                struct.pack("<Q", self._counter), key=self._secret, digest_size=8
            ).digest()
            vpi = struct.unpack("<Q", h)[0]
            # never hand out 0 (reserved as "no VPI")
            if vpi != 0 and vpi not in self._entries:
                return vpi
            self.stats["collisions"] += 1

    # -- registry ops ------------------------------------------------------
    def register(self, pool_id: str, pages, payload_len: int, meta=None) -> int:
        vpi = self._make_vpi()
        self._entries[vpi] = VpiEntry(vpi, pool_id, list(pages), payload_len,
                                      meta=meta)
        self.stats["registered"] += 1
        return vpi

    def import_grant(self, owner: "VpiRegistry", owner_vpi: int,
                     pool_id: str, pages, payload_len: int,
                     stash=None) -> int:
        """Cross-worker handoff: register a grant entry for an anchored
        payload owned by another worker's registry. With ``stash=None``
        the grant is **zero-copy** — ``pages`` reference the owner's pool
        (the caller must pin them via
        :meth:`~repro.core.anchor_pool.AnchorPool.export_grant`) and the
        entry carries a :class:`GrantRef` so completion/teardown forwards
        back to the owner. With a ``stash`` the entry is the **one-copy
        fallback**: the payload bytes ride the entry itself (``pages``
        empty, no owner back-reference — the owner side was released at
        handoff)."""
        vpi = self._make_vpi()
        self._entries[vpi] = VpiEntry(
            vpi, pool_id, list(pages), payload_len,
            grant=(GrantRef(owner, owner_vpi) if stash is None else None),
            stash=stash)
        self.stats["registered"] += 1
        self.stats["grants_in"] += 1
        owner.stats["grants_out"] += 1
        return vpi

    def resolve(self, vpi: int) -> Optional[VpiEntry]:
        e = self._entries.get(vpi)
        if e is None or e.state == "TEARDOWN":
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return e

    def peek(self, vpi: int) -> Optional[VpiEntry]:
        """``resolve`` without touching the hit/miss telemetry — for
        control-plane bookkeeping (the socket facade sizing a message)."""
        e = self._entries.get(vpi)
        return None if e is None or e.state == "TEARDOWN" else e

    def handoffs(self) -> List[VpiEntry]:
        """Live cross-worker handoff entries (grant back-reference or
        stashed payload) — the shutdown reclaim sweep's view."""
        return [e for e in self._entries.values()
                if e.grant is not None or e.stash is not None]

    def drop(self, vpi: int) -> Optional[VpiEntry]:
        """Forcibly remove an entry regardless of refcount — an abandoned
        cross-worker handoff reclaimed at shutdown (normal completion goes
        through :meth:`release`). Returns the entry, or None."""
        e = self._entries.pop(vpi, None)
        if e is not None:
            self.stats["released"] += 1
        return e

    def torn_down(self, vpi: int) -> bool:
        """True while ``vpi`` sits in its §A.4 grace period: the handle was
        real but its payload is being reclaimed (vs a garbage token)."""
        e = self._entries.get(vpi)
        return e is not None and e.state == "TEARDOWN"

    def retain(self, vpi: int) -> None:
        self._entries[vpi].refcount += 1

    def release(self, vpi: int) -> bool:
        """Drop a reference; returns True when the entry is fully gone."""
        e = self._entries.get(vpi)
        if e is None:
            return True
        e.refcount -= 1
        if e.refcount <= 0:
            del self._entries[vpi]
            self.stats["released"] += 1
            return True
        return False

    # -- deferred teardown (§A.4) -----------------------------------------
    def begin_teardown(self, vpi: int, now_tick: int) -> None:
        """Socket closed while payload still anchored: keep the anchor alive
        for a grace period instead of dangling."""
        e = self._entries.get(vpi)
        if e is not None:
            e.state = "TEARDOWN"
            e.teardown_deadline = now_tick + self.grace_ticks
            self.stats["deferred"] += 1

    def expire_teardowns(self, now_tick: int) -> List[VpiEntry]:
        """Returns entries whose grace period elapsed; caller frees pages."""
        out = []
        for vpi in list(self._entries):
            e = self._entries[vpi]
            if e.state == "TEARDOWN" and e.teardown_deadline is not None \
                    and now_tick >= e.teardown_deadline:
                out.append(e)
                del self._entries[vpi]
        return out

    # -- stream encoding ----------------------------------------------------
    @staticmethod
    def encode(vpi: int) -> bytes:
        return struct.pack("<Q", vpi)

    @staticmethod
    def decode(buf: bytes) -> int:
        assert len(buf) >= VPI_BYTES
        return struct.unpack("<Q", buf[:VPI_BYTES])[0]

    @staticmethod
    def to_token(vpi: int) -> int:
        """Bit-reinterpret the uint64 VPI into an int64 stream token (the
        8-byte slot it occupies in the user-visible byte stream)."""
        return struct.unpack("<q", struct.pack("<Q", vpi))[0]

    @staticmethod
    def from_token(tok: int) -> int:
        return struct.unpack("<Q", struct.pack("<q", int(tok)))[0]

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, vpi: int) -> bool:
        return vpi in self._entries
