"""kTLS-analogue record layer — the paper's §B.1 encrypted datapath.

The paper's second headline result is that Libra's selective-copy gains
survive encryption only when crypto runs where the payload lives: with
NIC-offloaded kTLS the cipher is fused into the DMA datapath ("hw" mode),
while software kTLS must run a separate decrypt/encrypt-and-copy pass over
every payload ("sw" mode) — exactly the pass Libra worked to eliminate.
This module is the token-level mirror of that record layer:

* **Record framing** (:class:`CryptoRecordParser`) — a TLS-record analogue
  wrapping any inner parser's frames. The wire carries
  ``[REC_MAGIC, seq, inner_meta_len, payload_len, tag]`` (the plaintext
  record header) followed by the encrypted inner frame. For the
  selective-copy machinery the record header + encrypted inner metadata are
  *metadata* (copied to user space, decrypted on the way) and the encrypted
  payload is the *anchored* region — so the whole existing RX/TX state
  machinery runs unmodified over ciphertext.
* **Per-record auth tag** — ``tag`` is a truncated (31-bit) keyed blake2b
  over ``(seq, inner plaintext frame)``: the GCM-tag analogue. Because it
  authenticates the *plaintext*, a proxy re-sealing a record under its TX
  key preserves the tag byte-for-byte (same plaintext, same seq) — egress
  pays zero tag recomputation, mirroring NIC-inline kTLS where the device
  re-tags in the DMA pass. Ingress verifies before anchoring: ``sw`` mode
  checks the tag on its decrypt-and-copy pass, ``hw`` mode folds the check
  into the batched keystream sweep (no separate per-message pass). A
  mismatch rejects the record — pages freed, stream advanced —
  via :class:`RecordAuthError` / a dropped batch slot. The MAC key defaults
  to a fixed domain-separation constant (integrity modeling; a real AEAD
  would derive it per session — the repro's point is the datapath cost,
  not the key schedule).
* **Token cipher** — a reversible XOR stream cipher whose per-record
  keystream is derived from the owning stack's :class:`VpiRegistry` secret
  (blake2b seed, splitmix64 expansion). Keystream tokens are 31-bit, so a
  ciphertext token of an int32-safe plaintext token stays int32-safe — the
  fused device kernel's ``keystream`` operand XORs it away in int32.
* **Sessions** (:class:`TlsSession`) — per-socket rx/tx keys plus the small
  amount of continuation state the full-copy fallbacks need (drained
  records on RX, budget-truncated record frames on TX).

Mode semantics (paper Fig. 6c/6d):

* ``sw`` — software kTLS. The record layer runs *between* the socket queue
  and the pool, per message: ingress pays a separate full decrypt pass
  (decrypt-and-copy) before anchoring, egress a separate encrypt pass after
  gathering, and the socket is **not admissible to the fused batched data
  plane** (``recv_batch``/``forward_batch`` prefetch skip it) — software
  crypto forfeits the batched-datapath speedup.
* ``hw`` — NIC-inline kTLS. The XOR is fused into the selective-copy
  scatter/gather itself (:meth:`TokenPool.write_payload` /
  :meth:`read_payload` ``keystream=`` operands, and the fused Pallas
  kernel's ``keystream`` input): anchored ciphertext is decrypted exactly
  once, on the fly, with zero extra passes, and batched rounds stay fused.

Both modes produce byte-identical wire traffic — they differ only in how
many times the payload is touched, which is the paper's point.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import struct
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.parser import (
    DEFAULT_LOOKAHEAD,
    LengthPrefixedParser,
    ParseResult,
    ParserPolicy,
)

#: record content-type marker (TLS ApplicationData is 23)
REC_MAGIC = 23
#: plaintext record header: [REC_MAGIC, seq, inner_meta_len, payload_len, tag]
REC_HEADER = 5
#: header slot carrying the truncated-blake2b record auth tag
TAG_SLOT = 4
#: keystream tokens are 31-bit so ciphertext = plaintext XOR keystream keeps
#: int32-safe plaintext tokens int32-safe (the device stream constraint)
KS_MASK = 0x7FFFFFFF
#: default MAC domain-separation key (see module docstring)
DEFAULT_MAC_KEY = b"libra-record-mac"

TLS_MODES = ("sw", "hw")


class RecordAuthError(Exception):
    """A record's auth tag did not verify — the record was rejected (bytes
    consumed past it, nothing anchored / anchored pages freed)."""


# ---------------------------------------------------------------------------
# keystream (deterministic, vectorized, host/device-identical)
# ---------------------------------------------------------------------------

def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over uint64 arrays (numpy array ops
    wrap mod 2**64 silently; only scalar ops would warn)."""
    z = x + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@functools.lru_cache(maxsize=8192)
def _record_seed(key: bytes, seq: int) -> int:
    """Per-record keystream seed — the only hash in the cipher. Cached so
    the several spans of one record (metadata, payload, drain resumes)
    derive from one blake2b evaluation."""
    return struct.unpack(
        "<Q", hashlib.blake2b(struct.pack("<q", int(seq)), key=key,
                              digest_size=8).digest())[0]


def keystream(key: bytes, seq: int, n: int, offset: int = 0) -> np.ndarray:
    """``n`` keystream tokens for record ``seq`` starting at encrypted-region
    position ``offset`` (position 0 = first token after the record header).
    Pure function of (key, seq, position): any span of a record's keystream
    can be regenerated independently — partial sends and §A.1 drains resume
    at arbitrary offsets."""
    if n <= 0:
        return np.zeros((0,), np.int64)
    seed = _record_seed(key, seq)
    idx = np.arange(offset, offset + n, dtype=np.uint64) + np.uint64(seed)
    return ((_splitmix64(idx) >> np.uint64(33)) & np.uint64(KS_MASK)
            ).astype(np.int64)


def keystream_batch(keys: Sequence[bytes], seqs: Sequence[int],
                    lens: Sequence[int],
                    offsets: Optional[Sequence[int]] = None,
                    ) -> "list[np.ndarray]":
    """Keystream spans for a whole batch of records in ONE vectorized pass
    (one index build + one splitmix sweep over the concatenated lengths) —
    the hw-mode batched data plane generates every record's keystream here,
    so per-message Python overhead stays out of the fused rounds. Returns
    one array per (key, seq, len, offset) quadruple; equals per-record
    :func:`keystream` calls token for token."""
    lens_arr = np.asarray(lens, np.int64)
    total = int(lens_arr.sum())
    if total == 0:
        return [np.zeros((0,), np.int64) for _ in lens]
    seeds = np.array([_record_seed(k, s) for k, s in zip(keys, seqs)],
                     np.uint64)
    if offsets is not None:
        seeds = seeds + np.asarray(offsets, np.uint64)
    starts = np.zeros_like(lens_arr)
    np.cumsum(lens_arr[:-1], out=starts[1:])
    rel = np.arange(total, dtype=np.uint64) \
        - np.repeat(starts.astype(np.uint64), lens_arr)
    idx = rel + np.repeat(seeds, lens_arr)
    ks = ((_splitmix64(idx) >> np.uint64(33)) & np.uint64(KS_MASK)
          ).astype(np.int64)
    return np.split(ks, np.cumsum(lens_arr)[:-1])


def xor_tokens(tokens: np.ndarray, ks: np.ndarray) -> np.ndarray:
    """Apply the stream cipher (its own inverse) — returns a new array."""
    return np.bitwise_xor(np.asarray(tokens, np.int64), ks)


def record_tag(mac_key: bytes, seq: int, body_plain: np.ndarray) -> int:
    """Truncated-blake2b record auth tag over the *plaintext* record body
    (the inner frame: inner metadata + payload), domain-separated by the
    record ``seq``. 31-bit so the tag token — part of the plaintext header —
    rides the int32 device stream untouched."""
    h = hashlib.blake2b(key=mac_key, digest_size=8)
    h.update(struct.pack("<q", int(seq)))
    h.update(np.ascontiguousarray(np.asarray(body_plain, np.int64)).tobytes())
    return struct.unpack("<Q", h.digest())[0] & KS_MASK


# ---------------------------------------------------------------------------
# record framing (the ParserPolicy)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CryptoRecordParser:
    """TLS-record framing over any inner parser's frames.

    ``parse`` needs no key: the record header is plaintext and
    self-describing (metadata boundary = header + encrypted inner metadata,
    payload = encrypted inner payload). ``inner`` is the application
    protocol the records encapsulate — used when *building* records
    (:func:`seal_record` locates the inner metadata boundary with it)."""

    inner: ParserPolicy = dataclasses.field(default_factory=LengthPrefixedParser)
    name: str = "crypto-record"
    lookahead: int = DEFAULT_LOOKAHEAD

    def parse(self, window: np.ndarray) -> ParseResult:
        if len(window) and int(window[0]) != REC_MAGIC:
            return ParseResult(False)   # not a record boundary: reject now
        if len(window) < REC_HEADER:
            return ParseResult(False, need_more=True)
        inner_meta = int(window[2])
        payload_len = int(window[3])
        if inner_meta < 0 or payload_len < 0 \
                or REC_HEADER + inner_meta > self.lookahead:
            return ParseResult(False)
        if len(window) < REC_HEADER + inner_meta:
            return ParseResult(False, need_more=True)
        return ParseResult(True, meta_len=REC_HEADER + inner_meta,
                           payload_len=payload_len,
                           consumed=REC_HEADER + inner_meta)


def record_header(buf: np.ndarray) -> Optional[Tuple[int, int, int]]:
    """``(seq, inner_meta_len, payload_len)`` when ``buf`` starts with a
    record header, else None."""
    if len(buf) < REC_HEADER or int(buf[0]) != REC_MAGIC:
        return None
    return int(buf[1]), int(buf[2]), int(buf[3])


# ---------------------------------------------------------------------------
# record build/open helpers (benchmarks, tests, and wire-side peers)
# ---------------------------------------------------------------------------

def seal_record(key: bytes, frame: np.ndarray, parser: ParserPolicy,
                seq: int, mac_key: bytes = DEFAULT_MAC_KEY) -> np.ndarray:
    """Wrap one inner ``frame`` (a full [meta..., payload...] message of
    ``parser``'s protocol) into an encrypted, tagged wire record under
    ``key``."""
    frame = np.asarray(frame, np.int64)
    res = parser.parse(frame)
    assert res.ok and res.payload_len >= 0, \
        "seal_record needs a complete, parseable inner frame"
    assert res.meta_len + res.payload_len == len(frame), \
        (res.meta_len, res.payload_len, len(frame))
    hdr = np.array([REC_MAGIC, seq, res.meta_len, res.payload_len,
                    record_tag(mac_key, seq, frame)], np.int64)
    body = xor_tokens(frame, keystream(key, seq, len(frame)))
    return np.concatenate([hdr, body])


def seal_stream(key: bytes, frames: Sequence[np.ndarray],
                parser: ParserPolicy, seq0: int = 0,
                mac_key: bytes = DEFAULT_MAC_KEY) -> np.ndarray:
    """Seal consecutive inner frames into a record stream (seq0, seq0+1, …)."""
    recs = [seal_record(key, f, parser, seq0 + i, mac_key=mac_key)
            for i, f in enumerate(frames)]
    if not recs:
        return np.zeros((0,), np.int64)
    return np.concatenate(recs)


def open_record(key: bytes, wire: np.ndarray,
                mac_key: bytes = DEFAULT_MAC_KEY,
                verify: bool = True) -> Tuple[np.ndarray, int]:
    """Decrypt the record at the head of ``wire``; returns
    ``(inner_frame, tokens_consumed)``. ``verify=True`` (default) checks
    the record auth tag and raises :class:`RecordAuthError` on mismatch."""
    hdr = record_header(wire)
    assert hdr is not None, "open_record: not a record boundary"
    seq, inner_meta, payload_len = hdr
    body_len = inner_meta + payload_len
    end = REC_HEADER + body_len
    assert len(wire) >= end, (len(wire), end)
    body = xor_tokens(wire[REC_HEADER:end], keystream(key, seq, body_len))
    if verify and record_tag(mac_key, seq, body) != int(wire[TAG_SLOT]):
        raise RecordAuthError(f"record seq={seq}: auth tag mismatch")
    return body, end


def open_stream(key: bytes, wire: np.ndarray,
                mac_key: bytes = DEFAULT_MAC_KEY,
                verify: bool = True) -> np.ndarray:
    """Decrypt a whole record stream back to the concatenated inner frames
    (what the plaintext regime would have put on the wire), verifying each
    record's auth tag along the way."""
    wire = np.asarray(wire, np.int64)
    frames, pos = [], 0
    while pos < len(wire):
        frame, used = open_record(key, wire[pos:], mac_key=mac_key,
                                  verify=verify)
        frames.append(frame)
        pos += used
    if not frames:
        return np.zeros((0,), np.int64)
    return np.concatenate(frames)


# ---------------------------------------------------------------------------
# per-socket session
# ---------------------------------------------------------------------------

class TlsSession:
    """Per-connection kTLS-analogue state: direction keys plus the small
    continuation state the full-copy fallback paths need.

    ``rx_key`` decrypts records *arriving at* this socket (wire peers seal
    with it); ``tx_key`` encrypts records this socket transmits (wire peers
    open its ``tx_wire()`` with it). Keys derive from the owning stack's
    VPI-registry secret, so two sockets of one stack never share keystreams.
    """

    def __init__(self, mode: str, rx_key: bytes, tx_key: bytes,
                 mac_key: bytes = DEFAULT_MAC_KEY):
        assert mode in TLS_MODES, mode
        self.mode = mode
        self.rx_key = rx_key
        self.tx_key = tx_key
        self.mac_key = mac_key
        self._seq = 0
        # §A.1 drain continuation: (seq, next encrypted-region offset) of the
        # record whose payload is being served through the full-copy path
        self.rx_drain: Optional[Tuple[int, int]] = None
        # budget-truncated full-copy TX record: (seq, next record position,
        # end position) — resumes the keystream mid-record
        self.tx_resume: Optional[Tuple[int, int, int]] = None
        # record seq of an RX metadata span copied across several recv calls
        # (tiny user buffers): continuations no longer see the header
        self.rx_meta_seq: Optional[int] = None
        # one-slot TX metadata-keystream stash: the batched forwarder
        # generates the whole record keystream in its vectorized sweep and
        # parks the metadata span here for the seal_meta call it is about
        # to trigger (keyed by seq — a mismatch just regenerates)
        self._tx_meta_ks: Optional[Tuple[int, np.ndarray]] = None
        self.stats = {"records_opened": 0, "records_sealed": 0,
                      "sw_decrypt_passes": 0, "sw_encrypt_passes": 0,
                      "auth_failures": 0}

    @staticmethod
    def _crypt_span(key: bytes, chunk: np.ndarray, seq: int,
                    rec_pos: int) -> np.ndarray:
        """XOR the encrypted-region part of a record span that starts at
        record position ``rec_pos`` (0 = REC_MAGIC). Header tokens pass
        through untouched; the keystream offset follows the position."""
        chunk = np.asarray(chunk, np.int64)
        out = chunk.copy()
        enc_from = max(REC_HEADER - rec_pos, 0)
        span = len(chunk) - enc_from
        if span > 0:
            off = rec_pos + enc_from - REC_HEADER
            out[enc_from:] = xor_tokens(chunk[enc_from:],
                                        keystream(key, seq, span, off))
        return out

    # -- wire-side helpers (tests / benchmarks: the remote peers) -----------
    def next_seq(self) -> int:
        """Fresh record sequence number for locally-originated records."""
        self._seq += 1
        return self._seq

    def seal(self, frame: np.ndarray, parser: ParserPolicy,
             seq: Optional[int] = None) -> np.ndarray:
        """Encrypt an inner frame *toward* this socket (peer-side sendmsg)."""
        return seal_record(self.rx_key, frame, parser,
                           self.next_seq() if seq is None else seq,
                           mac_key=self.mac_key)

    def seal_frames(self, frames: Sequence[np.ndarray],
                    parser: ParserPolicy) -> np.ndarray:
        return np.concatenate([self.seal(f, parser) for f in frames]) \
            if frames else np.zeros((0,), np.int64)

    def open_wire(self, wire: np.ndarray) -> np.ndarray:
        """Decrypt everything this socket transmitted (peer-side recv)."""
        return open_stream(self.tx_key, wire, mac_key=self.mac_key)

    # -- RX datapath hooks ---------------------------------------------------
    def verify_record(self, seq: int, tag: int,
                      body_plain: np.ndarray) -> bool:
        """Check a record's auth tag against the decrypted body (inner
        metadata + payload plaintext). Counts failures; the caller rejects
        the record (consume + free) on False."""
        if record_tag(self.mac_key, seq, body_plain) == int(tag):
            return True
        self.stats["auth_failures"] += 1
        return False

    def rx_open_span(self, chunk: np.ndarray, seq: int,
                     rec_pos: int) -> np.ndarray:
        """Decrypt an RX record span starting at record position
        ``rec_pos`` (full-copy fallbacks, drain mode, partial metadata)."""
        return self._crypt_span(self.rx_key, chunk, seq, rec_pos)

    def rx_payload_keystream(self, seq: int, inner_meta_len: int,
                             n: int, consumed: int = 0) -> np.ndarray:
        """Keystream covering payload tokens [consumed, consumed+n) of a
        record (payload starts at encrypted-region offset inner_meta_len)."""
        return keystream(self.rx_key, seq, n, inner_meta_len + consumed)

    def sw_decrypt_payload(self, seq: int, inner_meta_len: int,
                           payload: np.ndarray,
                           consumed: int = 0) -> np.ndarray:
        """sw-kTLS ingress: the separate decrypt-and-copy pass (a fresh
        buffer the zero-copy path then has to anchor anyway)."""
        self.stats["sw_decrypt_passes"] += 1
        return xor_tokens(payload, self.rx_payload_keystream(
            seq, inner_meta_len, len(payload), consumed))

    # -- TX datapath hooks ---------------------------------------------------
    def stash_tx_meta_ks(self, seq: int, ks: np.ndarray) -> None:
        """Park a metadata keystream the batched forwarder already swept."""
        self._tx_meta_ks = (seq, ks)

    def seal_meta(self, meta: np.ndarray) -> np.ndarray:
        """Re-encrypt the inner-metadata span of an outgoing record prefix
        under this socket's TX key (the selective metadata copy, outbound)."""
        meta = np.asarray(meta, np.int64)
        if len(meta) <= REC_HEADER:
            return meta
        seq = int(meta[1])
        span = len(meta) - REC_HEADER
        stash, self._tx_meta_ks = self._tx_meta_ks, None
        if stash is not None and stash[0] == seq and len(stash[1]) == span:
            ks = stash[1]
        else:
            ks = keystream(self.tx_key, seq, span)
        out = meta.copy()
        out[REC_HEADER:] = xor_tokens(meta[REC_HEADER:], ks)
        self.stats["records_sealed"] += 1
        return out

    def tx_payload_keystream(self, seq: int, inner_meta_len: int,
                             n: int) -> np.ndarray:
        return keystream(self.tx_key, seq, n, inner_meta_len)

    def sw_encrypt_payload(self, seq: int, inner_meta_len: int,
                           payload: np.ndarray) -> np.ndarray:
        """sw-kTLS egress: the encrypt-and-copy pass that re-touches the
        gathered payload (paper §B.1)."""
        self.stats["sw_encrypt_passes"] += 1
        return xor_tokens(payload, self.tx_payload_keystream(
            seq, inner_meta_len, len(payload)))

    def tx_encrypt_span(self, chunk: np.ndarray, seq: int,
                        rec_pos: int) -> np.ndarray:
        """Encrypt a full-copy TX span that starts at record position
        ``rec_pos`` (0 = REC_MAGIC): header tokens pass through, everything
        at positions >= REC_HEADER gets the TX keystream. Used by the
        fallback/bypass egress paths, including budget-truncated resumes."""
        return self._crypt_span(self.tx_key, chunk, seq, rec_pos)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"TlsSession(mode={self.mode!r})"
