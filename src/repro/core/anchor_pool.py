"""Anchor pool — the kernel-side socket buffer of the TPU adaptation.

Host-side allocator + accounting for the device-resident paged payload pool
(``[P, page, 2, Hkv, hd]`` per layer on device). Implements the paper's
appendix substrate:

* §A.1 receive-window management → watermarks + per-sequence anchoring cap
  (``max_pages_per_seq``); overflow falls back to the copy path instead of
  OOM-ing the pool.
* §A.2 deadlock-free transfer → two-phase page handoff through a staging
  list (extract from RX owner, then commit to TX owner; never both "locked").
* §A.3 send-side memory accounting → logical byte budget that is raised by
  exactly the staged size during a handoff and restored after.
* §A.4 refcount + deferred teardown → per-page refcounts (prefix sharing)
  and grace-period frees, driven by VpiRegistry.
* §A.5 granularity matching → ``page_size`` is the MAX_SKB_FRAGS analogue;
  ring-buffer tables support sliding-window (bounded) anchoring.

The allocator is pure host metadata: device code receives int32 arrays
(block tables, page base positions, write coordinates) — the Libra
mechanism/policy split.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PoolExhausted(Exception):
    pass


@dataclasses.dataclass
class PageRef:
    shard: int
    local_pid: int
    base_pos: int


class AnchorPool:
    """Allocator for one device pool, striped over ``n_shards`` combine
    shards within one data row (see attention.plan_decode_sharding)."""

    def __init__(
        self,
        n_shards: int,
        pages_per_shard: int,
        page_size: int,
        max_pages_per_seq: int = 0,        # 0 = unlimited (§A.1 cap)
        high_watermark: float = 0.9,
    ):
        self.n_shards = n_shards
        self.pages_per_shard = pages_per_shard
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        self.high_watermark = high_watermark
        self._free: List[List[int]] = [
            list(range(pages_per_shard - 1, -1, -1)) for _ in range(n_shards)
        ]
        self._refcount: Dict[Tuple[int, int], int] = {}
        # §A.3 logical accounting
        self.bytes_per_page = page_size  # logical tokens; scaled by caller
        self.accounted_pages = 0
        self.budget_pages = n_shards * pages_per_shard
        self._budget_raise = 0
        # deferred frees (§A.4)
        self._deferred: List[Tuple[int, List[PageRef]]] = []
        # pages currently pinned by outbound cross-worker grants (gauge)
        self.granted_out_pages = 0
        self.stats = {"allocs": 0, "frees": 0, "fallbacks": 0,
                      "deferred_frees": 0, "exports": 0, "export_releases": 0}

    # -- capacity ----------------------------------------------------------
    @property
    def total_pages(self) -> int:
        return self.n_shards * self.pages_per_shard

    @property
    def scratch_page(self) -> int:
        """Flat index of the scratch row reserved at allocation time — the
        dummy DMA target the fused selective-copy kernel routes invalid
        table entries to. Lives one row past the allocatable pages (the
        freelists never hand it out), so the device pool needs no per-call
        extension/copy."""
        return self.total_pages

    def flat_pid(self, pg: "PageRef") -> int:
        """Flat [0, total_pages) row index of a page (device table entry)."""
        return pg.shard * self.pages_per_shard + pg.local_pid

    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def used_fraction(self) -> float:
        return 1.0 - self.free_pages / max(self.total_pages, 1)

    def above_watermark(self) -> bool:
        return self.used_fraction >= self.high_watermark

    def can_admit(self, n_pages: int) -> bool:
        if self.max_pages_per_seq and n_pages > self.max_pages_per_seq:
            return False
        if self.accounted_pages + n_pages > self.budget_pages + self._budget_raise:
            return False
        return self.free_pages >= n_pages

    # -- allocation ----------------------------------------------------------
    def _pick_shard(self) -> int:
        # biased to the fullest freelist to keep shards balanced
        return max(range(self.n_shards), key=lambda s: len(self._free[s]))

    def _take_page(self, shard: int, base_pos: int) -> PageRef:
        """Unchecked single-page pop — the ONE copy of the placement
        policy (preferred shard, else fullest freelist) shared by
        alloc_page and alloc_batch. The caller has already verified a
        free page exists somewhere."""
        if not self._free[shard]:
            shard = max(range(self.n_shards),
                        key=lambda s: len(self._free[s]))
        pid = self._free[shard].pop()
        self._refcount[(shard, pid)] = 1
        return PageRef(shard, pid, base_pos)

    def alloc_page(self, base_pos: int, shard: Optional[int] = None) -> PageRef:
        if shard is None:
            shard = self._pick_shard()
        if self.free_pages == 0:
            raise PoolExhausted()
        pg = self._take_page(shard, base_pos)
        self.accounted_pages += 1
        self.stats["allocs"] += 1
        return pg

    def alloc_sequence(self, seq_len: int, striped: bool = True) -> List[PageRef]:
        """Allocate pages for a sequence of ``seq_len`` tokens, striping
        page p onto shard p % n_shards (flash-decode locality layout).
        A zero-length sequence owns no pages (nothing to anchor — it must
        not consume a page of pool budget)."""
        n = -(-max(seq_len, 0) // self.page_size)
        if not self.can_admit(n):
            self.stats["fallbacks"] += 1
            raise PoolExhausted()
        pages = []
        try:
            for p in range(n):
                shard = (p % self.n_shards) if striped else None
                if striped and not self._free[shard]:
                    shard = None  # fall back to any shard
                pages.append(self.alloc_page(p * self.page_size, shard))
        except PoolExhausted:
            self.free_pages_list(pages)
            self.stats["fallbacks"] += 1
            raise
        return pages

    def alloc_batch(self, sizes: Sequence[int]) -> List[Optional[List[PageRef]]]:
        """Bulk page allocation for one batched round: allocate pages for
        every sequence of ``sizes`` in a single pass over the freelists
        (no per-item call/exception machinery on the hot path).

        Admission is greedy in order — an item that cannot be admitted
        (per-sequence §A.1 cap, §A.3 budget, or pool exhaustion) yields
        ``None`` in its slot (that message falls back to the scalar path)
        without disturbing the items around it. Placement is identical to
        per-item :meth:`alloc_sequence` calls in the same order, so batched
        and scalar schedules agree on the pool layout byte-for-byte."""
        out: List[Optional[List[PageRef]]] = []
        allocs = 0
        for seq_len in sizes:
            n = -(-max(seq_len, 0) // self.page_size)
            if not self.can_admit(n):
                self.stats["fallbacks"] += 1
                out.append(None)
                continue
            pages = [self._take_page(p % self.n_shards, p * self.page_size)
                     for p in range(n)]
            self.accounted_pages += n
            allocs += n
            out.append(pages)
        self.stats["allocs"] += allocs
        return out

    def free_batch(self, seqs: Sequence[Optional[Sequence[PageRef]]]) -> int:
        """Bulk refcount-release for a round's page lists (``None`` entries
        are skipped). Returns the number of page references released."""
        freed = 0
        for pages in seqs:
            if not pages:
                continue
            self.free_pages_list(pages)
            freed += len(pages)
        return freed

    # -- refcounts / free -----------------------------------------------------
    def retain(self, pages: Sequence[PageRef]) -> None:
        for pg in pages:
            self._refcount[(pg.shard, pg.local_pid)] += 1
            self.accounted_pages += 1

    def free_pages_list(self, pages: Sequence[PageRef]) -> None:
        for pg in pages:
            key = (pg.shard, pg.local_pid)
            rc = self._refcount.get(key, 0)
            if rc <= 1:
                self._refcount.pop(key, None)
                self._free[pg.shard].append(pg.local_pid)
                self.stats["frees"] += 1
            else:
                self._refcount[key] = rc - 1
            self.accounted_pages -= 1

    def defer_free(self, pages: Sequence[PageRef], deadline_tick: int) -> None:
        self._deferred.append((deadline_tick, list(pages)))

    def expire_deferred(self, now_tick: int) -> int:
        kept, n = [], 0
        for deadline, pages in self._deferred:
            if now_tick >= deadline:
                self.free_pages_list(pages)
                n += len(pages)
                self.stats["deferred_frees"] += len(pages)
            else:
                kept.append((deadline, pages))
        self._deferred = kept
        return n

    # -- cross-worker grant pinning (multi-worker §A.4 extension) --------------
    def export_grant(self, pages: Sequence[PageRef]) -> None:
        """Pin ``pages`` for a zero-copy grant handed to another worker:
        each page gains a refcount (exactly like §A.4 prefix sharing), so
        the owner socket's teardown grace can expire — dropping the
        *original* reference — without the granted payload ever hitting
        the freelist. The pin is accounted (§A.3) against THIS pool: the
        memory stays resident here until the grantee releases it."""
        self.retain(pages)
        self.granted_out_pages += len(pages)
        self.stats["exports"] += 1

    def release_export(self, pages: Sequence[PageRef]) -> None:
        """Drop a grant pin (grantee's egress completed, or the grant was
        abandoned). The pages return to the freelist only when every other
        reference — including the owner's own — is gone."""
        self.free_pages_list(pages)
        self.granted_out_pages -= len(pages)
        self.stats["export_releases"] += 1

    # -- §A.2/§A.3 two-phase ownership transfer --------------------------------
    def stage_transfer(self, pages: Sequence[PageRef]) -> List[PageRef]:
        """Phase 1: extract from the RX side into a staging list. The budget
        is raised by exactly the staged size (§A.3): no real memory is
        allocated, but accounting must not underflow on commit."""
        staged = list(pages)
        self._budget_raise += len(staged)
        return staged

    def _unstage(self, staged: Sequence[PageRef]) -> List[PageRef]:
        """Restore the §A.3 budget raise for a staging list (the one copy
        of the accounting shared by commit and abort)."""
        self._budget_raise -= len(staged)
        assert self._budget_raise >= 0
        return list(staged)

    def commit_transfer(self, staged: Sequence[PageRef]) -> List[PageRef]:
        """Phase 2: ownership now belongs to the TX side; restore budget."""
        return self._unstage(staged)

    def abort_transfer(self, staged: Sequence[PageRef]) -> List[PageRef]:
        """Failed handoff: the egress path staged pages but never committed
        them (e.g. the payload compose raised). Ownership stays with the RX
        side; the §A.3 budget raise must still be restored, or it stays
        elevated forever and the accounting cap silently widens."""
        return self._unstage(staged)

    # -- device metadata ---------------------------------------------------------
    def tables_for(
        self,
        seqs: Sequence[Sequence[PageRef]],
        pps: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Build (block_tables, page_pos) [B, n_shards, pps] device metadata
        for a batch of page lists. Slots are filled per shard in allocation
        order; unused entries are -1."""
        b = len(seqs)
        if pps is None:
            pps = self.pages_per_shard
        tables = -np.ones((b, self.n_shards, pps), np.int32)
        page_pos = -np.ones((b, self.n_shards, pps), np.int32)
        for i, pages in enumerate(seqs):
            slot_ctr = [0] * self.n_shards
            for pg in pages:
                s = slot_ctr[pg.shard]
                if s >= pps:
                    raise PoolExhausted(f"pages-per-shard overflow: {s} >= {pps}")
                tables[i, pg.shard, s] = pg.local_pid
                page_pos[i, pg.shard, s] = pg.base_pos
                slot_ctr[pg.shard] += 1
        return tables, page_pos

    @staticmethod
    def write_coords(
        seqs: Sequence[Sequence[PageRef]],
        positions: Sequence[int],
        n_shards: int,
        page_size: int,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-request (write_shard, write_slot) for appending at
        ``positions[i]`` — exactly ONE page of the sequence must cover that
        position. Overlapping pages are a corrupted table (two pages would
        both claim the write) and assert instead of silently resolving
        last-match-wins."""
        b = len(seqs)
        wsh = np.zeros((b,), np.int32)
        wsl = np.zeros((b,), np.int32)
        for i, (pages, pos) in enumerate(zip(seqs, positions)):
            slot_ctr = [0] * n_shards
            matches = 0
            for pg in pages:
                s = slot_ctr[pg.shard]
                slot_ctr[pg.shard] += 1
                if pg.base_pos <= pos < pg.base_pos + page_size:
                    wsh[i], wsl[i] = pg.shard, s
                    matches += 1
            assert matches == 1, \
                (i, pos, matches, [p.base_pos for p in pages])
        return wsh, wsl

    def token_coords(
        self, seqs: Sequence[Sequence[PageRef]], seq_len: int
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Prefill metadata: per-token (shard, slot, offset, valid) arrays
        of shape [B, seq_len]."""
        b = len(seqs)
        tsh = np.zeros((b, seq_len), np.int32)
        tsl = np.zeros((b, seq_len), np.int32)
        toff = np.zeros((b, seq_len), np.int32)
        tval = np.zeros((b, seq_len), bool)
        for i, pages in enumerate(seqs):
            slot_ctr = [0] * self.n_shards
            for pg in pages:
                s = slot_ctr[pg.shard]
                slot_ctr[pg.shard] += 1
                lo = pg.base_pos
                hi = min(lo + self.page_size, seq_len)
                if lo >= seq_len:
                    continue
                tsh[i, lo:hi] = pg.shard
                tsl[i, lo:hi] = s
                toff[i, lo:hi] = np.arange(hi - lo)
                tval[i, lo:hi] = True
        return tsh, tsl, toff, tval
