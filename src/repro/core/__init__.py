"""Libra core: programmable selective data movement (the paper's contribution).

Mechanism (this package) / policy (user parsers) split:

* ``vpi``            — 64-bit opaque anchored-payload handles + registry
* ``anchor_pool``    — paged, refcounted payload pool allocator + accounting
* ``parser``         — programmable metadata-boundary policies (eBPF analogue)
* ``state_machine``  — RX/TX lifecycle state machines (paper Figs. 4–5)
* ``stream``         — connections + token payload pool (protocol testbed)
* ``ingress``        — selective-copy recv path
* ``egress``         — metadata-copy + zero-copy ownership-transfer send path
"""
from repro.core.anchor_pool import AnchorPool, PageRef, PoolExhausted
from repro.core.egress import expire_teardowns, libra_close, libra_send
from repro.core.ingress import libra_recv
from repro.core.parser import (
    BUILTIN_PARSERS,
    ChunkedParser,
    DelimiterParser,
    LengthPrefixedParser,
    TokenStreamParser,
    build_chunked_message,
    build_delimited_message,
    build_message,
    kmp_find,
)
from repro.core.state_machine import RxStateMachine, St, TxStateMachine
from repro.core.stream import Connection, CopyCounters, TokenPool
from repro.core.vpi import VPI_BYTES, VpiEntry, VpiRegistry

__all__ = [
    "AnchorPool", "PageRef", "PoolExhausted",
    "VpiRegistry", "VpiEntry", "VPI_BYTES",
    "LengthPrefixedParser", "DelimiterParser", "ChunkedParser",
    "TokenStreamParser", "BUILTIN_PARSERS", "kmp_find",
    "build_message", "build_delimited_message", "build_chunked_message",
    "RxStateMachine", "TxStateMachine", "St",
    "Connection", "TokenPool", "CopyCounters",
    "libra_recv", "libra_send", "libra_close", "expire_teardowns",
]
