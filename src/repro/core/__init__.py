"""Libra core: programmable selective data movement (the paper's contribution).

Three layers, top to bottom:

**Facade (policy-free POSIX surface)** — what unmodified proxies program
against. One :class:`LibraStack` per "kernel" owns the anchored payload
pool, the global VPI map, the parser registry, the tick clock, and the
copy-telemetry counters; :class:`LibraSocket` exposes per-connection
``recv``/``send``/``forward``/``close``/``poll`` with zero plumbing at
call-sites. :class:`ProxyRuntime` is the epoll-style event loop that
multiplexes N flows with mixed parser policies over one stack.

* ``stack``          — :class:`LibraStack` (shared kernel state + clock)
* ``socket``         — :class:`LibraSocket` (POSIX-shaped connection facade)
* ``runtime``        — :class:`ProxyRuntime` / :class:`ProxyChannel`
                       (readiness sets, round-robin/priority/DRR
                       scheduling, send budgets, ticks)
* ``cluster``        — :class:`LibraCluster` / :class:`SteeringPolicy` /
                       :class:`ClusterRuntime`: N-worker scale-out with
                       RSS-style flow steering, the cross-worker VPI
                       grant protocol, and work-stealing scheduling

**Mechanism (datapaths)** — the selective-copy machinery itself.

* ``ingress``        — selective-copy recv path (§3.3)
* ``egress``         — metadata-copy + zero-copy ownership-transfer send
                       path, deferred teardown (§3.4, §A.2–A.4)
* ``state_machine``  — RX/TX lifecycle state machines (paper Figs. 4–5)
* ``vpi``            — 64-bit opaque anchored-payload handles + registry
* ``anchor_pool``    — paged, refcounted payload pool allocator + accounting
* ``stream``         — connections + token payload pool (protocol testbed)

**Policy (user programs)** — the eBPF analogue supplied by applications.

* ``parser``         — programmable metadata-boundary policies
* ``crypto``         — kTLS-analogue record layer (§B.1): record framing as
                       a parser policy, keyed token cipher, sw/hw session
                       modes (``stack.socket(..., tls='sw'|'hw')``)
* ``policy``         — in-data-plane L7 policy engine: a
                       :class:`PolicyTable` of matcher→action rules
                       compiled to dense arrays, evaluated per batched
                       round as one vectorized match pass fused into
                       ``recv_batch`` (Python is the PUNT slow path);
                       epoch-versioned hot swap, plus the
                       :class:`HealthTable` backend circuit breaker that
                       feeds the match pass's ``live`` rule mask
* ``faults``         — :class:`FaultPlan`: seeded, deterministic chaos
                       injection (EAGAIN storms, resets, pool pressure,
                       worker kills, frame corruption) for testing the
                       fault-tolerance layer

The free functions ``libra_recv``/``libra_send``/``libra_close``/
``expire_teardowns`` remain exported as the explicit-plumbing compatibility
layer; new code should go through the facade (see docs/API.md).
"""
from repro.core.anchor_pool import AnchorPool, PageRef, PoolExhausted
from repro.core.cluster import ClusterRuntime, LibraCluster, SteeringPolicy
from repro.core.crypto import (
    REC_MAGIC,
    CryptoRecordParser,
    RecordAuthError,
    TlsSession,
    open_record,
    open_stream,
    record_tag,
    seal_record,
    seal_stream,
)
from repro.core.device_pool import DevicePool, DeviceRangeError
from repro.core.egress import expire_teardowns, libra_close, libra_send
from repro.core.faults import FaultPlan
from repro.core.ingress import libra_recv
from repro.core.parser import (
    BUILTIN_PARSERS,
    ChunkedParser,
    DelimiterParser,
    LengthPrefixedParser,
    TokenStreamParser,
    build_chunked_message,
    build_delimited_message,
    build_message,
    kmp_find,
)
from repro.core.policy import (
    Action,
    HealthTable,
    MatchCond,
    PolicyRule,
    PolicyTable,
    PythonPolicyRouter,
    Verdict,
    between,
    drop,
    eq,
    forward,
    prefix,
    punt,
    rate_limit,
    rewrite,
    rule,
)
from repro.core.runtime import (
    ChannelStats,
    LatencyHistogram,
    ProxyChannel,
    ProxyRuntime,
)
from repro.core.socket import Events, LibraSocket
from repro.core.stack import SEND_EAGAIN, SEND_OK, LibraStack
from repro.core.state_machine import RxStateMachine, St, TxStateMachine
from repro.core.stream import Connection, CopyCounters, RxRing, TokenPool
from repro.core.vpi import VPI_BYTES, VpiEntry, VpiRegistry

__all__ = [
    # facade
    "LibraStack", "LibraSocket", "Events",
    "ProxyRuntime", "ProxyChannel", "ChannelStats", "LatencyHistogram",
    "SEND_OK", "SEND_EAGAIN",
    "LibraCluster", "SteeringPolicy", "ClusterRuntime",
    # mechanism
    "AnchorPool", "PageRef", "PoolExhausted",
    "VpiRegistry", "VpiEntry", "VPI_BYTES",
    "RxStateMachine", "TxStateMachine", "St",
    "Connection", "TokenPool", "DevicePool", "DeviceRangeError",
    "CopyCounters", "RxRing",
    # policy
    "LengthPrefixedParser", "DelimiterParser", "ChunkedParser",
    "TokenStreamParser", "BUILTIN_PARSERS", "kmp_find",
    "build_message", "build_delimited_message", "build_chunked_message",
    # L7 policy engine + fault tolerance
    "PolicyTable", "PolicyRule", "MatchCond", "Action", "Verdict",
    "PythonPolicyRouter", "rule", "eq", "between", "prefix",
    "forward", "rewrite", "rate_limit", "drop", "punt",
    "HealthTable", "FaultPlan",
    # kTLS-analogue record layer
    "CryptoRecordParser", "TlsSession", "REC_MAGIC", "RecordAuthError",
    "seal_record", "seal_stream", "open_record", "open_stream", "record_tag",
    # compatibility layer (explicit plumbing)
    "libra_recv", "libra_send", "libra_close", "expire_teardowns",
]
