"""Ingress datapath — §3.3: state-driven selective copy.

``libra_recv`` is the instrumented recvmsg: the RX state machine (eBPF
RX-Prog analogue) decides, per call, which data-plane action runs:

  DEFAULT          -> native full copy (unparseable / short payload)
  METADATA_PARSED  -> copy only metadata; defer VPI (no buffer space)
  WRITE_VPI        -> copy remaining metadata, anchor payload, inject VPI
  FAST_PATH        -> advance the logical read offset; copy nothing

The returned length is the *logical* message length (metadata + anchored
payload), capped at the requested size — recv transparency (§3.3 box 3).
The RX machine stays in FAST_PATH until the egress path confirms full
transmission and resets it (cross-datapath cleanup, §3.4 box 3).

Pool exhaustion follows §A.1: the prefix that fits is anchored zero-copy;
the remainder is served through the native full-copy path.

Encrypted connections (``Connection.crypto`` set — the kTLS analogue) run
the same machine over ciphertext records: the record header + inner
metadata are decrypted during the metadata copy, and the payload cipher is
either a separate decrypt-and-copy pass before anchoring (``sw`` mode,
§B.1's software kTLS penalty, counted in ``CopyCounters.crypto_copied``)
or fused into the anchoring scatter itself (``hw`` mode, the NIC-inline
datapath — zero extra passes). Full-copy fallbacks (short records, §A.1
drain) decrypt in place so the application always sees plaintext.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.anchor_pool import PoolExhausted
from repro.core.crypto import REC_HEADER, TAG_SLOT, RecordAuthError, xor_tokens
from repro.core.state_machine import St
from repro.core.stream import Connection, CopyCounters, TokenPool
from repro.core.sync import plane_lock
from repro.core.vpi import VpiRegistry


def libra_recv(
    conn: Connection,
    buf_len: int,
    pool: TokenPool,
    registry: VpiRegistry,
    counters: CopyCounters,
) -> Tuple[np.ndarray, int]:
    """Returns (user_visible_buffer, logical_length).

    On the selective-copy path the buffer contains [metadata..., VPI] while
    the logical length covers metadata + anchored payload.
    """
    sm = conn.rx_machine
    crypto = conn.crypto

    # §A.1 drain mode: a previous message overflowed the pool; the rest of
    # its payload takes the native copy path.
    drain = conn.rx_drain_remaining
    if drain > 0:
        n = min(drain, conn.rx_available(), buf_len)
        out = conn.rx_peek(n).copy()
        conn.rx_advance(n)
        counters.full_copied += n
        conn.rx_drain_remaining = drain - n
        if crypto is not None and crypto.rx_drain is not None and n:
            # the drained ciphertext resumes its record keystream where the
            # previous call stopped (offsets are encrypted-region positions)
            seq, off = crypto.rx_drain
            out = xor_tokens(out, crypto.rx_payload_keystream(seq, 0, n, off))
            crypto.rx_drain = ((seq, off + n)
                               if conn.rx_drain_remaining else None)
        if conn.rx_drain_remaining == 0:
            sm.reset()
        return out, n

    window = conn.rx_window(sm.parser.lookahead)
    if len(window) == 0 and not (sm.state == St.FAST_PATH
                                 and sm.payload_consumed < sm.payload_len):
        # nothing buffered AND no capped logical remainder to report: the
        # FAST_PATH skip needs no rx bytes (the kernel already consumed the
        # skb at WRITE_VPI) — recv transparency must still surface it
        return np.zeros((0,), np.int64), 0

    parsed = None
    if sm.state == St.DEFAULT:
        # admission precondition for the selective path: the whole declared
        # payload must be resident in the kernel queue (NIC DMA complete)
        # before anchoring — a partially delivered message waits, it is
        # never anchored with holes. (parse() is pure; the result is reused
        # by the state machine below, so the window is scanned once.)
        parsed = sm.parser.parse(window)
        if parsed.ok and parsed.payload_len >= sm.min_payload \
                and conn.rx_available() < parsed.meta_len + parsed.payload_len:
            return np.zeros((0,), np.int64), 0
    # the window view may be invalidated by rx_advance below; capture the
    # record seq while it is still valid
    head_seq = int(window[1]) if len(window) >= 2 else None

    decision = sm.on_recv(window, buf_len, parsed=parsed)

    if decision.state == St.DEFAULT:
        n = min(decision.full_copy, conn.rx_available(), buf_len)
        out = conn.rx_peek(n).copy()
        if crypto is not None and parsed is not None and parsed.ok and n:
            # a short-payload record served through the native path: the
            # record layer verifies the WHOLE resident record BEFORE any
            # of its plaintext reaches the caller — including tiny-buffer
            # calls that serve only a prefix (a record whose payload has
            # not fully arrived yet serves unverified, the same streaming
            # corner as split metadata; the wire-side open still checks)
            whole = parsed.meta_len + parsed.payload_len
            if conn.rx_available() >= whole:
                rec = crypto.rx_open_span(conn.rx_peek(whole), head_seq, 0)
                if not crypto.verify_record(head_seq, rec[TAG_SLOT],
                                            rec[REC_HEADER:]):
                    # tag mismatch: reject — consume the record, deliver
                    # nothing, charge nothing
                    conn.rx_advance(whole)
                    sm.reset()
                    raise RecordAuthError(
                        f"record seq={head_seq}: tag mismatch")
                out = rec[:n].copy()
            else:
                out = crypto.rx_open_span(out, head_seq, 0)
        conn.rx_advance(n)
        counters.full_copied += n
        sm.reset()
        return out, n

    if decision.state == St.METADATA_PARSED:
        n = decision.copy_meta
        out = conn.rx_peek(n).copy()
        conn.rx_advance(n)
        counters.meta_copied += n
        if crypto is not None and n:
            start = sm.meta_copied - n
            if start == 0:
                # remember the record seq: continuations of this metadata
                # span no longer see the header
                crypto.rx_meta_seq = head_seq
            if crypto.rx_meta_seq is not None:
                out = crypto.rx_open_span(out, crypto.rx_meta_seq, start)
        return out, n

    if decision.state == St.WRITE_VPI:
        meta = conn.rx_peek(decision.copy_meta).copy()
        payload_len = sm.payload_len
        seq = None
        imeta = sm.meta_len - REC_HEADER
        # plaintext produced by the auth verify, reused by the decrypt
        # below so no record pays the cipher twice
        verified_plain = None
        if crypto is not None:
            start = sm.meta_len - decision.copy_meta
            seq = head_seq if start == 0 else crypto.rx_meta_seq
            crypto.rx_meta_seq = None
            if seq is not None:
                meta = crypto.rx_open_span(meta, seq, start)
                if start == 0:
                    # per-record auth, BEFORE anything is consumed or
                    # anchored: the record-layer verify (sw's decrypt pass
                    # and hw's fused scatter both run after — and only
                    # if — the tag checks out). The tag covers the whole
                    # plaintext record, so metadata spans split across
                    # several tiny-buffer recv calls (start > 0) cannot be
                    # checked inline and pass through (the §3.3
                    # deferred-VPI corner; the wire-side open still
                    # verifies).
                    ks = crypto.rx_payload_keystream(seq, imeta, payload_len)
                    plain = xor_tokens(
                        conn.rx_peek(sm.meta_len + payload_len)[sm.meta_len:],
                        ks)
                    if not crypto.verify_record(
                            seq, meta[TAG_SLOT],
                            np.concatenate([meta[REC_HEADER:], plain])):
                        conn.rx_advance(sm.meta_len + payload_len)
                        sm.reset()
                        raise RecordAuthError(
                            f"record seq={seq}: tag mismatch")
                    verified_plain = plain
                crypto.stats["records_opened"] += 1
        conn.rx_advance(decision.copy_meta)
        counters.meta_copied += len(meta)
        # zero-copy window over the resident payload (view stays valid
        # until the rx_advance below)
        payload = conn.rx_peek(payload_len)
        try:
            with plane_lock(pool.alloc):
                pages = pool.alloc.alloc_sequence(payload_len)
        except PoolExhausted:
            # anchor nothing; serve the whole payload via native copies.
            # the metadata was already accounted as meta_copied above — only
            # the payload portion goes through the full-copy path. (clamp to
            # what is actually buffered: never advance past delivered bytes)
            n = (min(payload_len, conn.rx_available(), buf_len - len(meta))
                 if buf_len > len(meta) else 0)
            served = payload[:n].copy()
            if seq is not None and n:
                served = (verified_plain[:n] if verified_plain is not None
                          else xor_tokens(
                              served,
                              crypto.rx_payload_keystream(seq, imeta, n)))
            out = np.concatenate([meta, served])
            conn.rx_advance(n)
            counters.full_copied += n
            conn.rx_drain_remaining = payload_len - n
            if crypto is not None:
                crypto.rx_drain = ((seq, imeta + n) if seq is not None
                                   and conn.rx_drain_remaining else None)
            if conn.rx_drain_remaining == 0:
                sm.reset()
            return out, len(out)
        try:
            if seq is None:
                pool.write_payload(pages, payload)
            elif crypto.mode == "sw":
                # sw-kTLS: decrypt-and-copy into a fresh buffer, THEN
                # anchor — the separate pass the paper's §B.1 software path
                # cannot avoid. The verify already produced the plaintext
                # buffer; it IS that pass (counted as such) — never run the
                # cipher twice.
                if verified_plain is not None:
                    plain = verified_plain
                    crypto.stats["sw_decrypt_passes"] += 1
                else:
                    plain = crypto.sw_decrypt_payload(seq, imeta, payload)
                counters.crypto_copied += payload_len
                pool.write_payload(pages, plain)
            elif verified_plain is not None:
                # hw-kTLS: the NIC verified and decrypted in the same
                # pass — anchor the plaintext the verify produced (one
                # cipher pass total; the keystream-fused scatter below
                # serves the rare unverified continuation case)
                pool.write_payload(pages, verified_plain)
            else:
                # hw-kTLS: the cipher rides the anchoring scatter itself —
                # the ciphertext is decrypted exactly once, on the fly
                pool.write_payload(
                    pages, payload,
                    keystream=crypto.rx_payload_keystream(
                        seq, imeta, payload_len))
            counters.anchored += payload_len
            counters.allocs += 1
            conn.rx_advance(payload_len)
            with plane_lock(registry):
                vpi = registry.register(
                    pool.pool_id,
                    [(p.shard, p.local_pid, p.base_pos) for p in pages],
                    payload_len,
                )
        except BaseException:
            # the pages are ours until the registry owns them: a datapath
            # fault between alloc and register hands them straight back to
            # the freelist instead of leaking them (OWN001)
            with plane_lock(pool.alloc):
                pool.alloc.free_pages_list(pages)
            raise
        conn.anchored[vpi] = (pages, payload_len)
        out = np.concatenate([meta, np.array([VpiRegistry.to_token(vpi)], np.int64)])
        counters.vpi_injected += 1
        logical = min(len(meta) + payload_len, buf_len)
        sm.on_payload_consumed(logical - len(meta))
        return out, logical

    if decision.state == St.FAST_PATH:
        # remaining logical length, zero physical copies
        n = min(decision.skip_payload, buf_len)
        sm.on_payload_consumed(n)
        return np.zeros((0,), np.int64), n

    raise AssertionError(decision.state)


def reset_rx_from_tx(conn: Connection) -> None:
    """Cross-datapath cleanup: called by the egress path once the anchored
    payload has been fully transmitted (§3.4 Post-Send)."""
    conn.rx_machine.reset()
