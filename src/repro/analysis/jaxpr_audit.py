"""Pass 2 — jaxpr auditor for every registered kernel entry point.

Generalizes ``check_kernel_parity.py``'s ad-hoc jaxpr walks into one
audited registry.  For each entry (``selective_copy`` legacy/reserved/
crypto, ``selective_gather`` ± keystream, ``policy_match`` ± keystream ±
live ± payload-prefix window, and ``fused_round`` — the one-kernel
scheduling round — across its optional-operand matrix and the DMA-staged
layout) the trace-level invariants are:

- ``JAX001`` — exactly one ``pallas_call`` per fused op (the whole round
  is ONE kernel; a second call means the fusion regressed).
- ``JAX002`` — no pool-sized-copy primitive (``concatenate``/``pad``/
  ``gather``-free hot path; the reserved-scratch row exists precisely so
  the kernel never materializes a grown pool).
- ``JAX003`` — no silent int64 promotion: an int64 aval appearing in a
  jaxpr whose inputs are all narrower means a host int64 leaked into the
  device plane (the int32 stream would truncate, or x64 doubles traffic).
- ``JAX004`` — declared-vs-observed boundary-transfer budget: the element
  count crossing the host/device boundary (invars + consts + outvars)
  must equal what the entry declares — a new operand or a pool-sized
  output shows up here before it shows up in a benchmark.
- ``JAX005`` — donation actually consumes its input: the donated pool
  buffer must be deleted after a ``donate_pool=True`` round (otherwise
  the "in-place" round silently keeps two live pools).

This module is the single source of truth for :data:`POOL_COPY_PRIMS` and
the jaxpr primitive walk — ``repro.kernels.testing`` re-exports them, and
``scripts/check_kernel_parity.py`` delegates here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, List, Sequence, Tuple

import numpy as np

from repro.analysis.common import Finding, Report

#: primitives that would betray a pool-sized copy on the hot path
POOL_COPY_PRIMS = ("concatenate", "pad")

JAXPR_RULES = ("JAX001", "JAX002", "JAX003", "JAX004", "JAX005")


def jaxpr_primitives(jaxpr) -> List[str]:
    """All primitive names in a jaxpr, recursing through call/closed-call
    params (pjit bodies etc.)."""
    acc: List[str] = []

    def walk(j):
        for eqn in j.eqns:
            acc.append(eqn.primitive.name)
            for v in eqn.params.values():
                inner = getattr(v, "jaxpr", None)
                if inner is not None:
                    walk(inner if hasattr(inner, "eqns") else inner.jaxpr)

    walk(jaxpr)
    return acc


def _avals(jaxpr) -> list:
    """Avals of every var in the jaxpr tree (boundary and internal)."""
    out = []

    def walk(j):
        for v in list(j.invars) + list(j.constvars) + list(j.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None:
                out.append(aval)
        for eqn in j.eqns:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None:
                    out.append(aval)
            for p in eqn.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    walk(inner if hasattr(inner, "eqns") else inner.jaxpr)

    walk(jaxpr)
    return out


def _boundary_elems(closed_jaxpr) -> int:
    """Element count crossing the host/device boundary: inputs, captured
    consts, and outputs of the top-level jaxpr."""
    j = closed_jaxpr.jaxpr
    total = 0
    for v in list(j.invars) + list(j.outvars):
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "shape"):
            total += int(np.prod(aval.shape, dtype=np.int64)) if aval.shape \
                else 1
    for c in closed_jaxpr.consts:
        total += int(np.asarray(c).size)
    return total


@dataclass
class KernelEntry:
    """One audited kernel entry point.

    ``build`` returns ``(fn, args, declared_boundary_elems)`` — the
    declared budget is the entry's contract for what crosses the
    host/device boundary per call.
    """
    name: str
    build: Callable[[], Tuple[Callable, tuple, int]]
    n_pallas: int = 1
    forbid: Tuple[str, ...] = POOL_COPY_PRIMS
    expect: Tuple[str, ...] = ()  # negative control: prims that MUST appear


def _case_dims(b=2, page=8, pps=4, meta_max=16):
    s = meta_max + pps * page
    p_total = b * pps + 2
    return s, p_total


def _selcopy_entry(reserved: bool, keystream: bool):
    def build():
        import jax
        from repro.kernels.selective_copy import selective_copy
        from repro.kernels.testing import selcopy_case, selcopy_crypto_case
        rng = np.random.default_rng(7)
        b, page, pps, meta_max = 2, 8, 4, 16
        s, p_total = _case_dims(b, page, pps, meta_max)
        if keystream:
            stream, ml, tl, pool, tables, ks = selcopy_crypto_case(
                rng, b=b, page=page, pps=pps, meta_max=meta_max)
            fn = functools.partial(selective_copy, meta_max=meta_max,
                                   interpret=True, reserved_scratch=True,
                                   keystream=ks)
            args = (stream, ml, tl, pool, tables)
            pool_rows = p_total + 1
            declared = (b * s            # stream
                        + 2 * b          # meta_len, total_len
                        + pool_rows * page
                        + b * pps        # tables
                        + b * s          # keystream (captured const)
                        + b * meta_max   # meta out
                        + pool_rows * page)  # pool out
        else:
            stream, ml, tl, pool, tables = selcopy_case(
                rng, b=b, page=page, pps=pps, meta_max=meta_max)
            if not reserved:
                pool = pool[:-1]
            fn = functools.partial(selective_copy, meta_max=meta_max,
                                   interpret=True,
                                   reserved_scratch=reserved)
            args = (stream, ml, tl, pool, tables)
            pool_rows = (p_total + 1) if reserved else p_total
            declared = (b * s + 2 * b + pool_rows * page + b * pps
                        + b * meta_max + pool_rows * page)
        return fn, args, declared
    return build


def _selgather_entry(keystream: bool):
    def build():
        from repro.kernels.selective_copy import selective_gather
        from repro.kernels.testing import selgather_case
        rng = np.random.default_rng(8)
        b, page, pps = 2, 8, 4
        p_total = b * pps + 2
        pool, tables, lengths, ks = selgather_case(rng, b=b, page=page,
                                                   pps=pps)
        fn = functools.partial(selective_gather, interpret=True,
                               keystream=ks if keystream else None)
        declared = ((p_total + 1) * page + b * pps + b
                    + (b * pps * page if keystream else 0)   # ks const
                    + b * pps * page)                        # gathered out
        return fn, (pool, tables, lengths), declared
    return build


def _policy_entry(keystream: bool, live: bool):
    def build():
        from repro.kernels.selective_copy import policy_match
        from repro.kernels.testing import policy_case, policy_live_column
        rng = np.random.default_rng(9)
        b, meta_max, r, k = 4, 16, 6, 3
        meta, ml, off, lo, hi, ks = policy_case(rng, b=b, meta_max=meta_max,
                                                r=r, k=k)
        lv = policy_live_column(rng, r) if live else None
        fn = functools.partial(policy_match, interpret=True,
                               keystream=ks if keystream else None, live=lv)
        declared = (b * meta_max + b + 3 * r * k
                    + (b * meta_max if keystream else 0)
                    + (r if live else 0)
                    + b)  # verdict out
        return fn, (meta, ml, off, lo, hi), declared
    return build


def _policy_payload_entry(keystream: bool, live: bool):
    def build():
        from repro.kernels.selective_copy import policy_match
        from repro.kernels.testing import (policy_live_column,
                                           policy_payload_case)
        rng = np.random.default_rng(10)
        b, meta_max, r, k, w = 4, 16, 6, 3, 8
        meta, ml, off, lo, hi, ks, pay, plen = policy_payload_case(
            rng, b=b, meta_max=meta_max, r=r, k=k, w=w)
        lv = policy_live_column(rng, r) if live else None
        fn = functools.partial(policy_match, interpret=True,
                               keystream=ks if keystream else None, live=lv,
                               payload=pay, payload_len=plen)
        declared = (b * meta_max + b + 3 * r * k
                    + (b * meta_max if keystream else 0)
                    + (r if live else 0)
                    + b * w + b       # payload window + payload_len consts
                    + b)              # verdict out
        return fn, (meta, ml, off, lo, hi), declared
    return build


def _fused_entry(crypto: bool, policy: bool, n_buffers: int = 0):
    """One-kernel scheduling round: anchor + kTLS XOR + policy match +
    egress gather as a SINGLE pallas_call (the fusion JAX001 guards is the
    3-to-1 launch collapse itself). The full-operand variant adds the TX
    keystream, the policy cond tables, the live column, and the metadata
    keystream; ``n_buffers >= 2`` audits the DMA-pipelined staging layout
    (same boundary budget — scratch buffers never cross the boundary)."""
    def build():
        from repro.kernels.selective_copy import fused_round
        from repro.kernels.testing import fused_round_case
        rng = np.random.default_rng(12)
        b, page, pps, meta_max, r, k = 2, 8, 4, 16, 6, 3
        s, p_total = _case_dims(b, page, pps, meta_max)
        case = fused_round_case(rng, b=b, page=page, pps=pps,
                                meta_max=meta_max, r=r, k=k)
        kw = dict(meta_max=meta_max, interpret=True, n_buffers=n_buffers)
        if crypto:
            kw.update(keystream=case["keystream"],
                      tx_keystream=case["tx_keystream"])
        if policy:
            kw.update(cond_off=case["cond_off"], cond_lo=case["cond_lo"],
                      cond_hi=case["cond_hi"], live=case["live"])
            if crypto:
                kw.update(meta_ks=case["meta_ks"])
        fn = functools.partial(fused_round, **kw)
        args = (case["stream"], case["meta_len"], case["total_len"],
                case["pool"], case["tables"])
        pool_rows = p_total + 1
        declared = (b * s + 2 * b + pool_rows * page + b * pps     # inputs
                    + (b * s + b * pps * page if crypto else 0)    # rx+tx ks
                    + (3 * r * k + r if policy else 0)             # conds+live
                    + (b * meta_max if crypto and policy else 0)   # meta ks
                    + b * meta_max + pool_rows * page              # meta, pool
                    + b * pps * page                               # gather out
                    + (b if policy else 0))                        # verdict
        return fn, args, declared
    return build


KERNEL_ENTRIES: List[KernelEntry] = [
    KernelEntry("selective_copy[reserved]", _selcopy_entry(True, False)),
    KernelEntry("selective_copy[keystream]", _selcopy_entry(True, True)),
    # legacy mode is the negative control: its grown-pool concatenate is
    # the pool-sized copy the reserved-scratch mode exists to eliminate
    KernelEntry("selective_copy[legacy]", _selcopy_entry(False, False),
                forbid=(), expect=("concatenate",)),
    KernelEntry("selective_gather", _selgather_entry(False)),
    KernelEntry("selective_gather[keystream]", _selgather_entry(True)),
    KernelEntry("policy_match", _policy_entry(False, False)),
    KernelEntry("policy_match[keystream]", _policy_entry(True, False)),
    KernelEntry("policy_match[live]", _policy_entry(False, True)),
    KernelEntry("policy_match[keystream+live]", _policy_entry(True, True)),
    KernelEntry("policy_match[payload]", _policy_payload_entry(False, False)),
    KernelEntry("policy_match[payload+keystream+live]",
                _policy_payload_entry(True, True)),
    # the one-kernel scheduling round: JAX001 == 1 here IS the 3-to-1
    # launch collapse (anchor + crypt + match + gather in one pallas_call)
    KernelEntry("fused_round", _fused_entry(False, False)),
    KernelEntry("fused_round[policy]", _fused_entry(False, True)),
    KernelEntry("fused_round[crypto]", _fused_entry(True, False)),
    KernelEntry("fused_round[crypto+policy]", _fused_entry(True, True)),
    KernelEntry("fused_round[crypto+policy+dma2]",
                _fused_entry(True, True, n_buffers=2)),
]


def audit_fn(fn: Callable, args: tuple, *, name: str,
             n_pallas: int = 1,
             forbid: Sequence[str] = POOL_COPY_PRIMS,
             expect: Sequence[str] = (),
             declared_boundary: int | None = None) -> List[Finding]:
    """Audit one traced callable against the kernel invariants.

    This is the primitive the parity gate and the fixture tests share.
    """
    import jax
    closed = jax.make_jaxpr(fn)(*args)
    names = jaxpr_primitives(closed.jaxpr)
    loc = f"<jaxpr:{name}>"
    findings: List[Finding] = []
    got_pallas = names.count("pallas_call")
    if got_pallas != n_pallas:
        findings.append(Finding(loc, 0, "JAX001",
                                f"{got_pallas} pallas_call(s), expected "
                                f"{n_pallas} — the fused round regressed"))
    bad = sorted(set(names) & set(forbid))
    if bad:
        findings.append(Finding(loc, 0, "JAX002",
                                f"pool-sized copy primitive(s) in the hot "
                                f"path: {bad}"))
    missing = sorted(set(expect) - set(names))
    if missing:
        findings.append(Finding(loc, 0, "JAX002",
                                f"negative control broken: expected "
                                f"{missing} in this (non-fused) trace"))
    in_dtypes = {str(getattr(v.aval, "dtype", ""))
                 for v in closed.jaxpr.invars} | \
                {str(np.asarray(c).dtype) for c in closed.consts}
    if "int64" not in in_dtypes:
        wide = [a for a in _avals(closed.jaxpr)
                if str(getattr(a, "dtype", "")) == "int64"]
        if wide:
            findings.append(Finding(
                loc, 0, "JAX003",
                f"silent int64 promotion: {len(wide)} int64 aval(s) in a "
                f"jaxpr with no int64 input"))
    if declared_boundary is not None:
        observed = _boundary_elems(closed)
        if observed != declared_boundary:
            findings.append(Finding(
                loc, 0, "JAX004",
                f"boundary-transfer budget: declared {declared_boundary} "
                f"elements, observed {observed}"))
    return findings


def assert_fused(fn: Callable, args: tuple, *, name: str,
                 n_pallas: int = 1,
                 forbid: Sequence[str] = POOL_COPY_PRIMS,
                 expect: Sequence[str] = ()) -> None:
    """Raise AssertionError on any finding — the parity-gate entry point."""
    findings = audit_fn(fn, args, name=name, n_pallas=n_pallas,
                        forbid=forbid, expect=expect)
    assert not findings, "; ".join(f.format() for f in findings)


def audit_donation() -> List[Finding]:
    """JAX005: a ``donate_pool=True`` round must consume the input pool
    buffer (otherwise two full pools stay live per round)."""
    import jax.numpy as jnp
    from repro.kernels import ops
    from repro.kernels.testing import fused_round_case, selcopy_case
    rng = np.random.default_rng(11)
    stream, ml, tl, pool, tables = selcopy_case(rng)
    donated = jnp.array(np.array(pool))
    ops.selective_copy(stream, ml, tl, donated, tables, meta_max=16,
                       impl="ref", donate_pool=True)
    findings: List[Finding] = []
    if not donated.is_deleted():
        findings.append(Finding(
            "<jaxpr:selective_copy[donated]>", 0, "JAX005",
            "donate_pool=True did not consume the input pool buffer — "
            "donation is declared but not honored"))
    case = fused_round_case(rng)
    fused_pool = jnp.array(np.array(case["pool"]))
    ops.fused_round(case["stream"], case["meta_len"], case["total_len"],
                    fused_pool, case["tables"], meta_max=16, impl="ref",
                    keystream=case["keystream"],
                    tx_keystream=case["tx_keystream"],
                    cond_off=case["cond_off"], cond_lo=case["cond_lo"],
                    cond_hi=case["cond_hi"], live=case["live"],
                    meta_ks=case["meta_ks"], donate_pool=True)
    if not fused_pool.is_deleted():
        findings.append(Finding(
            "<jaxpr:fused_round[donated]>", 0, "JAX005",
            "donate_pool=True did not consume the fused round's input pool "
            "buffer — donation is declared but not honored"))
    return findings


def run() -> Report:
    """Audit every registered kernel entry plus the donation contract."""
    findings: List[Finding] = []
    for entry in KERNEL_ENTRIES:
        fn, args, declared = entry.build()
        findings.extend(audit_fn(
            fn, args, name=entry.name, n_pallas=entry.n_pallas,
            forbid=entry.forbid, expect=entry.expect,
            declared_boundary=declared))
    findings.extend(audit_donation())
    return Report(name="jaxpr", active=findings, waived=[])
