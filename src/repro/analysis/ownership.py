"""Pass 1 — page/grant ownership lint (AST dataflow over ``core/*.py``).

Models the anchor-pool / grant lifecycle as acquire → {release | handoff}
and flags any path where an exception or an early exit can escape between
the two without try/finally protection or an explicit ownership transfer —
the bug class behind PR 5's abandoned-grant leak and PR 7's EAGAIN
page-hold.

Lifecycle model (intra-procedural, optimistic):

- **acquire**: ``alloc_page`` / ``alloc_sequence`` / ``alloc_batch`` /
  ``stage_transfer`` bind a *page* resource; ``export_grant`` binds a *pin*.
- **release**: ``free_pages_list`` / ``free_batch`` / ``release_export`` /
  ``defer_free`` / ``commit_transfer`` / ``abort_transfer``.
- **handoff**: ``register`` / ``import_grant`` / ``grant_into`` transfer
  ownership to a registry (``import_grant`` also consumes any live pin —
  the grant entry assumes the pin); storing into an attribute/subscript,
  appending into a collection, wrapping in a CamelCase constructor,
  returning or yielding all move ownership out of the local frame.
- **escape**: ``raise`` / ``assert`` / a call documented to raise
  (:data:`MAY_RAISE`) / ``return`` / ``break`` / ``continue``.
- **protection**: an enclosing ``try`` whose ``finally`` releases (covers
  every escape) or whose handlers each either release or swallow the
  exception (covers raising escapes only — handlers do not run on
  ``return``).

Rules:

- ``OWN001`` — a live resource can leak if a call/raise/assert escapes.
- ``OWN002`` — an acquire's result is discarded (unbound page resource).
- ``OWN003`` — early ``return``/``break``/``continue`` while holding.
- ``OWN004`` — a live resource name is rebound without a release.

The pass is deliberately optimistic (any plausible disposal counts) so that
every finding is worth a human look; residual false positives carry
``# libra: waive[OWNxxx] reason`` comments at the flagged line.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, Report, build_report

REPO_ROOT = Path(__file__).resolve().parents[3]

# method name -> resource kind it acquires
ACQUIRES = {
    "alloc_page": "page",
    "alloc_sequence": "pages",
    "alloc_batch": "page-batch",
    "stage_transfer": "staged-pages",
    "export_grant": "pin",
}
RELEASES = frozenset({
    "free_pages_list", "free_batch", "release_export", "defer_free",
    "commit_transfer", "abort_transfer",
})
HANDOFFS = frozenset({"register", "import_grant", "grant_into"})
# collection mutators that move their argument into the receiver
MOVES_INTO_RECEIVER = frozenset({"append", "extend", "add", "insert"})
# datapath calls documented (or observed) to raise mid-path: pool writes can
# hit bad coords, device anchoring raises DeviceRangeError, the record layer
# raises RecordAuthError, grant import can fault on a dead owner.
MAY_RAISE = frozenset(ACQUIRES) | frozenset({
    "import_grant", "grant_into",
    "write_payload", "write_payload_batch",
    "read_payload", "read_payload_batch",
    "anchor_batch_device", "gather_batch_device",
    "keystream_batch", "verify_record", "sw_decrypt_payload",
    "rx_payload_keystream", "rx_open_span", "seal_record",
})

OWNERSHIP_RULES = ("OWN001", "OWN002", "OWN003", "OWN004",
                   "WAIVER001", "WAIVER002")


@dataclass
class _Res:
    name: str
    kind: str
    line: int
    parent: Optional[str] = None
    reported: bool = False
    accum: bool = False  # receiver collection (append target)


@dataclass
class _TryFrame:
    protects_raise: bool
    protects_all: bool


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _names_in(node: Optional[ast.AST]) -> Set[str]:
    if node is None:
        return set()
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _contains_release(stmts: Sequence[ast.stmt]) -> bool:
    for s in stmts:
        for n in ast.walk(s):
            if isinstance(n, ast.Call) and _call_name(n) in RELEASES:
                return True
    return False


def _contains_raise(stmts: Sequence[ast.stmt]) -> bool:
    return any(isinstance(n, ast.Raise)
               for s in stmts for n in ast.walk(s))


def _terminates(stmts: Sequence[ast.stmt]) -> bool:
    """True when the block cannot fall through to the statement after it."""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def _is_constructor(name: str) -> bool:
    return bool(name) and name.lstrip("_")[:1].isupper()


class _FuncScanner:
    """Scans one function body; collects findings."""

    def __init__(self, filename: str, func: ast.AST):
        self.filename = filename
        self.func = func
        self.live: Dict[str, _Res] = {}
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        self._scan_block(self.func.body, prot=[], loop_start=None)
        return self.findings

    # -- block / statement dispatch ---------------------------------------

    def _scan_block(self, stmts: Sequence[ast.stmt],
                    prot: List[_TryFrame],
                    loop_start: Optional[int]) -> None:
        for stmt in stmts:
            self._scan_stmt(stmt, prot, loop_start)

    def _scan_stmt(self, stmt: ast.stmt, prot: List[_TryFrame],
                   loop_start: Optional[int]) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.findings.extend(
                _FuncScanner(self.filename, stmt).run())
            return
        if isinstance(stmt, ast.ClassDef):
            for sub in stmt.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self.findings.extend(
                        _FuncScanner(self.filename, sub).run())
            return
        if isinstance(stmt, ast.If):
            self._risk_only(stmt.test, prot, loop_start)
            self._scan_branches(stmt.body, stmt.orelse, prot, loop_start,
                                test=stmt.test)
            return
        if isinstance(stmt, ast.While):
            self._risk_only(stmt.test, prot, loop_start)
            self._scan_branches(stmt.body, stmt.orelse, prot, stmt.lineno)
            return
        if isinstance(stmt, ast.For):
            self._scan_for(stmt, prot, loop_start)
            return
        if isinstance(stmt, ast.Try):
            self._scan_try(stmt, prot, loop_start)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._risk_only(item.context_expr, prot, loop_start)
            self._scan_block(stmt.body, prot, loop_start)
            return
        self._scan_simple(stmt, prot, loop_start)

    # -- simple statements -------------------------------------------------

    def _scan_simple(self, stmt: ast.stmt, prot: List[_TryFrame],
                     loop_start: Optional[int]) -> None:
        calls = [n for n in ast.walk(stmt) if isinstance(n, ast.Call)]
        disposed = self._disposed_by(stmt, calls)
        self._check_risks(stmt, calls, disposed, prot, loop_start)
        for name in disposed:
            self._dispose(name)
        self._acquire_from(stmt, calls)

    def _risk_only(self, expr: ast.expr, prot: List[_TryFrame],
                   loop_start: Optional[int]) -> None:
        calls = [n for n in ast.walk(expr) if isinstance(n, ast.Call)]
        for c in calls:
            if _call_name(c) in MAY_RAISE:
                self._flag_raise(c.lineno, f"{_call_name(c)}() may raise",
                                 set(), prot)

    def _disposed_by(self, stmt: ast.stmt,
                     calls: List[ast.Call]) -> Set[str]:
        disposed: Set[str] = set()
        live = self.live
        for c in calls:
            name = _call_name(c)
            argnames = set()
            for a in list(c.args) + [kw.value for kw in c.keywords]:
                argnames |= _names_in(a)
            if name in RELEASES or name in HANDOFFS:
                disposed |= live.keys() & argnames
                if name == "import_grant":
                    # the grant entry assumes responsibility for the pin
                    disposed |= {n for n, r in live.items()
                                 if r.kind == "pin"}
                elif name == "release_export":
                    # a bare export_grant() pin has no binding name — the
                    # only way to release it IS reconstructed PageRefs, so
                    # any release_export on the path disposes it
                    disposed |= {n for n, r in live.items()
                                 if r.kind == "pin" and n.startswith("<pin@")}
            elif name in MOVES_INTO_RECEIVER and isinstance(
                    c.func, ast.Attribute):
                moved = live.keys() & argnames
                acquired_arg = any(
                    isinstance(a, ast.Call) and _call_name(a) in ACQUIRES
                    for a in c.args)
                if moved or acquired_arg:
                    disposed |= moved
                    recv = c.func.value
                    if isinstance(recv, ast.Name):
                        kind = (live[next(iter(moved))].kind if moved
                                else "pages")
                        self.live.setdefault(
                            recv.id,
                            _Res(recv.id, kind, c.lineno, accum=True))
            elif _is_constructor(name):
                disposed |= live.keys() & argnames
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, (ast.Attribute, ast.Subscript)):
                    # storing a resource into an object moves ownership —
                    # but writing to a field OF the resource itself (or
                    # merely reading it to index the store) does not
                    disposed |= (live.keys() & _names_in(stmt.value)) \
                        - _names_in(t)
        if isinstance(stmt, (ast.Return, ast.Expr)) and isinstance(
                getattr(stmt, "value", None),
                (ast.Name, ast.Tuple, ast.List, ast.Yield, ast.IfExp)):
            disposed |= live.keys() & _names_in(stmt.value)
        elif isinstance(stmt, ast.Return) and stmt.value is not None:
            disposed |= live.keys() & _names_in(stmt.value)
        return disposed

    def _check_risks(self, stmt: ast.stmt, calls: List[ast.Call],
                     disposed: Set[str], prot: List[_TryFrame],
                     loop_start: Optional[int]) -> None:
        if isinstance(stmt, (ast.Raise, ast.Assert)):
            kind = "raise" if isinstance(stmt, ast.Raise) else "assert"
            self._flag_raise(stmt.lineno, f"{kind} escapes", disposed, prot)
            return
        for c in calls:
            name = _call_name(c)
            if name in MAY_RAISE:
                self._flag_raise(c.lineno, f"{name}() may raise",
                                 disposed, prot)
        if isinstance(stmt, ast.Return):
            self._flag_exit(stmt.lineno, "early return while holding",
                            disposed, prot, only_after=None)
        elif isinstance(stmt, (ast.Break, ast.Continue)) and loop_start:
            word = ("break" if isinstance(stmt, ast.Break) else "continue")
            self._flag_exit(stmt.lineno, f"{word} while holding",
                            disposed, prot, only_after=loop_start)

    def _flag_raise(self, line: int, desc: str, disposed: Set[str],
                    prot: List[_TryFrame]) -> None:
        if any(f.protects_all or f.protects_raise for f in prot):
            return
        self._emit("OWN001", line, desc, disposed, skip_children=True)

    def _flag_exit(self, line: int, desc: str, disposed: Set[str],
                   prot: List[_TryFrame],
                   only_after: Optional[int]) -> None:
        if any(f.protects_all for f in prot):
            return
        self._emit("OWN003", line, desc, disposed, skip_children=True,
                   only_after=only_after)

    def _emit(self, rule: str, line: int, desc: str, disposed: Set[str],
              skip_children: bool, only_after: Optional[int] = None) -> None:
        for name, res in list(self.live.items()):
            if name in disposed or res.reported:
                continue
            if skip_children and res.parent is not None:
                continue
            if only_after is not None and (res.line <= only_after
                                           or res.accum):
                # break/continue only leak resources born this iteration;
                # appending into an accumulator then continuing is the
                # normal accumulate pattern (freed after the loop)
                continue
            res.reported = True
            self.findings.append(Finding(
                self.filename, line, rule,
                f"'{name}' ({res.kind} acquired at line {res.line}) "
                f"may leak: {desc}"))

    def _dispose(self, name: str) -> None:
        res = self.live.pop(name, None)
        if res is not None and res.parent is not None:
            # a consumed element optimistically disposes its collection
            self._dispose(res.parent)

    def _acquire_from(self, stmt: ast.stmt,
                      calls: List[ast.Call]) -> None:
        acq = [c for c in calls if _call_name(c) in ACQUIRES]
        if not acq:
            self._alias_comprehension(stmt)
            return
        c = acq[0]
        kind = ACQUIRES[_call_name(c)]
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
            if len(targets) == 1 and isinstance(targets[0], ast.Name):
                name = targets[0].id
                prev = self.live.get(name)
                if prev is not None and not prev.reported and \
                        name not in _names_in(stmt.value):
                    self.findings.append(Finding(
                        self.filename, stmt.lineno, "OWN004",
                        f"'{name}' ({prev.kind} acquired at line "
                        f"{prev.line}) rebound without release"))
                self.live[name] = _Res(name, kind, stmt.lineno)
            elif len(targets) == 1 and isinstance(targets[0], ast.Tuple):
                for elt in targets[0].elts:
                    if isinstance(elt, ast.Name):
                        self.live[elt.id] = _Res(elt.id, kind, stmt.lineno)
            # attribute/subscript target: stored into an object that now
            # owns it — out of local scope, nothing to track
        elif isinstance(stmt, ast.Expr) and stmt.value is c:
            # bare acquire, result discarded: pins are legal (released via
            # reconstructed refs), page acquires are an immediate leak
            if kind == "pin":
                name = f"<pin@{c.lineno}>"
                self.live[name] = _Res(name, "pin", c.lineno)
            else:
                self.findings.append(Finding(
                    self.filename, c.lineno, "OWN002",
                    f"{_call_name(c)}() result discarded — "
                    f"{kind} leaks immediately"))
        # acquire nested inside append/constructor/other call: moved into
        # the receiver by _disposed_by, or consumed by the callee (handoff)

    def _alias_comprehension(self, stmt: ast.stmt) -> None:
        """``view = {.. for x in owned ..}`` binds a child view of the
        owned collection: releasing through the view releases the whole."""
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            return
        value = stmt.value
        if not isinstance(value, (ast.ListComp, ast.SetComp, ast.DictComp,
                                  ast.GeneratorExp)):
            return
        name = stmt.targets[0].id
        for gen in value.generators:
            hits = (self.live.keys() & _names_in(gen.iter)) - {name}
            if hits:
                parent = next(iter(hits))
                self.live[name] = _Res(name, self.live[parent].kind,
                                       stmt.lineno, parent=parent)
                return

    # -- control flow ------------------------------------------------------

    def _scan_branches(self, body: Sequence[ast.stmt],
                       orelse: Sequence[ast.stmt],
                       prot: List[_TryFrame],
                       loop_start: Optional[int],
                       test: Optional[ast.expr] = None) -> None:
        # emptiness guard: inside `if not xs:` the collection xs is empty —
        # it cannot leak there; inside `if xs:` it is empty in the orelse
        empty_in_body = empty_in_else = None
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not) \
                and isinstance(test.operand, ast.Name):
            empty_in_body = test.operand.id
        elif isinstance(test, ast.Name):
            empty_in_else = test.id
        entry = dict(self.live)
        if empty_in_body in self.live:
            del self.live[empty_in_body]
        self._scan_block(body, prot, loop_start)
        body_live, body_exits = self.live, _terminates(body)
        if empty_in_body is not None and empty_in_body in entry:
            body_live[empty_in_body] = entry[empty_in_body]
        self.live = dict(entry)
        if empty_in_else in self.live:
            del self.live[empty_in_else]
        self._scan_block(orelse, prot, loop_start)
        else_live, else_exits = self.live, bool(orelse) and _terminates(orelse)
        if empty_in_else is not None and empty_in_else in entry:
            else_live[empty_in_else] = entry[empty_in_else]
        # a branch that cannot fall through does not join (its escapes were
        # already checked by the exit rules)
        if body_exits and not else_exits:
            self.live = dict(else_live)
            return
        if else_exits and not body_exits:
            self.live = dict(body_live)
            return
        merged: Dict[str, _Res] = {}
        for name, res in {**body_live, **else_live}.items():
            if name in entry:
                if name in body_live and name in else_live:
                    merged[name] = res
            else:
                merged[name] = res
        self.live = merged

    def _scan_for(self, stmt: ast.For, prot: List[_TryFrame],
                  loop_start: Optional[int]) -> None:
        self._risk_only(stmt.iter, prot, loop_start)
        children = self._bind_loop_targets(stmt)
        self._scan_block(stmt.body, prot, stmt.lineno)
        for child in children:
            if child in self.live:
                # element never consumed this iteration: scope ends, the
                # collection keeps ownership
                del self.live[child]
        self._scan_block(stmt.orelse, prot, loop_start)

    def _bind_loop_targets(self, stmt: ast.For) -> List[str]:
        """Bind loop targets iterating a live collection as child
        resources (positional for zip/enumerate)."""
        children: List[str] = []

        def bind(target: ast.expr, parent: str, kind: str) -> None:
            for n in ast.walk(target):
                if isinstance(n, ast.Name):
                    self.live[n.id] = _Res(n.id, kind, stmt.lineno,
                                           parent=parent)
                    children.append(n.id)

        it = stmt.iter
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Name) \
                and it.func.id == "zip" \
                and isinstance(stmt.target, ast.Tuple) \
                and len(stmt.target.elts) == len(it.args):
            for arg, tgt in zip(it.args, stmt.target.elts):
                hits = self.live.keys() & _names_in(arg)
                if hits:
                    parent = next(iter(hits))
                    bind(tgt, parent, self.live[parent].kind)
            return children
        if isinstance(it, ast.Call) and isinstance(it.func, ast.Name) \
                and it.func.id == "enumerate" and it.args:
            hits = self.live.keys() & _names_in(it.args[0])
            if hits and isinstance(stmt.target, ast.Tuple) \
                    and len(stmt.target.elts) == 2:
                parent = next(iter(hits))
                bind(stmt.target.elts[1], parent,
                     self.live[parent].kind)
            return children
        hits = self.live.keys() & _names_in(it)
        if hits:
            parent = next(iter(hits))
            bind(stmt.target, parent, self.live[parent].kind)
        return children

    def _scan_try(self, stmt: ast.Try, prot: List[_TryFrame],
                  loop_start: Optional[int]) -> None:
        handlers_ok = bool(stmt.handlers) and all(
            _contains_release(h.body) or not _contains_raise(h.body)
            for h in stmt.handlers)
        frame = _TryFrame(
            protects_raise=handlers_ok or _contains_release(stmt.finalbody),
            protects_all=_contains_release(stmt.finalbody),
        )
        entry = dict(self.live)
        self._scan_block(stmt.body, prot + [frame], loop_start)
        self._scan_block(stmt.orelse, prot + [frame], loop_start)
        after_body = self.live
        for h in stmt.handlers:
            # a handler may run before any acquire in the body completed;
            # optimistically scan it with entry-state liveness
            self.live = dict(entry)
            self._scan_block(h.body, prot, loop_start)
        self.live = after_body
        self._scan_block(stmt.finalbody, prot, loop_start)


def lint_source(source: str, filename: str) -> List[Finding]:
    """Run the ownership lint over one module's source text."""
    tree = ast.parse(source, filename=filename)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            findings.extend(_FuncScanner(filename, node).run())
    # ast.walk visits nested functions too — _FuncScanner already recurses,
    # so de-duplicate by (line, rule, message)
    seen: Set[Tuple[int, str, str]] = set()
    out = []
    for f in sorted(findings, key=lambda f: (f.file, f.line, f.rule)):
        key = (f.line, f.rule, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def default_targets(root: Path = REPO_ROOT) -> List[Path]:
    return sorted((root / "src" / "repro" / "core").glob("*.py"))


def run(root: Path = REPO_ROOT,
        paths: Optional[Sequence[Path]] = None) -> Report:
    """Lint ``core/*.py`` (or ``paths``) and apply waivers."""
    findings: List[Finding] = []
    sources: Dict[str, str] = {}
    for path in (paths if paths is not None else default_targets(root)):
        rel = str(Path(path).resolve().relative_to(root))
        text = Path(path).read_text()
        sources[rel] = text
        findings.extend(lint_source(text, rel))
    return build_report("ownership", findings, sources,
                        rules=OWNERSHIP_RULES)
