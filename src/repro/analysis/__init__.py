"""Static-analysis suite for the Libra datapath (verifier analogue).

Libra's safety story rests on the eBPF verifier proving selective-copy
programs safe *before* they touch the datapath.  This package is the
reproduction's analogue: three static passes + one runtime instrumentation
hook that together gate the invariants the datapath has accumulated:

- :mod:`repro.analysis.ownership` — AST dataflow over ``core/*.py`` modeling
  the page/grant lifecycle; flags paths where an exception or early return
  escapes between acquire and release without try/finally or an explicit
  ownership handoff.
- :mod:`repro.analysis.jaxpr_audit` — trace-level audit of every registered
  kernel entry point: exactly one ``pallas_call`` per fused op, no
  pool-sized-copy primitives, donation really consumes its buffer, no silent
  int64 promotion, declared-vs-observed boundary-transfer budget.
- :mod:`repro.analysis.lockset` — derives the shared-mutable-state map of the
  cluster plane as a checked manifest, plus a test-time ``LocksetMonitor``
  that records accessor-worker sets per shared object and fails on
  unsynchronized cross-worker mutation.
- :mod:`repro.analysis.importgraph` — warn-only import-graph hygiene report
  (modules under ``src/repro`` unreachable from tests/examples/benchmarks).

Findings carry ``file:line``, an invariant rule name, and honor waiver
comments of the form ``# libra: waive[RULE] reason`` (reason mandatory).
CLI: ``python -m repro.analysis`` — see ``docs/API.md``.
"""
from repro.analysis.common import Finding, Report, apply_waivers

__all__ = ["Finding", "Report", "apply_waivers"]
