"""Shared finding/waiver plumbing for the analysis passes.

Every pass emits :class:`Finding` records carrying ``file:line``, the rule
name (the invariant that failed), and a message.  A source line may waive a
rule with an explanatory comment::

    pages = risky_thing()  # libra: waive[OWN001] freed by caller via handoff X

The waiver may sit on the flagged line or on the line directly above it.
A waiver without a reason is itself a finding (``WAIVER001``) — the gate
runs at zero *unexplained* findings, not zero findings.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

WAIVER_RE = re.compile(r"#\s*libra:\s*waive\[([A-Z0-9_]+)\]\s*(.*)")


@dataclass
class Finding:
    """One rule violation at a source location."""
    file: str
    line: int
    rule: str
    message: str
    waived: bool = False
    waiver_reason: str = ""

    def format(self) -> str:
        tag = " (waived: %s)" % self.waiver_reason if self.waived else ""
        return f"{self.file}:{self.line} [{self.rule}] {self.message}{tag}"


@dataclass
class Report:
    """Findings from one pass, split by waiver status."""
    name: str
    active: List[Finding] = field(default_factory=list)
    waived: List[Finding] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.active

    def summary(self) -> str:
        return (f"{self.name}: {len(self.active)} finding(s), "
                f"{len(self.waived)} waived")

    def lines(self) -> List[str]:
        out = [self.summary()]
        out += ["  " + f.format() for f in self.active]
        out += ["  " + f.format() for f in self.waived]
        return out


def scan_waivers(source: str) -> Dict[int, Tuple[str, str]]:
    """Map line number -> (rule, reason) for every waiver comment."""
    out: Dict[int, Tuple[str, str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = WAIVER_RE.search(text)
        if m:
            out[i] = (m.group(1), m.group(2).strip())
    return out


def apply_waivers(
    findings: Iterable[Finding],
    waivers_by_file: Dict[str, Dict[int, Tuple[str, str]]],
    rules: Iterable[str] | None = None,
) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (active, waived).

    A finding is waived when a matching-rule waiver comment sits on the
    flagged line or the line directly above.  Reasonless waivers surface as
    ``WAIVER001`` findings; waivers that match nothing surface as
    ``WAIVER002`` (stale) so dead waivers cannot mask future regressions.
    ``rules`` restricts the stale-waiver sweep to the rule family a pass
    owns, so passes sharing a file do not flag each other's waivers.
    """
    rule_set = set(rules) if rules is not None else None
    active: List[Finding] = []
    waived: List[Finding] = []
    used: Dict[Tuple[str, int], bool] = {}
    for f in findings:
        file_waivers = waivers_by_file.get(f.file, {})
        hit = None
        for ln in (f.line, f.line - 1):
            w = file_waivers.get(ln)
            if w and w[0] == f.rule:
                hit = (ln, w[1])
                break
        if hit is None:
            active.append(f)
            continue
        ln, reason = hit
        used[(f.file, ln)] = True
        if not reason:
            active.append(Finding(f.file, ln, "WAIVER001",
                                  f"waiver for {f.rule} has no reason"))
        f.waived = True
        f.waiver_reason = reason or "<missing>"
        waived.append(f)
    for file, file_waivers in waivers_by_file.items():
        for ln, (rule, _reason) in file_waivers.items():
            if rule_set is not None and rule not in rule_set:
                continue
            if not used.get((file, ln)):
                active.append(Finding(
                    file, ln, "WAIVER002",
                    f"stale waiver: no {rule} finding at this line"))
    return active, waived


def build_report(name: str, findings: Sequence[Finding],
                 sources: Dict[str, str],
                 rules: Iterable[str] | None = None) -> Report:
    """Apply per-file waivers from ``sources`` (file -> text) and package."""
    waivers = {file: scan_waivers(text) for file, text in sources.items()}
    active, waived = apply_waivers(list(findings), waivers, rules=rules)
    return Report(name=name, active=active, waived=waived)
