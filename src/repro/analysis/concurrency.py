"""Pass 4 — concurrency verifier: lock order, atomicity, steal-path.

:mod:`repro.analysis.lockset` answers "is each cross-worker mutation site
locked?".  This pass answers the *global* questions a worker-per-thread
executor adds on top — the readiness gate ``ClusterRuntime.run_parallel
(threads=True)`` ships behind:

- **Lock order** (``DEAD001``–``DEAD003``) — statically derive the lock
  *acquisition graph*: every ``with <x>.lock`` / ``with plane_lock(...)``
  entry (plus ``*_locked`` functions, whose body holds the plane lock from
  entry, and calls into the self-locking ``SteeringPolicy``/``HealthTable``
  mutators, which acquire their leaf lock internally) is classified into a
  lock *class* and every nested acquisition becomes an edge.  ``DEAD001``
  flags cycles (a static deadlock), ``DEAD002`` flags order inversions
  against the committed rank table (acquisition must follow strictly
  increasing rank: plane=0 < registry=1 < alloc=2 < steering/health=3 —
  the :mod:`repro.core.sync` contract), ``DEAD003`` flags unclassifiable
  acquisitions and drift against the committed
  ``lock_hierarchy_manifest.json`` (line-number-free; re-commit with
  ``python -m repro.analysis --write-manifest`` after review).  In a
  cluster the plane/registry/alloc classes are today one lock object
  (reentrant), so the graph is the contract that keeps a future
  per-island fine-graining deadlock-free, not a present-tense hazard —
  which is exactly when it is cheap to enforce.

- **Atomicity** (``ATOM001``–``ATOM003``) — a guard (``peek``,
  ``can_admit``, ``above_watermark``, ``find_owner``, ``torn_down``,
  ``healthy``) and the mutation it authorizes form one invariant; the
  lock must span the *whole* region.  ``ATOM001``: a guard call on
  peer-rooted state whose test dominates a plane mutation of peer-rooted
  state, with the region not inside one continuous lock scope
  (check-then-act).  ``ATOM002``: a read-modify-write (``+=`` and
  friends) of allocator/registry state in a plane file outside any lock
  scope (lost-update).  ``ATOM003``: a guard result produced in one lock
  scope and consumed in a *different* scope of the same lock class —
  release/re-acquire fragmentation: the invariant the guard established
  died at the first release.  (``resolve`` is deliberately *not* a
  guard: a resolved entry is refcount-pinned, which is why the unlocked
  resolve → locked release pattern in ``libra_send`` is sound.)

- **Steal path** (``STEAL001``–``STEAL002``) — everything reachable from
  a stolen quantum must be lock-protected or owner-pinned.
  ``STEAL001``: servicing a channel whose provenance is a cross-runtime
  poll harvest (the steal set) under a worker context without holding
  the cluster lock.  ``STEAL002``: a stolen reference escaping the
  locked handoff region into an attribute (``self.<x>``/``obj.<x>``) —
  local bookkeeping containers (the ``stolen`` membership filter) are
  owner-pinned to the scheduler and allowed.

All three scanners take a ``{relpath: source}`` mapping so tests can run
them over synthetic trees; :func:`run` reads the real files.
"""
from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, Report, build_report
from repro.analysis.lockset import (
    PLANE_FILES,
    PLANE_MUTATORS,
    REPO_ROOT,
    _attr_root,
    _functions,
    _peer_names,
)

HIERARCHY_PATH = (Path(__file__).resolve().parent
                  / "lock_hierarchy_manifest.json")

CONCURRENCY_RULES = ("DEAD001", "DEAD002", "DEAD003",
                     "ATOM001", "ATOM002", "ATOM003",
                     "STEAL001", "STEAL002")

#: the committed lock hierarchy: acquisition must follow strictly
#: increasing rank; same-class re-acquisition is reentrant and free
LOCK_RANKS = {"plane": 0, "registry": 1, "alloc": 2,
              "steering": 3, "health": 3}

#: classes whose ``self.lock`` is a leaf lock of their own class
SELF_LOCK_CLASSES = {"SteeringPolicy": "steering", "HealthTable": "health"}

#: method names that internally acquire a leaf lock when called
#: (``tick`` is deliberately absent: it collides with ``LibraStack.tick``)
LEAF_MUTATOR_CLASSES = {
    "worker_for": "steering", "forget": "steering",
    "resteer": "steering", "remove_worker": "steering",
    "note_failure": "health", "note_success": "health",
    "mark_down": "health", "mark_up": "health",
}

#: check-then-act guards: their result authorizes a mutation
GUARD_CALLS = frozenset({
    "peek", "_peek_message", "can_admit", "above_watermark",
    "find_owner", "torn_down", "healthy",
})

#: files the pass scans on the real tree
CONCURRENCY_FILES = PLANE_FILES + (
    "src/repro/core/ingress.py",
    "src/repro/core/policy.py",
)


# -- lock-acquisition classification ----------------------------------------

def _last_segment(expr: ast.expr) -> str:
    """Final attribute (or bare name) of a chain: ``pool.alloc`` -> alloc."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return ""


def classify_acquisition(expr: ast.expr,
                         owner_class: Optional[str]) -> Optional[str]:
    """Lock class of a ``with``-context expression, or None if it is not
    a lock acquisition at all. ``"?"`` means a lock we cannot classify."""
    # with <chain>.lock:
    if isinstance(expr, ast.Attribute) and expr.attr == "lock":
        if isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                and owner_class in SELF_LOCK_CLASSES:
            return SELF_LOCK_CLASSES[owner_class]
        # self.lock in LibraCluster / cluster.lock / self.cluster.lock —
        # anything reachable as a bare ``.lock`` on the cluster plane
        chain = ast.unparse(expr.value)
        if "cluster" in chain or chain == "self":
            return "plane"
        return "?"
    # with plane_lock(<obj>):
    if isinstance(expr, ast.Call):
        f = expr.func
        name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", "")
        if name != "plane_lock":
            return None
        if not expr.args:
            return "?"
        seg = _last_segment(expr.args[0])
        if "alloc" in seg:
            return "alloc"
        if "registry" in seg or seg in ("oreg", "reg"):
            return "registry"
        return "?"
    return None


def _leaf_call_class(node: ast.Call) -> Optional[str]:
    """Lock class a call acquires internally (self-locking mutators)."""
    if isinstance(node.func, ast.Attribute):
        return LEAF_MUTATOR_CLASSES.get(node.func.attr)
    return None


# -- the statement walker (lock stack + scope identity) ---------------------

class _LockWalker:
    """Walks one function's statements tracking the stack of held lock
    classes and the identity of each ``with`` scope, invoking per-node
    callbacks supplied by the individual passes."""

    def __init__(self, filename: str, qualname: str, func: ast.AST,
                 owner_class: Optional[str]):
        self.filename = filename
        self.qualname = qualname
        self.func = func
        self.owner_class = owner_class
        # (lock class, scope id) innermost-last; a *_locked function body
        # holds the plane lock with the function itself as the scope
        self.stack: List[Tuple[str, int]] = []
        if func.name.endswith("_locked"):
            self.stack.append(("plane", id(func)))

    # hooks overridden by passes
    def on_acquire(self, cls: str, node: ast.AST) -> None: ...
    def on_unclassifiable(self, node: ast.AST) -> None: ...
    def on_stmt(self, node: ast.AST) -> None: ...

    def held(self) -> List[str]:
        return [c for c, _ in self.stack]

    def scope_of(self, cls: str) -> Optional[int]:
        for c, sid in reversed(self.stack):
            if c == cls:
                return sid
        return None

    def run(self) -> None:
        if self.func.name == "__init__":
            return  # construction happens-before publication
        for stmt in self.func.body:
            self._scan(stmt)

    def _scan(self, node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            pushed = 0
            for item in node.items:
                cls = classify_acquisition(item.context_expr,
                                           self.owner_class)
                if cls is None:
                    continue
                if cls == "?":
                    self.on_unclassifiable(item.context_expr)
                    continue
                self.on_acquire(cls, node)
                self.stack.append((cls, id(node)))
                pushed += 1
            for s in node.body:
                self._scan(s)
            for _ in range(pushed):
                self.stack.pop()
            return
        self.on_stmt(node)
        # leaf acquisitions ride ordinary expressions
        for sub in self._walk_exprs(node):
            if isinstance(sub, ast.Call):
                cls = self._call_leaf(sub)
                if cls is not None:
                    self.on_acquire(cls, sub)
        for field in ("body", "orelse", "finalbody"):
            for s in getattr(node, field, []) or []:
                self._scan(s)
        for h in getattr(node, "handlers", []) or []:
            for s in h.body:
                self._scan(s)

    @staticmethod
    def _walk_exprs(node: ast.AST):
        """Expression-level descendants only — nested statements get
        their own :meth:`_scan` visit with their own lock state."""
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                continue
            yield from _LockWalker._walk_exprs(child)

    def _call_leaf(self, node: ast.Call) -> Optional[str]:
        cls = _leaf_call_class(node)
        if cls is None:
            return None
        # calls on self inside the owning class are the internal
        # delegation pattern (resteer -> worker_for), not a re-acquisition
        if self.owner_class in SELF_LOCK_CLASSES \
                and isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self":
            return None
        return cls


def _owner_classes(tree: ast.Module) -> Dict[int, str]:
    """id(function node) -> enclosing class name."""
    out: Dict[int, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    out[id(sub)] = node.name
    return out


# -- pass (a): lock-order / deadlock graph ----------------------------------

class _EdgeWalker(_LockWalker):
    def __init__(self, *a, edges: List[dict], findings: List[Finding]):
        super().__init__(*a)
        self.edges = edges
        self.findings = findings

    def on_acquire(self, cls: str, node: ast.AST) -> None:
        for held in self.held():
            if held == cls:
                continue  # reentrant same-class: always fine
            self.edges.append({"src": held, "dst": cls,
                               "file": self.filename,
                               "func": self.qualname,
                               "line": node.lineno})

    def on_unclassifiable(self, node: ast.AST) -> None:
        self.findings.append(Finding(
            self.filename, node.lineno, "DEAD003",
            f"{self.qualname}: lock acquisition "
            f"'{ast.unparse(node)}' cannot be classified into the lock "
            f"hierarchy (plane/registry/alloc/steering/health) — name "
            f"the lock so its rank is derivable"))


def derive_lock_graph(sources: Dict[str, str]
                      ) -> Tuple[List[dict], List[Finding]]:
    """(acquisition edges, DEAD003 classification findings)."""
    edges: List[dict] = []
    findings: List[Finding] = []
    for rel, text in sorted(sources.items()):
        tree = ast.parse(text, filename=rel)
        owners = _owner_classes(tree)
        for qualname, func in _functions(tree):
            w = _EdgeWalker(rel, qualname, func, owners.get(id(func)),
                            edges=edges, findings=findings)
            w.run()
    return edges, findings


def check_lock_order(edges: Sequence[dict]) -> List[Finding]:
    """DEAD002 rank inversions + DEAD001 cycles over the class graph."""
    findings: List[Finding] = []
    for e in edges:
        if LOCK_RANKS[e["src"]] >= LOCK_RANKS[e["dst"]]:
            findings.append(Finding(
                e["file"], e["line"], "DEAD002",
                f"{e['func']}: acquires '{e['dst']}' "
                f"(rank {LOCK_RANKS[e['dst']]}) while holding "
                f"'{e['src']}' (rank {LOCK_RANKS[e['src']]}) — "
                f"acquisition order must follow strictly increasing rank"))
    graph: Dict[str, Set[str]] = {}
    rep: Dict[Tuple[str, str], dict] = {}
    for e in edges:
        graph.setdefault(e["src"], set()).add(e["dst"])
        rep.setdefault((e["src"], e["dst"]), e)
    seen_cycles: Set[Tuple[str, ...]] = set()

    def dfs(node: str, path: List[str], on_path: Set[str]) -> None:
        for nxt in sorted(graph.get(node, ())):
            if nxt in on_path:
                cyc = path[path.index(nxt):] + [nxt]
                canon = tuple(sorted(cyc[:-1]))
                if canon in seen_cycles:
                    continue
                seen_cycles.add(canon)
                e = rep[(cyc[-2], cyc[-1])]
                findings.append(Finding(
                    e["file"], e["line"], "DEAD001",
                    f"lock-order cycle {' -> '.join(cyc)}: two threads "
                    f"taking these locks in opposing orders deadlock"))
                continue
            dfs(nxt, path + [nxt], on_path | {nxt})

    for start in sorted(graph):
        dfs(start, [start], {start})
    return findings


def hierarchy_manifest(edges: Sequence[dict]) -> dict:
    """Line-number-free manifest of the derived graph."""
    dedup = sorted({(e["src"], e["dst"], e["file"], e["func"])
                    for e in edges})
    return {"version": 1,
            "ranks": dict(sorted(LOCK_RANKS.items())),
            "edges": [{"src": s, "dst": d, "file": f, "func": fn}
                      for s, d, f, fn in dedup]}


def write_hierarchy_manifest(root: Path = REPO_ROOT,
                             path: Path = HIERARCHY_PATH) -> dict:
    sources = {rel: (root / rel).read_text() for rel in CONCURRENCY_FILES}
    edges, _ = derive_lock_graph(sources)
    manifest = hierarchy_manifest(edges)
    path.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
    return manifest


def compare_hierarchy(derived: dict,
                      committed: Optional[dict]) -> List[Finding]:
    loc = str(HIERARCHY_PATH.relative_to(REPO_ROOT))
    if committed is None:
        return [Finding(loc, 0, "DEAD003",
                        "lock-hierarchy manifest missing — generate with "
                        "`python -m repro.analysis --write-manifest` and "
                        "commit it")]
    findings: List[Finding] = []
    if committed.get("ranks") != derived["ranks"]:
        findings.append(Finding(
            loc, 0, "DEAD003",
            f"lock rank table drift: committed "
            f"{committed.get('ranks')} vs derived {derived['ranks']} — "
            f"review the ordering change, then re-run --write-manifest"))
    key = lambda e: (e["src"], e["dst"], e["file"], e["func"])  # noqa: E731
    new = {key(e) for e in derived["edges"]}
    old = {key(e) for e in committed.get("edges", [])}
    for s, d, f, fn in sorted(new - old):
        findings.append(Finding(
            loc, 0, "DEAD003",
            f"new lock-order edge {s} -> {d} in {fn} ({f}) — review the "
            f"nesting, then re-run --write-manifest"))
    for s, d, f, fn in sorted(old - new):
        findings.append(Finding(
            loc, 0, "DEAD003",
            f"manifest lock-order edge {s} -> {d} in {fn} ({f}) no "
            f"longer exists — re-run --write-manifest"))
    return findings


# -- pass (b): atomicity lint -----------------------------------------------

def _guard_call_on_peer(expr: ast.AST, peers: Set[str]) -> Optional[ast.Call]:
    """A GUARD_CALLS call whose receiver is peer-rooted, if any."""
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute) \
                and node.func.attr in GUARD_CALLS:
            root = _attr_root(node.func.value)
            if root in peers:
                return node
        # find_owner & co are guards regardless of receiver: their
        # *result* is the peer handle the region then mutates
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                getattr(f, "id", "")
            if name == "find_owner":
                return node
    return None


def _peer_mutation(region: Sequence[ast.stmt],
                   peers: Set[str]) -> Optional[ast.Call]:
    for stmt in region:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Attribute) \
                    and node.func.attr in PLANE_MUTATORS \
                    and _attr_root(node.func.value) in peers:
                return node
    return None


def _rmw_target(node: ast.AST) -> Optional[str]:
    """Dotted path of a read-modify-write on allocator/registry state."""
    if not isinstance(node, ast.AugAssign):
        return None
    t = node.target
    if not isinstance(t, (ast.Attribute, ast.Subscript)):
        return None
    parts: List[str] = []
    cur: ast.AST = t
    while isinstance(cur, (ast.Attribute, ast.Subscript)):
        if isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
        cur = cur.value
    if any(p in ("alloc", "registry") for p in parts):
        return ast.unparse(t)
    return None


class _AtomWalker(_LockWalker):
    def __init__(self, *a, findings: List[Finding]):
        super().__init__(*a)
        self.findings = findings
        self.peers = _peer_names(self.func)
        # guard-result names: name -> (lock class, scope id) at production
        self.guard_scopes: Dict[str, Dict[str, Optional[int]]] = {}

    def on_stmt(self, node: ast.AST) -> None:
        held = self.held()
        # ATOM001: check-then-act across peer state
        if isinstance(node, (ast.If, ast.While)):
            g = _guard_call_on_peer(node.test, self.peers)
            if g is not None:
                m = _peer_mutation(list(node.body) + list(node.orelse),
                                   self.peers)
                if m is not None and not held:
                    self.findings.append(Finding(
                        self.filename, node.lineno, "ATOM001",
                        f"{self.qualname}: '{ast.unparse(g.func)}()' "
                        f"guards a peer-state mutation at line {m.lineno} "
                        f"but the region runs outside any lock — the "
                        f"check and the act must share one lock scope"))
        # ATOM002: unlocked RMW on allocator/registry state
        path = _rmw_target(node)
        if path is not None and not held:
            root = _attr_root(node.target)
            if root in self.peers or root == "self" or root in (
                    "pool", "alloc", "registry"):
                self.findings.append(Finding(
                    self.filename, node.lineno, "ATOM002",
                    f"{self.qualname}: read-modify-write of '{path}' "
                    f"outside any lock scope — a concurrent writer makes "
                    f"this a lost update"))
        # ATOM003: guard results crossing disjoint same-class scopes
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            g = self._any_guard_call(node.value)
            if g is not None and self.stack:
                cls, sid = self.stack[-1]
                self.guard_scopes[node.targets[0].id] = {
                    "cls": cls, "sid": sid, "line": node.lineno,
                    "call": ast.unparse(g.func)}
        for sub in self._walk_exprs(node):
            if isinstance(sub, ast.Name) and isinstance(sub.ctx, ast.Load) \
                    and sub.id in self.guard_scopes:
                info = self.guard_scopes[sub.id]
                cur = self.scope_of(info["cls"])
                if cur is not None and cur != info["sid"]:
                    self.findings.append(Finding(
                        self.filename, sub.lineno, "ATOM003",
                        f"{self.qualname}: '{sub.id}' (from "
                        f"{info['call']}() at line {info['line']}) is "
                        f"consumed in a different '{info['cls']}' lock "
                        f"scope than produced — the release/re-acquire "
                        f"fragmented the atomic region"))
                    del self.guard_scopes[sub.id]
                    break

    @staticmethod
    def _any_guard_call(expr: ast.AST) -> Optional[ast.Call]:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute) \
                    and node.func.attr in GUARD_CALLS:
                return node
        return None


def scan_atomicity(sources: Dict[str, str]) -> List[Finding]:
    findings: List[Finding] = []
    for rel, text in sorted(sources.items()):
        tree = ast.parse(text, filename=rel)
        owners = _owner_classes(tree)
        for qualname, func in _functions(tree):
            _AtomWalker(rel, qualname, func, owners.get(id(func)),
                        findings=findings).run()
    return findings


# -- pass (c): steal-path ownership -----------------------------------------

def _steal_names(func: ast.AST) -> Set[str]:
    """Names whose provenance is a cross-runtime poll harvest (the steal
    candidate set): seeded by expressions containing a ``.poll()`` call,
    propagated through assignments, comprehensions and for-targets."""
    tainted: Set[str] = set()

    def has_taint(expr: ast.AST) -> bool:
        for node in ast.walk(expr):
            if isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute) and node.func.attr == "poll":
                return True
            if isinstance(node, ast.Name) and node.id in tainted:
                return True
        return False

    changed = True
    while changed:
        changed = False
        for node in ast.walk(func):
            new: List[str] = []
            # only names being BOUND are tainted — the root of an
            # attribute target (`self` in `self.x = take`) is a read
            if isinstance(node, ast.Assign) and has_taint(node.value):
                for t in node.targets:
                    new.extend(n.id for n in ast.walk(t)
                               if isinstance(n, ast.Name)
                               and isinstance(n.ctx, ast.Store))
            elif isinstance(node, ast.For) and has_taint(node.iter):
                new.extend(n.id for n in ast.walk(node.target)
                           if isinstance(n, ast.Name)
                           and isinstance(n.ctx, ast.Store))
            for n in new:
                if n not in tainted:
                    tainted.add(n)
                    changed = True
    return tainted


class _StealWalker(_LockWalker):
    def __init__(self, *a, findings: List[Finding]):
        super().__init__(*a)
        self.findings = findings
        self.tainted = _steal_names(self.func)
        self.worker_depth = 0

    def _scan(self, node: ast.AST) -> None:
        # as_worker() scopes mark a worker-context quantum
        entered = False
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                src = ast.unparse(item.context_expr)
                if "as_worker(" in src:
                    entered = True
        if entered:
            self.worker_depth += 1
        super()._scan(node)
        if entered:
            self.worker_depth -= 1

    def on_stmt(self, node: ast.AST) -> None:
        held = self.held()
        for sub in self._walk_exprs(node):
            # STEAL001: executing a stolen quantum without the lock
            if isinstance(sub, ast.Call) and isinstance(
                    sub.func, ast.Attribute) and sub.func.attr == "service" \
                    and isinstance(sub.func.value, ast.Name) \
                    and sub.func.value.id in self.tainted:
                if self.worker_depth and "plane" not in held:
                    self.findings.append(Finding(
                        self.filename, sub.lineno, "STEAL001",
                        f"{self.qualname}: stolen quantum "
                        f"'{ast.unparse(sub.func)}()' executes in a "
                        f"worker context without the cluster lock — the "
                        f"donor's pool/registry are reachable unlocked"))
            # STEAL002: stolen reference escaping into an attribute
            if isinstance(sub, ast.Assign):
                if any(isinstance(t, (ast.Attribute, ast.Subscript))
                       and isinstance(_attr_root(t), str)
                       and _attr_root(t) not in self.tainted
                       for t in sub.targets) \
                        and self._names(sub.value) & self.tainted \
                        and any(isinstance(t, ast.Attribute)
                                or (isinstance(t, ast.Subscript)
                                    and isinstance(t.value, ast.Attribute))
                                for t in sub.targets):
                    self.findings.append(Finding(
                        self.filename, sub.lineno, "STEAL002",
                        f"{self.qualname}: stolen reference "
                        f"'{ast.unparse(sub.value)}' escapes the handoff "
                        f"into '{ast.unparse(sub.targets[0])}' — it "
                        f"outlives the lock scope that pinned it"))
            if isinstance(sub, ast.Call) and isinstance(
                    sub.func, ast.Attribute) \
                    and sub.func.attr in ("append", "add", "setdefault") \
                    and isinstance(sub.func.value, ast.Attribute) \
                    and self._call_args_tainted(sub):
                self.findings.append(Finding(
                    self.filename, sub.lineno, "STEAL002",
                    f"{self.qualname}: stolen reference stored into "
                    f"'{ast.unparse(sub.func.value)}' — it outlives the "
                    f"lock scope that pinned it"))

    @staticmethod
    def _names(expr: ast.AST) -> Set[str]:
        return {n.id for n in ast.walk(expr) if isinstance(n, ast.Name)}

    def _call_args_tainted(self, call: ast.Call) -> bool:
        return any(self._names(a) & self.tainted for a in call.args)


def scan_steal(sources: Dict[str, str]) -> List[Finding]:
    findings: List[Finding] = []
    for rel, text in sorted(sources.items()):
        tree = ast.parse(text, filename=rel)
        owners = _owner_classes(tree)
        for qualname, func in _functions(tree):
            _StealWalker(rel, qualname, func, owners.get(id(func)),
                         findings=findings).run()
    return findings


# -- entry point ------------------------------------------------------------

def run(root: Path = REPO_ROOT) -> Report:
    sources = {rel: (root / rel).read_text() for rel in CONCURRENCY_FILES}
    edges, findings = derive_lock_graph(sources)
    findings.extend(check_lock_order(edges))
    committed = None
    if HIERARCHY_PATH.exists():
        committed = json.loads(HIERARCHY_PATH.read_text())
    findings.extend(compare_hierarchy(hierarchy_manifest(edges), committed))
    findings.extend(scan_atomicity(sources))
    findings.extend(scan_steal(sources))
    return build_report("concurrency", findings, sources,
                        rules=CONCURRENCY_RULES)
