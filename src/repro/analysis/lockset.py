"""Pass 3 — lockset checker for the cluster plane.

The ROADMAP's worker-per-thread executor turns every object reachable from
two workers into a data race. This pass derives that shared-mutable-state
map *statically* and verifies the locking discipline of
:mod:`repro.core.sync` before any thread exists — the eBPF-verifier move
of the source paper applied to the repo's own control plane.

Three static checks plus a committed manifest:

- **Shared-class map** — for each class shared across workers
  (``AnchorPool``, ``VpiRegistry``, ``SteeringPolicy``, ``HealthTable``)
  derive the set of attributes its methods mutate (AST attribute-write
  analysis). This is the state a thread could corrupt.
- **Cross-worker mutation sites** (``LOCK001``) — in the plane files
  (``cluster.py``, ``egress.py``, ``stack.py``), find every statement that
  mutates *peer-rooted* state — a receiver whose provenance traces to
  another worker (``find_owner``/``pool_for_entry``/``pool_router``
  results, ``_worker_by_pool`` lookups, ``.owner_registry`` handles,
  iteration over ``.workers``, the ``dst_stack`` parameter) — and require
  it to run under a lock: lexically inside ``with <x>.lock:`` /
  ``with plane_lock(...):``, or inside a ``*_locked`` function (whose
  callers must themselves hold the lock — also checked).
- **Lock plumbing** (``LOCK003``) — ``SteeringPolicy`` and ``HealthTable``
  must be self-locking (every mutator takes ``self.lock``), and
  ``LibraCluster.__init__`` must attach the plane lock to each worker's
  ``alloc`` and ``registry``.
- **Manifest** (``LOCK002``) — the derived map is compared against the
  committed ``shared_state_manifest.json`` (line-number-free, so pure code
  motion never trips it). New shared state or a new cross-worker touch
  point must be reviewed and re-committed:
  ``python -m repro.analysis --write-manifest``.

Test-time, :class:`LocksetMonitor` instruments every worker's allocator
and registry mutators, records which worker context
(``LibraCluster.current_worker``) touches each object, and emits
``LOCK004`` when a cross-worker mutation runs without the plane lock held
— the dynamic readiness gate the threaded executor must pass. Telemetry
counters (``stats`` dicts, ``resolve`` hit/miss bumps) are deliberately
out of scope: they are benign-racy by design and never feed back into
datapath decisions.
"""
from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.common import Finding, Report, build_report

REPO_ROOT = Path(__file__).resolve().parents[3]
MANIFEST_PATH = Path(__file__).resolve().parent / "shared_state_manifest.json"

LOCKSET_RULES = ("LOCK001", "LOCK002", "LOCK003", "LOCK004")

#: classes whose instances are reachable from >= 2 workers
SHARED_CLASSES = {
    "AnchorPool": "src/repro/core/anchor_pool.py",
    "VpiRegistry": "src/repro/core/vpi.py",
    "SteeringPolicy": "src/repro/core/cluster.py",
    "HealthTable": "src/repro/core/policy.py",
}

#: files whose functions can reach a PEER worker's state
PLANE_FILES = (
    "src/repro/core/cluster.py",
    "src/repro/core/egress.py",
    "src/repro/core/stack.py",
)

#: methods that mutate cluster-plane state when called on a peer object
PLANE_MUTATORS = frozenset({
    # AnchorPool
    "alloc_page", "alloc_sequence", "alloc_batch", "free_pages_list",
    "free_batch", "retain", "defer_free", "expire_deferred",
    "export_grant", "release_export",
    "stage_transfer", "commit_transfer", "abort_transfer",
    # VpiRegistry
    "register", "import_grant", "release", "drop", "begin_teardown",
    "expire_teardowns",
})
#: generic container mutators — a mutation when the receiver is peer-rooted
CONTAINER_MUTATORS = frozenset({
    "append", "extend", "add", "insert", "pop", "remove", "clear",
    "update", "setdefault", "sort",
})

#: provenance: names whose values reach a peer worker
PEER_PARAMS = frozenset({"dst_stack"})
PEER_RESOLVERS = frozenset({"find_owner", "pool_for_entry", "pool_router"})
PEER_ATTRS = frozenset({"owner_registry"})

#: self-locking classes: these mutators must take self.lock internally
SELF_LOCKED = {
    "SteeringPolicy": ("worker_for", "forget", "resteer", "remove_worker"),
    "HealthTable": ("note_failure", "note_success", "tick",
                    "mark_down", "mark_up"),
}


# -- shared-class attribute-write analysis ---------------------------------

def _attr_root(expr: ast.expr) -> Optional[str]:
    """Leftmost Name of an attribute/subscript/call chain."""
    while isinstance(expr, (ast.Attribute, ast.Subscript, ast.Call)):
        expr = expr.func if isinstance(expr, ast.Call) else expr.value
    return expr.id if isinstance(expr, ast.Name) else None


def _self_attr(expr: ast.expr) -> Optional[str]:
    """The X of a ``self.X``-rooted chain (attribute, subscript, call)."""
    node = expr
    prev = None
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        prev = node
        node = node.func if isinstance(node, ast.Call) else node.value
    if isinstance(node, ast.Name) and node.id == "self" and \
            isinstance(prev, ast.Attribute):
        return prev.attr
    return None


def _mutated_attrs(cls: ast.ClassDef) -> Set[str]:
    """Attributes of ``self`` that any method of ``cls`` writes."""
    out: Set[str] = set()
    for node in ast.walk(cls):
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                out.add(attr)
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute) \
                and node.func.attr in CONTAINER_MUTATORS:
            attr = _self_attr(node.func.value)
            if attr is not None:
                out.add(attr)
    return out


def derive_shared_classes(root: Path = REPO_ROOT) -> Dict[str, List[str]]:
    """{class name: sorted mutated attributes} for every shared class."""
    out: Dict[str, List[str]] = {}
    for name, rel in SHARED_CLASSES.items():
        tree = ast.parse((root / rel).read_text(), filename=rel)
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and node.name == name:
                out[name] = sorted(_mutated_attrs(node))
    return out


# -- cross-worker mutation-site analysis -----------------------------------

def _expr_is_peer(expr: ast.expr, peers: Set[str]) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in peers:
            return True
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                getattr(f, "id", "")
            if name in PEER_RESOLVERS:
                return True
            if name == "get" and isinstance(f, ast.Attribute) and \
                    "_worker_by_pool" in ast.dump(f.value):
                return True
        if isinstance(node, ast.Attribute) and node.attr in PEER_ATTRS:
            return True
        if isinstance(node, ast.Attribute) and node.attr == "workers":
            return True
    return False


def _peer_names(func: ast.AST) -> Set[str]:
    """Names in ``func`` whose provenance traces to a peer worker
    (flow-insensitive union, iterated to a fixpoint)."""
    peers: Set[str] = set()
    args = getattr(func, "args", None)
    if args is not None:
        peers |= PEER_PARAMS & {a.arg for a in args.args}
    changed = True
    while changed:
        changed = False
        for node in ast.walk(func):
            new: List[str] = []
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                t = node.targets[0]
                if isinstance(t, ast.Name) and \
                        _expr_is_peer(node.value, peers):
                    new.append(t.id)
                elif isinstance(t, ast.Tuple) and \
                        isinstance(node.value, ast.Tuple):
                    for tt, vv in zip(t.elts, node.value.elts):
                        if isinstance(tt, ast.Name) and \
                                _expr_is_peer(vv, peers):
                            new.append(tt.id)
            elif isinstance(node, ast.For) and \
                    _expr_is_peer(node.iter, peers):
                new.extend(n.id for n in ast.walk(node.target)
                           if isinstance(n, ast.Name))
            for n in new:
                if n not in peers:
                    peers.add(n)
                    changed = True
    return peers


def _is_lock_ctx(expr: ast.expr) -> bool:
    """``with self.lock:`` / ``with cluster.lock:`` /
    ``with plane_lock(...):``"""
    if isinstance(expr, ast.Attribute) and expr.attr == "lock":
        return True
    if isinstance(expr, ast.Call):
        f = expr.func
        name = f.attr if isinstance(f, ast.Attribute) else \
            getattr(f, "id", "")
        return name == "plane_lock"
    return False


class _SiteScanner:
    """Finds cross-worker mutation sites in one function and records
    whether each runs under a lock."""

    def __init__(self, filename: str, qualname: str, func: ast.AST):
        self.filename = filename
        self.qualname = qualname
        self.func = func
        self.peers = _peer_names(func)
        self.sites: List[dict] = []
        self.findings: List[Finding] = []

    def run(self) -> None:
        if self.func.name == "__init__":
            # construction happens-before publication: an object being
            # wired up in __init__ is not yet reachable from any worker
            return
        start_locked = self.func.name.endswith("_locked")
        for stmt in self.func.body:
            self._scan(stmt, start_locked)

    def _scan(self, node: ast.AST, locked: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested defs are scanned as their own functions
        if isinstance(node, (ast.With, ast.AsyncWith)):
            inner = locked or any(_is_lock_ctx(i.context_expr)
                                  for i in node.items)
            for item in node.items:
                self._visit_exprs(item.context_expr, locked)
            for s in node.body:
                self._scan(s, inner)
            return
        self._visit_exprs(node, locked)
        for field in ("body", "orelse", "finalbody"):
            for s in getattr(node, field, []) or []:
                self._scan(s, locked)
        for h in getattr(node, "handlers", []) or []:
            for s in h.body:
                self._scan(s, locked)

    @staticmethod
    def _walk_exprs(node: ast.AST):
        """Walk expression-level descendants only — nested statements are
        scanned by :meth:`_scan` with their own lock state."""
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.stmt):
                continue
            yield from _SiteScanner._walk_exprs(child)

    def _visit_exprs(self, stmt: ast.AST, locked: bool) -> None:
        for node in self._walk_exprs(stmt):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Attribute):
                meth = node.func.attr
                root = _attr_root(node.func.value)
                if root in self.peers and (
                        meth in PLANE_MUTATORS
                        or meth in CONTAINER_MUTATORS):
                    self._site(node, ast.unparse(node.func), "call", locked)
                elif meth.endswith("_locked") and not locked:
                    self.findings.append(Finding(
                        self.filename, node.lineno, "LOCK001",
                        f"{self.qualname}: call to {meth}() outside a "
                        f"lock-holding context — *_locked callees require "
                        f"the caller to hold the plane lock"))
            targets: List[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            for t in targets:
                if isinstance(t, (ast.Attribute, ast.Subscript)) and \
                        _attr_root(t) in self.peers:
                    self._site(node, ast.unparse(t), "store", locked)

    def _site(self, node: ast.AST, path: str, kind: str,
              locked: bool) -> None:
        self.sites.append({"file": self.filename, "func": self.qualname,
                           "path": path, "kind": kind})
        if not locked:
            self.findings.append(Finding(
                self.filename, node.lineno, "LOCK001",
                f"{self.qualname}: unsynchronized cross-worker mutation "
                f"of peer state via '{path}' ({kind}) — wrap in the "
                f"cluster-plane lock (with <lock>: / plane_lock())"))


def _functions(tree: ast.Module) -> List[Tuple[str, ast.AST]]:
    """(qualname, node) for every function, with Class.method names."""
    out: List[Tuple[str, ast.AST]] = []

    def walk(node: ast.AST, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append((prefix + child.name, child))
                walk(child, prefix + child.name + ".")
            elif isinstance(child, ast.ClassDef):
                walk(child, prefix + child.name + ".")

    walk(tree, "")
    return out


def derive_sites(root: Path = REPO_ROOT
                 ) -> Tuple[List[dict], List[Finding]]:
    """All cross-worker mutation sites in the plane files, plus LOCK001
    findings for any not under a lock."""
    sites: List[dict] = []
    findings: List[Finding] = []
    for rel in PLANE_FILES:
        tree = ast.parse((root / rel).read_text(), filename=rel)
        for qualname, func in _functions(tree):
            sc = _SiteScanner(rel, qualname, func)
            sc.run()
            sites.extend(sc.sites)
            findings.extend(sc.findings)
    sites.sort(key=lambda s: (s["file"], s["func"], s["path"], s["kind"]))
    # the same dotted path may be touched on several lines of one function
    dedup = []
    for s in sites:
        if not dedup or dedup[-1] != s:
            dedup.append(s)
    return dedup, findings


# -- lock plumbing checks ---------------------------------------------------

def _method(cls: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _takes_self_lock(func: ast.FunctionDef, siblings: Sequence[str]) -> bool:
    """The method body enters ``with self.lock`` or delegates to another
    self-locked sibling."""
    for node in ast.walk(func):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Attribute) and ctx.attr == "lock" \
                        and isinstance(ctx.value, ast.Name) \
                        and ctx.value.id == "self":
                    return True
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self" \
                and node.func.attr in siblings:
            return True
    return False


def check_plumbing(root: Path = REPO_ROOT) -> List[Finding]:
    findings: List[Finding] = []
    classes: Dict[str, Tuple[str, ast.ClassDef]] = {}
    for rel in sorted(set(SHARED_CLASSES.values())):
        tree = ast.parse((root / rel).read_text(), filename=rel)
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = (rel, node)
    # 1. self-locking classes: lock in __init__, every mutator takes it
    for cname, methods in SELF_LOCKED.items():
        rel, cls = classes[cname]
        init = _method(cls, "__init__")
        has_lock = init is not None and any(
            _self_attr(t) == "lock"
            for n in ast.walk(init) if isinstance(n, ast.Assign)
            for t in n.targets)
        if not has_lock:
            findings.append(Finding(
                rel, cls.lineno, "LOCK003",
                f"{cname}.__init__ does not create self.lock — the class "
                f"is shared across workers and must be self-locking"))
        for mname in methods:
            m = _method(cls, mname)
            if m is None or not _takes_self_lock(m, methods):
                findings.append(Finding(
                    rel, (m or cls).lineno, "LOCK003",
                    f"{cname}.{mname} mutates shared state without "
                    f"taking self.lock"))
    # 2. LibraCluster.__init__ attaches the plane lock to alloc + registry
    rel = "src/repro/core/cluster.py"
    cls = classes.get("LibraCluster", (rel, None))[1]
    init = _method(cls, "__init__") if cls is not None else None
    attached: Set[str] = set()
    if init is not None:
        for node in ast.walk(init):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and t.attr == "lock" \
                            and isinstance(t.value, ast.Attribute):
                        attached.add(t.value.attr)
    for need in ("alloc", "registry"):
        if need not in attached:
            findings.append(Finding(
                rel, (init or cls).lineno if cls is not None else 0,
                "LOCK003",
                f"LibraCluster.__init__ does not attach the plane lock to "
                f"each worker's {need} (w.{need}.lock = self.lock) — "
                f"plane_lock() degrades to a no-op"))
    return findings


# -- manifest ---------------------------------------------------------------

def derive(root: Path = REPO_ROOT) -> Tuple[dict, List[Finding]]:
    """(shared-state manifest dict, LOCK001/LOCK003 findings)."""
    sites, findings = derive_sites(root)
    findings.extend(check_plumbing(root))
    manifest = {"version": 1,
                "classes": derive_shared_classes(root),
                "sites": sites}
    return manifest, findings


def write_manifest(root: Path = REPO_ROOT,
                   path: Path = MANIFEST_PATH) -> dict:
    manifest, _ = derive(root)
    path.write_text(json.dumps(manifest, indent=2, sort_keys=True) + "\n")
    return manifest


def compare_manifest(derived: dict,
                     committed: Optional[dict]) -> List[Finding]:
    loc = str(MANIFEST_PATH.relative_to(REPO_ROOT))
    if committed is None:
        return [Finding(loc, 0, "LOCK002",
                        "shared-state manifest missing — generate with "
                        "`python -m repro.analysis --write-manifest` and "
                        "commit it")]
    findings: List[Finding] = []
    for cname, attrs in derived["classes"].items():
        old = committed.get("classes", {}).get(cname)
        if old != attrs:
            extra = sorted(set(attrs) - set(old or []))
            gone = sorted(set(old or []) - set(attrs))
            findings.append(Finding(
                loc, 0, "LOCK002",
                f"shared-state drift in {cname}: new mutable attrs "
                f"{extra or '[]'}, removed {gone or '[]'} — review the "
                f"locking impact, then re-run --write-manifest"))
    key = lambda s: (s["file"], s["func"], s["path"], s["kind"])  # noqa: E731
    derived_sites = {key(s) for s in derived["sites"]}
    committed_sites = {key(s) for s in committed.get("sites", [])}
    for f, fn, p, k in sorted(derived_sites - committed_sites):
        findings.append(Finding(
            loc, 0, "LOCK002",
            f"new cross-worker mutation site {fn}: {p} ({k}) in {f} — "
            f"review its locking, then re-run --write-manifest"))
    for f, fn, p, k in sorted(committed_sites - derived_sites):
        findings.append(Finding(
            loc, 0, "LOCK002",
            f"manifest site {fn}: {p} ({k}) in {f} no longer exists — "
            f"re-run --write-manifest"))
    return findings


def run(root: Path = REPO_ROOT) -> Report:
    derived, findings = derive(root)
    committed = None
    if MANIFEST_PATH.exists():
        committed = json.loads(MANIFEST_PATH.read_text())
    findings.extend(compare_manifest(derived, committed))
    sources = {rel: (root / rel).read_text()
               for rel in list(PLANE_FILES)
               + sorted(set(SHARED_CLASSES.values()))}
    return build_report("lockset", findings, sources, rules=LOCKSET_RULES)


# -- test-time lockset instrumentation --------------------------------------

#: per-worker objects whose mutators the monitor wraps
MONITORED = {
    "alloc": ("alloc_page", "alloc_sequence", "alloc_batch",
              "free_pages_list", "free_batch", "retain", "defer_free",
              "expire_deferred", "export_grant", "release_export",
              "stage_transfer", "commit_transfer", "abort_transfer"),
    "registry": ("register", "import_grant", "release", "drop",
                 "begin_teardown", "expire_teardowns", "retain"),
}


class LocksetMonitor:
    """Records, per shared object, the set of worker contexts that mutate
    it, and emits a ``LOCK004`` finding for every cross-worker mutation
    executed without the cluster-plane lock held.

    Usage::

        with LocksetMonitor(cluster) as mon:
            ... drive the ClusterRuntime ...
        assert not mon.violations, mon.format()

    Attribution comes from ``cluster.current_worker`` (maintained by
    ``ClusterRuntime`` around each scheduling quantum); ``None`` is the
    control plane, which is single-threaded by construction and therefore
    never a violation. A mutation of worker ``j``'s allocator or registry
    from worker ``i != j``'s quantum must hold ``cluster.lock`` — that is
    the invariant a worker-per-thread executor needs."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.accessors: Dict[str, Set[Optional[int]]] = {}
        self.violations: List[Finding] = []
        self._seen: Set[Tuple[str, str, int]] = set()
        self._installed: List[Tuple[object, str]] = []

    # -- install / restore --------------------------------------------------
    def __enter__(self) -> "LocksetMonitor":
        for w in self.cluster.workers:
            for role, obj in (("alloc", w.alloc), ("registry", w.registry)):
                label = f"worker{w.worker_id}.{role}"
                for meth in MONITORED[role]:
                    self._wrap(obj, meth, label, w.worker_id)
        return self

    def __exit__(self, *exc) -> None:
        for obj, meth in self._installed:
            # the wrapper shadows the class method via an instance
            # attribute; deleting it restores normal lookup
            delattr(obj, meth)
        self._installed.clear()

    def _wrap(self, obj, meth: str, label: str, owner: int) -> None:
        orig = getattr(obj, meth)

        def wrapped(*args, **kw):
            self._record(label, meth, owner)
            return orig(*args, **kw)

        setattr(obj, meth, wrapped)
        self._installed.append((obj, meth))

    def _record(self, label: str, meth: str, owner: int) -> None:
        cur = self.cluster.current_worker
        self.accessors.setdefault(label, set()).add(cur)
        if cur is None or cur == owner:
            return
        if not self.cluster.lock.held:
            key = (label, meth, cur)
            if key not in self._seen:
                self._seen.add(key)
                self.violations.append(Finding(
                    f"<runtime:{label}>", 0, "LOCK004",
                    f"{label}.{meth}() mutated from worker {cur}'s "
                    f"context without the cluster-plane lock held"))

    # -- reporting -----------------------------------------------------------
    def shared_objects(self) -> Dict[str, Set[Optional[int]]]:
        """Objects actually touched from >= 2 distinct contexts."""
        return {k: v for k, v in self.accessors.items() if len(v) > 1}

    def format(self) -> str:
        return "\n".join(f.format() for f in self.violations)

    def report(self) -> Report:
        return Report(name="lockset-runtime", active=list(self.violations),
                      waived=[])
