"""Import-graph hygiene check (``IMPORT001``).

Builds the static import graph of the ``repro`` package plus the repo's
executable roots (``tests/``, ``scripts/``, ``examples/``,
``benchmarks/``) and flags any ``repro`` module that no root can reach.
Unreachable modules are dead weight: nothing tests them, nothing ships
them, and they silently rot. Intentional staging of future work is
legitimate — waive it in the module itself with the standard comment
(``libra: waive[IMPORT001] <reason>`` after a ``#``, anywhere in the
file; the finding anchors to the waiver line). A module
driven only through ``subprocess``/``importlib`` is invisible to the
static graph and needs the same waiver. The gate runs at zero unexplained
findings; a stale waiver on a module that became reachable is itself
flagged (``WAIVER002``).

Pure-AST: modules are never imported, so a module with a missing optional
dependency still participates in the graph.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set

from repro.analysis.common import Finding, Report, build_report

IMPORT_RULES = ("IMPORT001",)

REPO_ROOT = Path(__file__).resolve().parents[3]
PKG_ROOT = REPO_ROOT / "src" / "repro"
ENTRY_DIRS = ("tests", "scripts", "examples", "benchmarks")


def _module_name(py: Path) -> str:
    rel = py.relative_to(PKG_ROOT.parent).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports(py: Path, within: str) -> Set[str]:
    """repro.* modules imported by ``py``; ``within`` resolves relatives."""
    try:
        tree = ast.parse(py.read_text(), filename=str(py))
    except SyntaxError:
        return set()
    out: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            out.update(a.name for a in node.names
                       if a.name.split(".")[0] == "repro")
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = within.split(".")
                base = base[: len(base) - node.level + 1]
                mod = ".".join(base + ([node.module] if node.module else []))
            else:
                mod = node.module or ""
            if mod.split(".")[0] == "repro":
                out.add(mod)
                # `from repro.core import cluster` names a submodule, not
                # an attribute — add both candidates; unknowns are dropped
                # when edges are resolved against the real module set
                out.update(f"{mod}.{a.name}" for a in node.names)
    return out


def build_graph() -> Dict[str, Set[str]]:
    """module -> set of repro modules it imports (package-internal only)."""
    modules = {_module_name(py): py for py in PKG_ROOT.rglob("*.py")}
    graph: Dict[str, Set[str]] = {}
    for name, py in modules.items():
        deps = set()
        for imp in _imports(py, name):
            # resolve to the longest known prefix (repro.core.cluster.Foo
            # -> repro.core.cluster); importing a package pulls __init__
            parts = imp.split(".")
            while parts and ".".join(parts) not in modules:
                parts.pop()
            if parts:
                deps.add(".".join(parts))
        graph[name] = deps - {name}
    return graph


def entry_imports() -> Set[str]:
    """repro modules imported directly by any executable root."""
    out: Set[str] = set()
    for d in ENTRY_DIRS:
        root = REPO_ROOT / d
        if not root.is_dir():
            continue
        for py in root.rglob("*.py"):
            out |= _imports(py, "")
    return out


def unreachable() -> List[str]:
    """repro modules no executable root can reach, sorted."""
    graph = build_graph()
    # `python -m pkg` entry points are roots in their own right
    roots = {m for m in graph if m.rsplit(".", 1)[-1] == "__main__"}
    for imp in entry_imports():
        parts = imp.split(".")
        while parts and ".".join(parts) not in graph:
            parts.pop()
        if parts:
            roots.add(".".join(parts))
    seen: Set[str] = set()
    stack = list(roots)
    while stack:
        mod = stack.pop()
        if mod in seen:
            continue
        seen.add(mod)
        # importing repro.core.cluster executes repro/__init__ and
        # repro/core/__init__ too — packages on the dotted path count
        parts = mod.split(".")
        for i in range(1, len(parts)):
            pkg = ".".join(parts[:i])
            if pkg in graph and pkg not in seen:
                stack.append(pkg)
        stack.extend(graph.get(mod, ()))
    return sorted(m for m in graph if m not in seen)


def report_lines() -> List[str]:
    dead = unreachable()
    if not dead:
        return ["imports: all repro modules reachable from "
                f"{'/'.join(ENTRY_DIRS)}"]
    lines = [f"imports: {len(dead)} module(s) unreachable from any "
             f"executable root ({'/'.join(ENTRY_DIRS)}):"]
    lines += [f"  {m}" for m in dead]
    return lines


def run() -> Report:
    """Gated report: one IMPORT001 finding per unreachable module, waived
    by a standard waiver comment anywhere inside the module."""
    modules = {_module_name(py): py for py in PKG_ROOT.rglob("*.py")}
    findings: List[Finding] = []
    sources: Dict[str, str] = {}
    for mod in unreachable():
        py = modules[mod]
        rel = str(py.relative_to(REPO_ROOT))
        text = py.read_text()
        sources[rel] = text
        # anchor the finding to the module's waiver comment if it has one
        # (the waiver mechanism is line-based; "this whole module" is not)
        line = 1
        for i, t in enumerate(text.splitlines(), start=1):
            if "waive[IMPORT001]" in t:
                line = i
                break
        findings.append(Finding(
            rel, line, "IMPORT001",
            f"module {mod} is unreachable from any executable root "
            f"({'/'.join(ENTRY_DIRS)}) — wire it into a test or entry "
            f"point, or waive it with a staging reason"))
    # reachable modules still participate in the stale-waiver sweep
    for py in PKG_ROOT.rglob("*.py"):
        rel = str(py.relative_to(REPO_ROOT))
        sources.setdefault(rel, py.read_text())
    return build_report("imports", findings, sources, rules=IMPORT_RULES)
