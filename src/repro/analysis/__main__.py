"""CLI for the datapath verifier: ``python -m repro.analysis``.

Runs the analysis passes (ownership lint, jaxpr zero-copy audit,
cluster-plane lockset check, concurrency verifier, import-graph hygiene)
and exits non-zero on any unwaived finding. ``--write-manifest``
regenerates the committed shared-state and lock-hierarchy manifests
after a reviewed locking change.
"""
from __future__ import annotations

import argparse
import sys

PASSES = ("ownership", "jaxpr", "lockset", "concurrency", "imports")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Libra datapath verifier — static analysis passes")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASSES + ("all",), default=None,
                    help="pass to run (repeatable; default: all)")
    ap.add_argument("--write-manifest", action="store_true",
                    help="regenerate the shared-state manifest from the "
                         "current tree, then run the lockset pass")
    args = ap.parse_args(argv)

    selected = set(args.passes or ["all"])
    if "all" in selected:
        selected = set(PASSES)

    if args.write_manifest:
        from repro.analysis import concurrency, lockset
        m = lockset.write_manifest()
        print(f"wrote {lockset.MANIFEST_PATH} "
              f"({len(m['classes'])} classes, {len(m['sites'])} sites)")
        h = concurrency.write_hierarchy_manifest()
        print(f"wrote {concurrency.HIERARCHY_PATH} "
              f"({len(h['edges'])} lock-order edges)")
        selected |= {"lockset", "concurrency"}

    failed = False
    if "ownership" in selected:
        from repro.analysis import ownership
        rep = ownership.run()
        print("\n".join(rep.lines()))
        failed |= not rep.ok
    if "jaxpr" in selected:
        from repro.analysis import jaxpr_audit
        rep = jaxpr_audit.run()
        print("\n".join(rep.lines()))
        failed |= not rep.ok
    if "lockset" in selected:
        from repro.analysis import lockset
        rep = lockset.run()
        print("\n".join(rep.lines()))
        failed |= not rep.ok
    if "concurrency" in selected:
        from repro.analysis import concurrency
        rep = concurrency.run()
        print("\n".join(rep.lines()))
        failed |= not rep.ok
    if "imports" in selected:
        from repro.analysis import importgraph
        rep = importgraph.run()
        print(rep.summary())
        print("\n".join("  " + f.format() for f in rep.active))
        failed |= not rep.ok
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
