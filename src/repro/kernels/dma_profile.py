"""DMA-vs-compute profiler for the one-kernel scheduling round.

The fused round's ``n_buffers >= 2`` mode stages each [1, S] stream row
(and its RX keystream) into a VMEM ring by an async copy issued one row
ahead of compute — classic double/quad buffering. Whether that pipelining
*wins* depends on the DMA:compute ratio of the deployment shape: when the
per-row copy is slower than the per-row compute, deeper rings hide more
of it; when compute dominates, the staging is pure overhead and the
blocked layout (``n_buffers == 0``) is faster.

This module measures that trade-off empirically and picks the depth:

* :func:`profile_fused_depths` — wall-clock the fused round at each
  candidate depth on a representative operand bundle (the same
  ``testing.fused_round_case`` shapes the parity gate runs), warmup
  excluded so compile time never biases the pick.
* :func:`dma_compute_profile` — decompose one round into its *transfer*
  leg (host→device staging of the stream operands) and its *compute* leg
  (the round with operands already resident), and report the measured
  overlap ratio ``(transfer + compute - fused) / min(transfer, compute)``
  — 1.0 means the staged round fully hides the cheaper leg, 0 means the
  legs serialized.
* :func:`auto_buffer_depth` — the selection policy: fastest measured
  depth, with the ``LIBRA_FUSED_BUFFERS`` env var as an explicit
  override (set it to pin a depth, e.g. on a box where profiling at
  import time is unwanted).

On the host (interpret-mode) backend the async copies execute eagerly,
so staging usually loses and the profiler correctly selects depth 0 —
the point is that the *selection is measured, not assumed*, and the same
harness picks 2/4 on hardware where the DMA engines are real. Results
feed ``benchmarks/bench_dma_overlap.py`` (BENCH_dma_overlap.json rows).
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

#: candidate ring depths: blocked, double-, quad-buffered
DEFAULT_DEPTHS: Tuple[int, ...] = (0, 2, 4)


@dataclass
class DepthProfile:
    """Measured cost of one fused round at one staging depth."""
    depth: int
    round_s: float        # best-of-iters wall time per round
    rounds_per_s: float


def _case(b: int, page: int, pps: int, meta_max: int, seed: int):
    from repro.kernels.testing import fused_round_case
    rng = np.random.default_rng(seed)
    return fused_round_case(rng, b=b, page=page, pps=pps, meta_max=meta_max)


def _run(case: Dict, *, meta_max: int, n_buffers: int, interpret: bool):
    from repro.kernels.selective_copy import fused_round
    got = fused_round(
        case["stream"], case["meta_len"], case["total_len"], case["pool"],
        case["tables"], meta_max=meta_max, interpret=interpret,
        n_buffers=n_buffers, keystream=case["keystream"],
        tx_keystream=case["tx_keystream"], cond_off=case["cond_off"],
        cond_lo=case["cond_lo"], cond_hi=case["cond_hi"],
        live=case["live"], meta_ks=case["meta_ks"])
    for g in got:
        if g is not None:
            np.asarray(g)          # block until the round is done
    return got


def profile_fused_depths(*, b: int = 8, page: int = 16, pps: int = 4,
                         meta_max: int = 16,
                         depths: Sequence[int] = DEFAULT_DEPTHS,
                         iters: int = 5, warmup: int = 2,
                         interpret: bool = True,
                         seed: int = 0) -> Dict[int, DepthProfile]:
    """Wall-clock the full-operand fused round per candidate depth.

    Warmup rounds absorb tracing/compile; the reported figure is the
    best of ``iters`` timed rounds (min is the right statistic for a
    deterministic kernel under scheduler noise)."""
    case = _case(b, page, pps, meta_max, seed)
    out: Dict[int, DepthProfile] = {}
    for d in depths:
        for _ in range(warmup):
            _run(case, meta_max=meta_max, n_buffers=d, interpret=interpret)
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            _run(case, meta_max=meta_max, n_buffers=d, interpret=interpret)
            best = min(best, time.perf_counter() - t0)
        out[d] = DepthProfile(depth=d, round_s=best,
                              rounds_per_s=1.0 / max(best, 1e-12))
    return out


def dma_compute_profile(*, b: int = 8, page: int = 16, pps: int = 4,
                        meta_max: int = 16, iters: int = 5, warmup: int = 2,
                        n_buffers: int = 2, interpret: bool = True,
                        seed: int = 0) -> Dict[str, float]:
    """Decompose the staged round into transfer vs compute legs.

    * ``transfer_s`` — host→device placement of the stream + RX keystream
      operands (the bytes the DMA ring stages row-by-row inside the
      kernel), measured as a standalone device_put sweep.
    * ``compute_s`` — the blocked-layout round with every operand already
      device-resident: pure kernel work, no staging.
    * ``fused_s``   — the staged (``n_buffers``) round end to end.
    * ``overlap_ratio`` — ``(transfer_s + compute_s - fused_s) /
      min(transfer_s, compute_s)``, clamped to [0, 1]: the fraction of
      the cheaper leg the pipeline actually hid.
    """
    import jax

    case = _case(b, page, pps, meta_max, seed)

    def _transfer():
        ops = [jax.device_put(np.asarray(case["stream"])),
               jax.device_put(np.asarray(case["keystream"]))]
        for o in ops:
            o.block_until_ready()
        return ops

    def _best(fn) -> float:
        for _ in range(warmup):
            fn()
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    transfer_s = _best(_transfer)
    resident = dict(case)
    for k in ("stream", "keystream", "pool", "tx_keystream"):
        resident[k] = jax.device_put(np.asarray(case[k]))
    compute_s = _best(lambda: _run(resident, meta_max=meta_max, n_buffers=0,
                                   interpret=interpret))
    fused_s = _best(lambda: _run(case, meta_max=meta_max,
                                 n_buffers=n_buffers, interpret=interpret))
    hidden = transfer_s + compute_s - fused_s
    overlap = hidden / max(min(transfer_s, compute_s), 1e-12)
    return {"transfer_s": transfer_s, "compute_s": compute_s,
            "fused_s": fused_s,
            "overlap_ratio": float(np.clip(overlap, 0.0, 1.0))}


def auto_buffer_depth(*, b: int = 8, page: int = 16, pps: int = 4,
                      meta_max: int = 16,
                      depths: Sequence[int] = DEFAULT_DEPTHS,
                      iters: int = 3, warmup: int = 1,
                      interpret: bool = True, seed: int = 0,
                      profiles: Optional[Dict[int, DepthProfile]] = None,
                      ) -> int:
    """The staging depth the fused datapath should run with.

    ``LIBRA_FUSED_BUFFERS`` overrides (0 disables staging, >= 2 pins a
    ring depth); otherwise the fastest measured depth wins. Pass
    ``profiles`` to reuse an existing :func:`profile_fused_depths` sweep
    instead of re-measuring."""
    env = os.environ.get("LIBRA_FUSED_BUFFERS", "")
    if env:
        depth = int(env)
        assert depth == 0 or depth >= 2, f"LIBRA_FUSED_BUFFERS={depth}"
        return depth
    if profiles is None:
        profiles = profile_fused_depths(
            b=b, page=page, pps=pps, meta_max=meta_max, depths=depths,
            iters=iters, warmup=warmup, interpret=interpret, seed=seed)
    return min(profiles.values(), key=lambda p: p.round_s).depth
