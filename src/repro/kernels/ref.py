"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function defines the exact semantics its kernel must reproduce; tests
sweep shapes/dtypes and assert allclose between kernel (interpret=True on
CPU) and these references.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jax.Array,   # [B, Hq, Sq, hd]
    k: jax.Array,   # [B, Hkv, Skv, hd]
    v: jax.Array,   # [B, Hkv, Skv, hd]
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))
    iq = jnp.arange(sq)[:, None]
    ik = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok = ok & (iq >= ik)
    if window > 0:
        ok = ok & (iq - ik < window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, hd).astype(q.dtype)


def paged_attention_ref(
    q: jax.Array,        # [B, Hq, hd]
    pool: jax.Array,     # [P, page, 2, Hkv, hd]
    tables: jax.Array,   # [B, pps] local page ids, -1 invalid
    page_pos: jax.Array, # [B, pps] base position per page
    seq_lens: jax.Array, # [B] highest valid position (inclusive)
    *,
    window: int = 0,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partial-softmax attention over owned pages. Returns (acc [B,Hq,hd],
    m [B,Hq], l [B,Hq]) — partial stats, combinable across shards."""
    b, hq, hd = q.shape
    p_, page, _, hkv, _ = pool.shape
    pps = tables.shape[1]
    g = hq // hkv
    pages = pool[jnp.clip(tables, 0)]                    # [B, pps, page, 2, Hkv, hd]
    kk = pages[:, :, :, 0].reshape(b, pps * page, hkv, hd)
    vv = pages[:, :, :, 1].reshape(b, pps * page, hkv, hd)
    pos = page_pos[:, :, None] + jnp.arange(page)[None, None, :]
    valid = (tables[:, :, None] >= 0) & (pos <= seq_lens[:, None, None])
    if window > 0:
        valid = valid & (seq_lens[:, None, None] - pos < window)
    valid = valid.reshape(b, pps * page)
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, kk.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgt,bthd->bhgd", p, vv.astype(jnp.float32))
    return (acc.reshape(b, hq, hd), m.reshape(b, hq), l.reshape(b, hq))


def selective_copy_ref(
    stream: jax.Array,    # [B, S] int32 token stream
    meta_len: jax.Array,  # [B] metadata boundary from the parser policy
    total_len: jax.Array, # [B] message length in the stream
    pool: jax.Array,      # [P, page] anchored payload pages
    tables: jax.Array,    # [B, pps] destination page ids (-1 unused)
    *,
    meta_max: int,
) -> Tuple[jax.Array, jax.Array]:
    """RX-Prog data plane: compact metadata into [B, meta_max] (selective
    copy) and scatter the payload into anchored pages (single placement).
    Returns (meta_buf, new_pool)."""
    b, s = stream.shape
    p_, page = pool.shape
    pps = tables.shape[1]
    idx = jnp.arange(meta_max)
    meta_buf = jnp.where(idx[None, :] < meta_len[:, None],
                         jnp.take_along_axis(
                             stream, jnp.minimum(idx[None, :], s - 1), axis=1),
                         0)
    # payload token t (global stream position meta_len + t) -> page t//page
    t = jnp.arange(s)
    rel = t[None, :] - meta_len[:, None]                  # payload-relative pos
    valid = (rel >= 0) & (t[None, :] < total_len[:, None])
    pg = jnp.clip(rel // page, 0, pps - 1)
    dest_page = jnp.take_along_axis(tables, pg, axis=1)   # [B, S]
    dest_off = rel % page
    flat_dest = jnp.where(valid & (dest_page >= 0),
                          dest_page * page + dest_off, p_ * page)
    new_pool = pool.reshape(-1).at[flat_dest.reshape(-1)].set(
        stream.reshape(-1).astype(pool.dtype), mode="drop").reshape(p_, page)
    return meta_buf, new_pool


def selective_copy_crypto_ref(
    stream: jax.Array,    # [B, S] int32 ciphertext token stream
    meta_len: jax.Array,  # [B] metadata boundary from the parser policy
    total_len: jax.Array, # [B] message length in the stream
    pool: jax.Array,      # [P, page] anchored payload pages
    tables: jax.Array,    # [B, pps] destination page ids (-1 unused)
    keystream: jax.Array, # [B, S] per-token keystream (0 outside payload)
    *,
    meta_max: int,
) -> Tuple[jax.Array, jax.Array]:
    """hw-kTLS RX-Prog data plane: identical to :func:`selective_copy_ref`
    except payload tokens are XORed with ``keystream`` *inside* the
    anchoring scatter — the NIC-inline decrypt, fused into the single
    placement pass. The metadata compaction stays raw (record headers are
    plaintext; inner-metadata decryption happens host-side during the user
    copy, where the bytes are being touched anyway)."""
    b, s = stream.shape
    p_, page = pool.shape
    pps = tables.shape[1]
    idx = jnp.arange(meta_max)
    meta_buf = jnp.where(idx[None, :] < meta_len[:, None],
                         jnp.take_along_axis(
                             stream, jnp.minimum(idx[None, :], s - 1), axis=1),
                         0)
    plain = jnp.bitwise_xor(stream, keystream.astype(stream.dtype))
    t = jnp.arange(s)
    rel = t[None, :] - meta_len[:, None]
    valid = (rel >= 0) & (t[None, :] < total_len[:, None])
    pg = jnp.clip(rel // page, 0, pps - 1)
    dest_page = jnp.take_along_axis(tables, pg, axis=1)
    dest_off = rel % page
    flat_dest = jnp.where(valid & (dest_page >= 0),
                          dest_page * page + dest_off, p_ * page)
    new_pool = pool.reshape(-1).at[flat_dest.reshape(-1)].set(
        plain.reshape(-1).astype(pool.dtype), mode="drop").reshape(p_, page)
    return meta_buf, new_pool


def selective_gather_ref(
    pool: jax.Array,      # [P+1, page] anchored payload pages (+ scratch row)
    tables: jax.Array,    # [B, pps] source page ids (-1 unused)
    lengths: jax.Array,   # [B] payload lengths
    keystream: Optional[jax.Array] = None,  # [B, pps*page] or None
) -> jax.Array:
    """TX-Prog data plane: gather each message's anchored payload out of
    the pool in one pass. ``out[i, :lengths[i]]`` holds the payload (page
    ``tables[i, j]`` supplies positions ``[j*page, (j+1)*page)``); lanes
    past the length — and lanes of invalid (-1) table slots — are zero.
    ``keystream`` (payload-relative) is XORed into the gathered tokens
    inside the same pass (hw-kTLS NIC-inline TX encrypt)."""
    p_, page = pool.shape
    b, pps = tables.shape
    out = pool[jnp.clip(tables, 0)].reshape(b, pps * page)
    pos = jnp.arange(pps * page)
    valid = (jnp.repeat(tables >= 0, page, axis=1)
             & (pos[None, :] < lengths[:, None]))
    if keystream is not None:
        out = jnp.bitwise_xor(out, keystream.astype(out.dtype))
    return jnp.where(valid, out, 0)


def policy_match_ref(
    meta: jax.Array,       # [B, M] int32 metadata tokens (round-padded)
    meta_len: jax.Array,   # [B] int32 valid metadata lengths
    cond_off: jax.Array,   # [R, K] int32 offsets (-1 pad; <= -2 payload)
    cond_lo: jax.Array,    # [R, K] int32 inclusive lower bounds
    cond_hi: jax.Array,    # [R, K] int32 inclusive upper bounds
    keystream: Optional[jax.Array] = None,   # [B, M] int32 or None
    live: Optional[jax.Array] = None,        # [R] int32 health mask or None
    payload: Optional[jax.Array] = None,     # [B, W] first-page window
    payload_len: Optional[jax.Array] = None, # [B] payload lengths
) -> jax.Array:
    """L7 policy table first-match pass (the in-data-plane routing
    decision). A condition holds iff its offset is the padding slot
    (``-1``), or ``0 <= offset < meta_len`` and ``lo <= meta[offset] <=
    hi``, or — *payload-prefix* conditions, ``offset <= -2`` encoding
    first-anchored-page position ``-offset - 2`` — the position is inside
    both the window and the payload and the window token is in bounds.
    A rule matches iff all K conditions hold; the result is the FIRST
    matching rule per message (rule order is priority), ``R`` when none
    match. ``keystream`` (0 on plaintext lanes) is XORed in before
    matching — the hw-kTLS analogue matches against *decrypted* metadata
    without a separate decrypt pass. ``live`` (the backend-health rule
    mask; 0 = every backend of the rule is down) excludes dead rules from
    the first-match scan so priority falls through in-plane. ``payload``
    is the [B, W] *plaintext* window of each message's first anchored
    page; when omitted, payload-prefix conditions never hold. Returns [B]
    int32 rule indices."""
    b, mm = meta.shape
    r, k = cond_off.shape
    m = meta if keystream is None else jnp.bitwise_xor(
        meta, keystream.astype(meta.dtype))
    vals = m[:, jnp.clip(cond_off, 0, mm - 1)]               # [B, R, K]
    pad = cond_off == -1                                      # [R, K]
    present = (cond_off >= 0)[None] \
        & (cond_off[None] < meta_len[:, None, None]) \
        & (cond_off[None] < mm)
    ok = pad[None] | (present & (vals >= cond_lo[None])
                      & (vals <= cond_hi[None]))
    if payload is not None:
        w = payload.shape[1]
        ppos = -cond_off - 2                                  # [R, K]
        pvals = payload[:, jnp.clip(ppos, 0, w - 1)]          # [B, R, K]
        pay_ok = (cond_off <= -2)[None] \
            & (ppos[None] < payload_len[:, None, None]) & (ppos < w)[None] \
            & (pvals >= cond_lo[None]) & (pvals <= cond_hi[None])
        ok = ok | pay_ok
    rule_ok = ok.all(axis=2)                                  # [B, R]
    if live is not None:
        rule_ok &= live.reshape(1, r) > 0
    ridx = jnp.arange(r, dtype=jnp.int32)
    return jnp.min(jnp.where(rule_ok, ridx[None, :], r),
                   axis=1).astype(jnp.int32)


def fused_round_ref(
    stream: jax.Array,     # [B, S] int32 token stream
    meta_len: jax.Array,   # [B] int32
    total_len: jax.Array,  # [B] int32
    pool: jax.Array,       # [P+1, page] int32 (+ reserved scratch row)
    tables: jax.Array,     # [B, pps] int32 page ids (-1 unused)
    *,
    meta_max: int,
    keystream: Optional[jax.Array] = None,      # [B, S] hw-kTLS RX
    tx_keystream: Optional[jax.Array] = None,   # [B, pps*page] hw-kTLS TX
    cond_off: Optional[jax.Array] = None,       # [R, K] policy table
    cond_lo: Optional[jax.Array] = None,
    cond_hi: Optional[jax.Array] = None,
    live: Optional[jax.Array] = None,           # [R] health column
    meta_ks: Optional[jax.Array] = None,        # [B, meta_max] meta keystream
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], jax.Array]:
    """One-kernel scheduling round oracle: selective copy + hw-kTLS RX
    decrypt + policy first-match (with payload-prefix conditions peeking
    the first anchored page) + egress gather, composed from the per-pass
    references. Returns ``(meta [B, meta_max], new_pool, verdict [B] |
    None, out [B, pps*page])`` — the exact semantics
    ``selective_copy.fused_round`` must reproduce."""
    if keystream is None:
        meta, new_pool = selective_copy_ref(
            stream, meta_len, total_len, pool, tables, meta_max=meta_max)
        plain = stream
    else:
        meta, new_pool = selective_copy_crypto_ref(
            stream, meta_len, total_len, pool, tables, keystream,
            meta_max=meta_max)
        plain = jnp.bitwise_xor(stream, keystream.astype(stream.dtype))
    plen = total_len - meta_len
    verdict = None
    if cond_off is not None:
        b, s = stream.shape
        page = pool.shape[1]
        # first-anchored-page window: payload-relative positions [0, page)
        # (clamped in-stream; lanes past the payload are gated off by the
        # ppos < payload_len check inside the match)
        idx = jnp.minimum(meta_len[:, None] + jnp.arange(page)[None, :], s - 1)
        window = jnp.take_along_axis(plain, idx, axis=1)
        mrow = meta if meta_ks is None else jnp.bitwise_xor(
            meta, meta_ks.astype(meta.dtype))
        verdict = policy_match_ref(mrow, meta_len, cond_off, cond_lo, cond_hi,
                                   None, live, payload=window,
                                   payload_len=plen)
    out = selective_gather_ref(new_pool, tables, plen, tx_keystream)
    return meta, new_pool, verdict, out


def mlstm_scan_ref(q, k, v, log_i, log_f):
    """Sequential mLSTM oracle. q/k/v [B, H, S, dh]; gates [B, H, S].
    Returns h [B, H, S, dh]."""
    from repro.models.ssm import mlstm_cell_sequential

    h, _ = mlstm_cell_sequential(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), log_i.transpose(0, 2, 1),
        log_f.transpose(0, 2, 1))
    return h.transpose(0, 2, 1, 3)
