"""Shared helpers for the selective-copy kernel gates.

Used by both tests/test_kernels.py and scripts/check_kernel_parity.py so
the regression test and the CI gate assert the SAME property with the same
machinery (case shapes, and the jaxpr walk that proves the reserved-scratch
hot path performs no pool-sized copy).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

# single source of truth for the zero-copy trace invariants lives in the
# analysis suite; re-exported here for the existing test/gate imports
from repro.analysis.jaxpr_audit import (  # noqa: F401
    POOL_COPY_PRIMS,
    jaxpr_primitives,
)


def selcopy_case(rng: np.random.Generator, b: int = 2, page: int = 8,
                 pps: int = 4, meta_max: int = 16) -> Tuple:
    """(stream, meta_len, total_len, pool_with_scratch, tables) with random
    parse boundaries; the pool's LAST row is the reserved scratch page
    (slice it off for legacy-mode calls)."""
    s = meta_max + pps * page
    p_total = b * pps + 2
    stream = jnp.array(rng.integers(1, 1000, (b, s)), jnp.int32)
    meta_len, total_len = [], []
    tables = np.full((b, pps), -1, np.int32)
    ctr = 0
    for i in range(b):
        ml = int(rng.integers(0, meta_max + 1))
        pl = int(rng.integers(0, pps * page + 1))
        meta_len.append(ml)
        total_len.append(ml + pl)
        for j in range(-(-pl // page)):
            tables[i, j] = ctr
            ctr += 1
    pool = jnp.array(rng.integers(0, 5, (p_total + 1, page)), jnp.int32)
    return (stream, jnp.array(meta_len, jnp.int32),
            jnp.array(total_len, jnp.int32), pool, jnp.array(tables))


def selcopy_crypto_case(rng: np.random.Generator, b: int = 2, page: int = 8,
                        pps: int = 4, meta_max: int = 16) -> Tuple:
    """A :func:`selcopy_case` plus a [B, S] int32 keystream operand — 31-bit
    values on the payload lanes (the kTLS-analogue hw mode), zero elsewhere,
    exactly as the batched datapath builds it."""
    stream, ml, tl, pool, tables = selcopy_case(rng, b=b, page=page, pps=pps,
                                                meta_max=meta_max)
    s = stream.shape[1]
    ks = rng.integers(0, 1 << 31, (b, s)).astype(np.int32)
    pos = np.arange(s)[None, :]
    payload_lane = (pos >= np.array(ml)[:, None]) & (pos < np.array(tl)[:, None])
    ks = np.where(payload_lane, ks, 0).astype(np.int32)
    return stream, ml, tl, pool, tables, jnp.array(ks)


def selgather_case(rng: np.random.Generator, b: int = 2, page: int = 8,
                   pps: int = 4, p_total: int = 0) -> Tuple:
    """(pool_with_scratch, tables, lengths, keystream) for the egress
    gather kernel: random page contents, random per-row lengths in
    [0, pps*page], valid table prefixes, a payload-relative 31-bit
    keystream zeroed past each length (exactly as forward_batch builds
    it)."""
    p_total = p_total or b * pps + 2
    pool = jnp.array(rng.integers(1, 1000, (p_total + 1, page)), jnp.int32)
    tables = np.full((b, pps), -1, np.int32)
    lengths = []
    ctr = 0
    for i in range(b):
        ln = int(rng.integers(0, pps * page + 1))
        lengths.append(ln)
        for j in range(-(-ln // page)):
            tables[i, j] = ctr % p_total
            ctr += 1
    ks = rng.integers(0, 1 << 31, (b, pps * page)).astype(np.int32)
    pos = np.arange(pps * page)[None, :]
    ks = np.where(pos < np.array(lengths)[:, None], ks, 0).astype(np.int32)
    return (pool, jnp.array(tables), jnp.array(lengths, jnp.int32),
            jnp.array(ks))


def policy_case(rng: np.random.Generator, b: int = 4, meta_max: int = 16,
                r: int = 6, k: int = 3) -> Tuple:
    """(meta, meta_len, cond_off, cond_lo, cond_hi, keystream) for the
    policy-match kernel: random metadata rows with random valid lengths, a
    dense [R, K] condition table mixing padding slots (-1), in-range and
    out-of-range offsets, and narrow/wide value bands (so matches, misses
    and no-match sentinels all occur), plus a [B, M] 31-bit keystream
    zeroed past each row's metadata (the hw-kTLS operand: the kernel
    matches meta XOR keystream)."""
    meta = rng.integers(0, 200, (b, meta_max)).astype(np.int32)
    meta_len = rng.integers(1, meta_max + 1, b).astype(np.int32)
    cond_off = rng.integers(-1, meta_max + 3, (r, k)).astype(np.int32)
    lo = rng.integers(0, 200, (r, k)).astype(np.int32)
    width = rng.integers(0, 120, (r, k)).astype(np.int32)
    ks = rng.integers(0, 1 << 31, (b, meta_max)).astype(np.int32)
    pos = np.arange(meta_max)[None, :]
    ks = np.where(pos < meta_len[:, None], ks, 0).astype(np.int32)
    return (jnp.array(meta), jnp.array(meta_len), jnp.array(cond_off),
            jnp.array(lo), jnp.array((lo + width).astype(np.int32)),
            jnp.array(ks))


def policy_live_column(rng: np.random.Generator, r: int) -> jnp.ndarray:
    """A random [R] int32 backend-health rule mask for the policy-match
    kernel's ``live`` operand: mostly-live rows with a sprinkling of dead
    ones (the HealthTable shape under partial backend failure), never
    all-dead so first-match and no-match sentinels both still occur."""
    live = (rng.random(r) < 0.7).astype(np.int32)
    if not live.any():
        live[int(rng.integers(0, r))] = 1
    return jnp.array(live)


def policy_payload_case(rng: np.random.Generator, b: int = 4,
                        meta_max: int = 16, r: int = 6, k: int = 3,
                        w: int = 8) -> Tuple:
    """A :func:`policy_case` where ~a third of the conditions are remapped
    to *payload-prefix* slots (``offset <= -2`` encodes first-anchored-page
    position ``-offset - 2``, in-window and past-window positions both
    drawn), plus the [B, W] plaintext first-page window and the [B]
    payload lengths the match gates on. Returns (meta, meta_len, cond_off,
    cond_lo, cond_hi, keystream, payload, payload_len)."""
    meta, ml, off, lo, hi, ks = policy_case(rng, b=b, meta_max=meta_max,
                                            r=r, k=k)
    off = np.array(off)
    sel = rng.random((r, k)) < 0.35
    ppos = rng.integers(0, w + 3, (r, k))
    off = np.where(sel, -2 - ppos, off).astype(np.int32)
    payload = rng.integers(0, 200, (b, w)).astype(np.int32)
    payload_len = rng.integers(0, w + 1, b).astype(np.int32)
    return (meta, ml, jnp.array(off), lo, hi, ks,
            jnp.array(payload), jnp.array(payload_len))


def fused_round_case(rng: np.random.Generator, b: int = 2, page: int = 8,
                     pps: int = 4, meta_max: int = 16, r: int = 6,
                     k: int = 3) -> dict:
    """Full operand bundle for the one-kernel fused round: a crypto
    selective-copy case plus a payload-relative TX keystream (zeroed past
    each payload length), a policy table mixing metadata / padding /
    payload-prefix conditions, a live health column, and a
    standalone-contract metadata keystream. Preserves the fused-round
    caller invariant ``S = meta_max + pps*page >= meta_len + pps*page``.
    Returned as a dict keyed by :func:`repro.kernels.ops.fused_round`
    argument names (drop keys to exercise the optional-operand matrix)."""
    stream, ml, tl, pool, tables, ks = selcopy_crypto_case(
        rng, b=b, page=page, pps=pps, meta_max=meta_max)
    mlv, tlv = np.array(ml), np.array(tl)
    plen = tlv - mlv
    tx = rng.integers(0, 1 << 31, (b, pps * page)).astype(np.int32)
    pos = np.arange(pps * page)[None, :]
    tx = np.where(pos < plen[:, None], tx, 0).astype(np.int32)
    cond_off = rng.integers(-1, meta_max + 3, (r, k)).astype(np.int32)
    pay = rng.random((r, k)) < 0.3
    ppos = rng.integers(0, page + 3, (r, k))
    cond_off = np.where(pay, -2 - ppos, cond_off).astype(np.int32)
    lo = rng.integers(0, 1200, (r, k)).astype(np.int32)
    width = rng.integers(0, 800, (r, k)).astype(np.int32)
    mks = rng.integers(0, 1 << 31, (b, meta_max)).astype(np.int32)
    mks = np.where(np.arange(meta_max)[None, :] < mlv[:, None], mks, 0)
    return dict(stream=stream, meta_len=ml, total_len=tl, pool=pool,
                tables=tables, keystream=ks, tx_keystream=jnp.array(tx),
                cond_off=jnp.array(cond_off), cond_lo=jnp.array(lo),
                cond_hi=jnp.array((lo + width).astype(np.int32)),
                live=policy_live_column(rng, r),
                meta_ks=jnp.array(mks.astype(np.int32)))


