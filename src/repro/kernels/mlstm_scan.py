"""Chunkwise-parallel mLSTM Pallas TPU kernel (xlstm / long-context decode).

TPU adaptation of the fused recurrent GPU kernels in the xLSTM paper: the
matrix memory C [dh, dh] lives in VMEM scratch and is carried across the
sequential chunk dimension; within a chunk the recurrence is evaluated in
its stabilised chunkwise-parallel form (intra-chunk [c, c] gate matrix +
inter-chunk state application) so the MXU does all the work. Matches
kernels.ref.mlstm_scan_ref (sequential oracle) to fp32 tolerance.

Layout: q/k/v [B, H, S, dh]; gates log_i/log_f [B, H, S] (log_f already
log-sigmoided). Output h [B, H, S, dh].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
                  C_s, n_s, m_s, *, chunk: int, nchunks: int, dh: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        C_s[...] = jnp.zeros_like(C_s)
        n_s[...] = jnp.zeros_like(n_s)
        m_s[...] = jnp.full_like(m_s, NEG_INF)

    scale = 1.0 / math.sqrt(dh)
    q = q_ref[0, 0].astype(jnp.float32)            # [c, dh]
    k = k_ref[0, 0].astype(jnp.float32) * scale
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32)          # [c]
    lf = lf_ref[0, 0].astype(jnp.float32)

    A = jnp.cumsum(lf)                             # [c] inclusive
    m_prev = m_s[0, 0]
    # intra-chunk log weights W[t, s] = A_t - A_s + li_s for s <= t
    W = A[:, None] - A[None, :] + li[None, :]
    tmask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    W = jnp.where(tmask, W, NEG_INF)
    binter = A + m_prev                            # [c]
    m_loc = jnp.maximum(jnp.max(W, axis=1), binter)
    S_intra = jnp.exp(W - m_loc[:, None])
    qk = (q @ k.T)                                 # [c, c]
    num = (S_intra * qk) @ v                       # [c, dh]
    num = num + jnp.exp(binter - m_loc)[:, None] * (q @ C_s[...])
    den = jnp.sum(S_intra * qk, axis=1)
    den = den + jnp.exp(binter - m_loc) * (q @ n_s[...][:, 0])
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))[:, None]
    o_ref[0, 0] = h.astype(o_ref.dtype)

    # ---- carry state to the end of the chunk ----
    A_T = A[chunk - 1]
    w_end = A_T - A + li                           # [c]
    m_new = jnp.maximum(A_T + m_prev, jnp.max(w_end))
    decay = jnp.exp(A_T + m_prev - m_new)
    kw = k * jnp.exp(w_end - m_new)[:, None]       # [c, dh]
    C_s[...] = decay * C_s[...] + kw.T @ v
    n_s[...] = decay * n_s[...] + jnp.sum(kw, axis=0)[:, None]
    m_s[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_scan(
    q: jax.Array,      # [B, H, S, dh]
    k: jax.Array,
    v: jax.Array,
    log_i: jax.Array,  # [B, H, S]
    log_f: jax.Array,  # [B, H, S] (log-sigmoid applied)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, dh = q.shape
    assert s % chunk == 0, (s, chunk)
    nchunks = s // chunk

    kernel = functools.partial(_mlstm_kernel, chunk=chunk, nchunks=nchunks,
                               dh=dh)
    qspec = pl.BlockSpec((1, 1, chunk, dh), lambda bh, j: (bh // h, bh % h, j, 0))
    gspec = pl.BlockSpec((1, 1, chunk), lambda bh, j: (bh // h, bh % h, j))
    return pl.pallas_call(
        kernel,
        grid=(b * h, nchunks),
        in_specs=[qspec, qspec, qspec, gspec, gspec],
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
        if not interpret else None,
    )(q, k, v, log_i, log_f)
