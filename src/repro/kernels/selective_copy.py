"""Selective-copy ingress Pallas TPU kernel (RX-Prog data plane).

One kernel performs both halves of the paper's ingress action:
  * **selective copy** — the metadata prefix (boundary supplied by the
    parser policy, scalar-prefetched) is compacted into a small [B, M]
    buffer (the only bytes that cross to the control plane);
  * **payload anchoring** — payload tokens are placed page-by-page into the
    anchored pool, addressed through the block table. The destination page
    index is known before the DMA issues (SMEM metadata), so the payload is
    written exactly once and never touched again.

Pool updates are in-place via input_output_aliasing (the anchored payload
is donated, like the kernel socket buffer it models).

Layout: stream [B, S] int32; pool [P, page] int32; tables [B, pps].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _meta_kernel(mlen_ref, tlen_ref, stream_ref, meta_ref, *, meta_max: int):
    b = pl.program_id(0)
    mlen = mlen_ref[b]
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, meta_max), 1)
    window = stream_ref[0, :meta_max]
    meta_ref[0, :] = jnp.where(idx[0] < mlen, window, 0)


def _payload_kernel(mlen_ref, tlen_ref, tables_ref, stream_ref, pool_in_ref,
                    pool_ref, *, page: int, s: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    mlen = mlen_ref[b]
    tlen = tlen_ref[b]
    pid = tables_ref[b, j]
    start = jnp.minimum(mlen + j * page, s - page)  # in-bounds (caller pads S)
    # row index as a size-1 dslice: older pallas interpret-mode discharge
    # rules reject plain-int indices mixed with dynamic slices
    toks = pl.load(stream_ref, (pl.dslice(0, 1), pl.dslice(start, page)))[0]
    rel = j * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = (pid >= 0) & (rel + mlen < tlen)
    # always write the block: invalid lanes / skipped pages pass the original
    # page content through (the out block is revisited via the clamped index)
    cur = pool_in_ref[0, :]
    pool_ref[0, :] = jnp.where(valid, toks, cur)


@functools.partial(jax.jit, static_argnames=("meta_max", "interpret"))
def selective_copy(
    stream: jax.Array,    # [B, S] int32
    meta_len: jax.Array,  # [B] int32
    total_len: jax.Array, # [B] int32
    pool: jax.Array,      # [P, page] int32 (donated)
    tables: jax.Array,    # [B, pps] int32
    *,
    meta_max: int,
    interpret: bool = False,
):
    """Returns (meta_buf [B, meta_max], new_pool). Matches
    kernels.ref.selective_copy_ref."""
    b, s = stream.shape
    p_, page = pool.shape
    pps = tables.shape[1]
    assert s % page == 0, (s, page)

    meta = pl.pallas_call(
        functools.partial(_meta_kernel, meta_max=meta_max),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b,),
            in_specs=[pl.BlockSpec((1, s), lambda b_, ml, tl: (b_, 0))],
            out_specs=pl.BlockSpec((1, meta_max), lambda b_, ml, tl: (b_, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, meta_max), stream.dtype),
        interpret=interpret,
    )(meta_len, total_len, stream)

    # invalid table entries (-1) are routed to a dummy page row so no real
    # page is ever revisited by a non-owner grid step
    pool_ext = jnp.concatenate(
        [pool, jnp.zeros((1, page), pool.dtype)], axis=0)
    new_pool = pl.pallas_call(
        functools.partial(_payload_kernel, page=page, s=s),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, pps),
            in_specs=[
                pl.BlockSpec((1, s), lambda b_, j, ml, tl, tbl: (b_, 0)),
                pl.BlockSpec((1, page),
                             lambda b_, j, ml, tl, tbl: (
                                 jnp.where(tbl[b_, j] < 0, p_, tbl[b_, j]), 0)),
            ],
            out_specs=pl.BlockSpec((1, page),
                                   lambda b_, j, ml, tl, tbl: (
                                       jnp.where(tbl[b_, j] < 0, p_,
                                                 tbl[b_, j]), 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((p_ + 1, page), pool.dtype),
        input_output_aliases={4: 0},  # pool donated -> in-place anchoring
        interpret=interpret,
    )(meta_len, total_len, tables, stream, pool_ext)
    return meta, new_pool[:p_]
