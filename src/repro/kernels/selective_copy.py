"""Selective-copy ingress + gather egress Pallas TPU kernels (the RX-Prog
and TX-Prog data planes).

One **fused** kernel performs both halves of the paper's ingress action in a
single pass over the stream:

  * **selective copy** — the metadata prefix (boundary supplied by the
    parser policy, scalar-prefetched) is compacted into a small [B, M]
    buffer (the only bytes that cross to the control plane);
  * **payload anchoring** — payload tokens are placed page-by-page into the
    anchored pool, addressed through the block table. The destination page
    index is known before the DMA issues (SMEM metadata), so the payload is
    written exactly once and never touched again.

The grid is flattened to ``(B, 1 + pps)``: step ``j == 0`` of each row
writes the metadata block, steps ``j >= 1`` anchor payload page ``j - 1``.
The stream block index depends only on ``b``, so each row is fetched into
VMEM once and shared by its metadata and payload steps.

Pool updates are in-place via input_output_aliasing (the anchored payload
is donated, like the kernel socket buffer it models). Invalid table
entries (-1) and the metadata step are routed to a *scratch page row*;
with ``reserved_scratch=True`` that row is the one :class:`AnchorPool`
reserves inside the pool at allocation time, so the hot path performs **no
pool-sized copy at all** (no ``concatenate``; the donation stays a true
in-place update). The legacy mode (``reserved_scratch=False``) appends a
dummy row per call for callers that still hold a scratch-less pool.

**kTLS-analogue hw mode**: an optional ``keystream`` operand (same [B, S]
layout as the stream) is XORed into the payload tokens *inside* the
anchoring step — the NIC-inline decrypt, fused into the same single pass
(paper §B.1: hardware kTLS adds zero extra passes). The metadata step
stays raw: record headers are plaintext and inner-metadata decryption
happens host-side during the user copy. Plaintext calls (``keystream
None``) compile exactly the pre-crypto kernel — no extra operand, no
extra VMEM traffic. Matches ``kernels.ref.selective_copy_crypto_ref``.

:func:`selective_gather` is the egress mirror: one fused pass reads each
message's anchored pages back out of the **resident** pool (read-only, no
donation, no pool-sized copy) into a dense [B, pps*page] payload block,
with the same optional ``keystream`` operand fusing the hw-kTLS TX encrypt
into the gather — together the two kernels close the batched datapath loop
entirely on-device.

Layout: stream [B, S] int32; pool [P(+1), page] int32; tables [B, pps].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(mlen_ref, tlen_ref, tables_ref, stream_ref, *rest,
                  page: int, s: int, meta_max: int, has_ks: bool):
    if has_ks:
        ks_ref, pool_in_ref, meta_ref, pool_ref = rest
    else:
        pool_in_ref, meta_ref, pool_ref = rest
    b = pl.program_id(0)
    j = pl.program_id(1)   # 0 = metadata step; j >= 1 anchors payload page j-1
    mlen = mlen_ref[b]
    tlen = tlen_ref[b]

    @pl.when(j == 0)
    def _meta():
        idx = jax.lax.broadcasted_iota(jnp.int32, (1, meta_max), 1)
        window = stream_ref[0, :meta_max]
        meta_ref[0, :] = jnp.where(idx[0] < mlen, window, 0)

    # payload step: j == 0 is aimed at the scratch row by the index map and
    # must pass the block through untouched (valid is forced False below)
    jj = jnp.maximum(j - 1, 0)
    pid = tables_ref[b, jj]
    start = jnp.minimum(mlen + jj * page, s - page)  # in-bounds (caller pads S)
    # row index as a size-1 dslice: older pallas interpret-mode discharge
    # rules reject plain-int indices mixed with dynamic slices
    toks = pl.load(stream_ref, (pl.dslice(0, 1), pl.dslice(start, page)))[0]
    if has_ks:
        # hw-kTLS: decrypt on the fly, inside the one placement pass
        kst = pl.load(ks_ref, (pl.dslice(0, 1), pl.dslice(start, page)))[0]
        toks = jnp.bitwise_xor(toks, kst)
    rel = jj * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = (j > 0) & (pid >= 0) & (rel + mlen < tlen)
    # always write the block: invalid lanes / skipped pages pass the original
    # page content through (the scratch block is revisited via the routed index)
    cur = pool_in_ref[0, :]
    pool_ref[0, :] = jnp.where(valid, toks, cur)


def _selective_copy_impl(
    stream: jax.Array,    # [B, S] int32
    meta_len: jax.Array,  # [B] int32
    total_len: jax.Array, # [B] int32
    pool: jax.Array,      # [P, page] int32 (donated); [P+1, page] w/ scratch
    tables: jax.Array,    # [B, pps] int32
    *,
    meta_max: int,
    interpret: bool = False,
    reserved_scratch: bool = False,
    keystream: jax.Array = None,   # [B, S] int32 (hw-kTLS) or None
):
    """Returns (meta_buf [B, meta_max], new_pool). Matches
    kernels.ref.selective_copy_ref (selective_copy_crypto_ref when a
    ``keystream`` is supplied).

    With ``reserved_scratch=True`` the pool's LAST row is the scratch page
    reserved by :attr:`AnchorPool.scratch_page` at allocation time: nothing
    is concatenated, the donation is honoured in place, and ``new_pool``
    keeps the full (scratch-inclusive) shape. Table entries must never
    reference the scratch row (the allocator never hands it out).

    Two jitted entry points share this body: :func:`selective_copy` (the
    default; the caller keeps its pool buffer) and
    :func:`selective_copy_donated`, whose outer jit **donates the pool
    argument** — the resident :class:`~repro.core.device_pool.DevicePool`
    uses it so the in-place aliasing inside the ``pallas_call`` composes
    with outer-level donation and device rounds keep ONE pool buffer
    instead of an input + an output copy. Callers of the donated entry
    must not touch their pool array afterwards (XLA deletes it)."""
    b, s = stream.shape
    page = pool.shape[1]
    pps = tables.shape[1]
    assert s % page == 0, (s, page)
    has_ks = keystream is not None
    if has_ks:
        assert keystream.shape == stream.shape, (keystream.shape, stream.shape)

    if reserved_scratch:
        pool_ext = pool                     # last row IS the reserved scratch
    else:
        # legacy callers hold a scratch-less pool: append a dummy row (one
        # pool-sized copy — the batched datapath never takes this branch)
        pool_ext = jnp.concatenate(
            [pool, jnp.zeros((1, page), pool.dtype)], axis=0)
    p_ext = pool_ext.shape[0]
    scratch = p_ext - 1

    def _pool_index(b_, j, ml, tl, tbl):
        # invalid table entries (-1) and the metadata step are routed to the
        # scratch row so no real page is ever revisited by a non-owner step
        pid = tbl[b_, jnp.maximum(j - 1, 0)]
        return (jnp.where((j == 0) | (pid < 0), scratch, pid), 0)

    stream_spec = pl.BlockSpec((1, s), lambda b_, j, ml, tl, tbl: (b_, 0))
    in_specs = [stream_spec]
    operands = [stream]
    if has_ks:
        in_specs.append(stream_spec)        # keystream rides the stream layout
        operands.append(keystream)
    in_specs.append(pl.BlockSpec((1, page), _pool_index))
    operands.append(pool_ext)

    meta, new_pool = pl.pallas_call(
        functools.partial(_fused_kernel, page=page, s=s, meta_max=meta_max,
                          has_ks=has_ks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, 1 + pps),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, meta_max), lambda b_, j, ml, tl, tbl: (b_, 0)),
                pl.BlockSpec((1, page), _pool_index),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, meta_max), stream.dtype),
            jax.ShapeDtypeStruct((p_ext, page), pool.dtype),
        ],
        # pool donated -> in-place anchoring (operand index counts the 3
        # scalar-prefetch args, the stream, and the optional keystream)
        input_output_aliases={(5 if has_ks else 4): 1},
        interpret=interpret,
    )(meta_len, total_len, tables, *operands)
    if reserved_scratch:
        return meta, new_pool
    return meta, new_pool[: p_ext - 1]


_JIT_STATICS = ("meta_max", "interpret", "reserved_scratch")

#: default entry — pool buffer NOT donated (safe for callers that reuse it,
#: e.g. parity checks running several impls against one pool)
selective_copy = jax.jit(_selective_copy_impl, static_argnames=_JIT_STATICS)

#: donating entry — the pool argument (index 3) is donated through the
#: outer jit, so the resident device pool is updated truly in place
#: (one live pool buffer across rounds; see DevicePool.anchor_batch_device)
selective_copy_donated = jax.jit(_selective_copy_impl,
                                 static_argnames=_JIT_STATICS,
                                 donate_argnums=(3,))


#: policy condition-offset encoding (shared with repro.core.policy):
#: ``-1`` is a padding slot (always true); ``<= -2`` is a *payload-prefix*
#: condition matching first-anchored-page position ``-offset - 2``
PAD_COND = -1
PAYLOAD_COND_BASE = -2


def _policy_rule_match(row, mlen, off, lo, hi, *, m: int, r: int, k: int,
                       payload=None, plen=None, w: int = 0):
    """Shared condition-evaluation body for the standalone policy kernel
    and the fused round: metadata conditions gather ``row[off]`` via a
    one-hot lane mask (no dynamic indexing); payload-prefix conditions
    (``off <= -2``) gather position ``-off - 2`` of the first anchored
    page window the same way. Returns the [R] rule_ok mask."""
    lane = jax.lax.broadcasted_iota(jnp.int32, (r * k, m), 1)
    oh = lane == off.reshape(r * k, 1)
    vals = jnp.sum(jnp.where(oh, jnp.broadcast_to(row[None, :], (r * k, m)),
                             0), axis=1).reshape(r, k)
    pad = off == PAD_COND
    present = (off >= 0) & (off < mlen) & (off < m)
    ok = pad | (present & (vals >= lo) & (vals <= hi))
    if payload is not None:
        # payload-prefix conditions: position -off-2 of the message's
        # first anchored page, gated on the window and the payload length
        ppos = PAYLOAD_COND_BASE - off
        plane = jax.lax.broadcasted_iota(jnp.int32, (r * k, w), 1)
        poh = plane == ppos.reshape(r * k, 1)
        pvals = jnp.sum(
            jnp.where(poh, jnp.broadcast_to(payload[None, :], (r * k, w)), 0),
            axis=1).reshape(r, k)
        pay_ok = (off <= PAYLOAD_COND_BASE) & (ppos < plen) & (ppos < w) \
            & (pvals >= lo) & (pvals <= hi)
        ok = ok | pay_ok
    return jnp.all(ok, axis=1)                             # [R]


def _policy_kernel(*refs, m: int, r: int, k: int,
                   has_ks: bool, has_live: bool, has_payload: bool, w: int):
    refs = list(refs)
    mlen_ref = refs.pop(0)
    plen_ref = refs.pop(0) if has_payload else None
    meta_ref = refs.pop(0)
    ks_ref = refs.pop(0) if has_ks else None
    off_ref, lo_ref, hi_ref = refs[:3]
    refs = refs[3:]
    live_ref = refs.pop(0) if has_live else None
    payload_ref = refs.pop(0) if has_payload else None
    (out_ref,) = refs
    b = pl.program_id(0)
    mlen = mlen_ref[b]
    row = meta_ref[0, :]                                   # [M]
    if has_ks:
        # hw-kTLS: match against decrypted metadata — the keystream XOR
        # fused into the match pass, no separate decrypt
        row = jnp.bitwise_xor(row, ks_ref[0, :])
    rule_ok = _policy_rule_match(
        row, mlen, off_ref[:, :], lo_ref[:, :], hi_ref[:, :], m=m, r=r, k=k,
        payload=payload_ref[0, :] if has_payload else None,
        plen=plen_ref[b] if has_payload else None, w=w)
    if has_live:
        # backend-health column: dead rules (every backend down) never
        # win the first-match scan — failover priority in-plane
        rule_ok &= live_ref[0, :] > 0
    ridx = jax.lax.broadcasted_iota(jnp.int32, (r,), 0)
    out_ref[0, 0] = jnp.min(jnp.where(rule_ok, ridx, r))


@functools.partial(jax.jit, static_argnames=("interpret",))
def policy_match(
    meta: jax.Array,       # [B, M] int32 metadata tokens (round-padded)
    meta_len: jax.Array,   # [B] int32
    cond_off: jax.Array,   # [R, K] int32 (-1 = padding; <= -2 payload-prefix)
    cond_lo: jax.Array,    # [R, K] int32
    cond_hi: jax.Array,    # [R, K] int32
    *,
    interpret: bool = False,
    keystream: jax.Array = None,   # [B, M] int32 (hw-kTLS) or None
    live: jax.Array = None,        # [R] int32 backend-health mask or None
    payload: jax.Array = None,     # [B, W] int32 first-page window or None
    payload_len: jax.Array = None, # [B] int32 payload lengths (with payload)
) -> jax.Array:
    """L7 policy-table first-match kernel — the in-data-plane routing
    decision, fused into the batched metadata pass. One grid step per
    message evaluates all R×K dense conditions against that message's
    metadata row in VMEM and writes the first matching rule index (``R``
    = no match). The optional ``keystream`` operand (same [B, M] layout,
    zeros on plaintext lanes) XORs the metadata inside the same step, so
    hw-kTLS rounds match against decrypted metadata with zero extra
    passes. The optional ``live`` operand ([R] int32, the HealthTable
    rule mask) masks dead rules out of the first-match scan — backend
    failover priority resolved in-plane. The optional ``payload`` operand
    ([B, W] plaintext window of each message's first anchored page, with
    ``payload_len``) serves *payload-prefix* conditions (``cond_off <=
    -2`` encodes page position ``-cond_off - 2``); without it those
    conditions simply never match. Touches only [B, M] metadata, the
    [R, K] table, and the page-sized window — never the payload pool — so
    the hot path performs no pool-sized copy by construction (gated in
    check_kernel_parity). Matches ``kernels.ref.policy_match_ref``.
    Returns [B] int32."""
    b, m = meta.shape
    r, k = cond_off.shape
    has_ks = keystream is not None
    if has_ks:
        assert keystream.shape == meta.shape, (keystream.shape, meta.shape)
    has_live = live is not None
    has_payload = payload is not None
    w = payload.shape[1] if has_payload else 0
    if has_payload:
        assert payload.shape[0] == b and payload_len is not None, \
            (payload.shape, b)

    meta_spec = pl.BlockSpec((1, m), lambda b_, *_: (b_, 0))
    table_spec = pl.BlockSpec((r, k), lambda b_, *_: (0, 0))
    in_specs = [meta_spec]
    operands = [meta]
    if has_ks:
        in_specs.append(meta_spec)       # keystream rides the meta layout
        operands.append(keystream)
    in_specs += [table_spec, table_spec, table_spec]
    operands += [cond_off, cond_lo, cond_hi]
    if has_live:
        assert live.shape == (r,), (live.shape, r)
        in_specs.append(pl.BlockSpec((1, r), lambda b_, *_: (0, 0)))
        operands.append(jnp.asarray(live, jnp.int32).reshape(1, r))
    if has_payload:
        in_specs.append(pl.BlockSpec((1, w), lambda b_, *_: (b_, 0)))
        operands.append(payload)
        prefetch = (meta_len, jnp.asarray(payload_len, jnp.int32))
    else:
        prefetch = (meta_len,)

    out = pl.pallas_call(
        functools.partial(_policy_kernel, m=m, r=r, k=k, has_ks=has_ks,
                          has_live=has_live, has_payload=has_payload, w=w),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1), lambda b_, *_: (b_, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
    )(*prefetch, *operands)
    return out[:, 0]


def _gather_kernel(len_ref, tables_ref, pool_ref, *rest,
                   page: int, has_ks: bool):
    if has_ks:
        ks_ref, out_ref = rest
    else:
        (out_ref,) = rest
    b = pl.program_id(0)
    j = pl.program_id(1)   # output page slot j covers payload [j*page, ...)
    pid = tables_ref[b, j]
    ln = len_ref[b]
    rel = j * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = (pid >= 0) & (rel < ln)
    toks = pool_ref[0, :]
    if has_ks:
        # hw-kTLS TX: encrypt inline while consuming the anchored page —
        # the same fused single pass as the ingress decrypt
        toks = jnp.bitwise_xor(toks, ks_ref[0, :])
    out_ref[0, :] = jnp.where(valid, toks, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_gather(
    pool: jax.Array,      # [P+1, page] int32; last row = reserved scratch
    tables: jax.Array,    # [B, pps] int32 source page ids (-1 unused)
    lengths: jax.Array,   # [B] int32 payload lengths
    *,
    interpret: bool = False,
    keystream: jax.Array = None,   # [B, pps*page] int32 (hw-kTLS TX) or None
):
    """Egress half of the paper's data plane: gather each message's anchored
    payload out of the resident pool in one fused pass — the TX-Prog mirror
    of :func:`selective_copy`'s payload anchoring. Returns ``out [B,
    pps*page]`` where ``out[i, :lengths[i]]`` is message ``i``'s payload
    (page ``tables[i, j]`` supplies payload positions ``[j*page, (j+1)*
    page)``) and every lane past the length is zero. The pool is read-only
    (nothing is donated); invalid table entries (-1) are routed to the
    reserved scratch row and masked, so no real page is ever touched by a
    non-owner step and the call performs **no pool-sized copy**.

    ``keystream`` (payload-relative, zeros past each length) is XORed into
    the gathered tokens inside the same pass — NIC-inline TX encryption,
    zero extra passes. Matches ``kernels.ref.selective_gather_ref``."""
    p_ext, page = pool.shape
    b, pps = tables.shape
    scratch = p_ext - 1
    has_ks = keystream is not None
    if has_ks:
        assert keystream.shape == (b, pps * page), \
            (keystream.shape, (b, pps * page))

    def _pool_index(b_, j, ln, tbl):
        pid = tbl[b_, j]
        return (jnp.where(pid < 0, scratch, pid), 0)

    in_specs = [pl.BlockSpec((1, page), _pool_index)]
    operands = [pool]
    if has_ks:
        in_specs.append(pl.BlockSpec((1, page), lambda b_, j, ln, tbl: (b_, j)))
        operands.append(keystream)

    out = pl.pallas_call(
        functools.partial(_gather_kernel, page=page, has_ks=has_ks),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, pps),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, page), lambda b_, j, ln, tbl: (b_, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, pps * page), pool.dtype),
        interpret=interpret,
    )(lengths, tables, *operands)
    return out


def _fused_round_kernel(mlen_ref, tlen_ref, tables_ref, *refs,
                        page: int, s: int, meta_max: int, b_rows: int,
                        r: int, k: int, has_ks: bool, has_txks: bool,
                        has_policy: bool, has_live: bool, has_meta_ks: bool,
                        n_buffers: int):
    refs = list(refs)
    stream_ref = refs.pop(0)
    ks_ref = refs.pop(0) if has_ks else None
    txks_ref = refs.pop(0) if has_txks else None
    pool_in_ref = refs.pop(0)
    off_ref = lo_ref = hi_ref = metaks_ref = live_ref = None
    if has_policy:
        off_ref, lo_ref, hi_ref = refs[:3]
        refs = refs[3:]
        metaks_ref = refs.pop(0) if has_meta_ks else None
        live_ref = refs.pop(0) if has_live else None
    meta_ref = refs.pop(0)
    pool_ref = refs.pop(0)
    out_ref = refs.pop(0)
    verdict_ref = refs.pop(0) if has_policy else None
    stream_buf = stream_sem = ks_buf = ks_sem = None
    if n_buffers:
        stream_buf, stream_sem = refs.pop(0), refs.pop(0)
        if has_ks:
            ks_buf, ks_sem = refs.pop(0), refs.pop(0)
    assert not refs, refs

    b = pl.program_id(0)
    j = pl.program_id(1)   # 0 = metadata+policy step; j >= 1 = payload page j-1
    mlen = mlen_ref[b]
    tlen = tlen_ref[b]

    if n_buffers:
        # row-level DMA staging: the [1, S] stream row (and its keystream
        # row) for batch row b + D - 1 is prefetched from off-chip memory
        # into VMEM slot (b + D - 1) % D while row b computes — metadata
        # prefetch for tile i+1 overlaps compute on tile i. Slot reuse is
        # safe under the sequential grid: row b + D - 1 lands in slot
        # (b - 1) % D, whose previous owner (row b - 1) ran its last grid
        # step before (b, 0) executes.
        def _start(row):
            slot = row % n_buffers
            pltpu.make_async_copy(stream_ref.at[pl.ds(row, 1), :],
                                  stream_buf.at[slot],
                                  stream_sem.at[slot]).start()
            if has_ks:
                pltpu.make_async_copy(ks_ref.at[pl.ds(row, 1), :],
                                      ks_buf.at[slot],
                                      ks_sem.at[slot]).start()

        def _wait(row):
            slot = row % n_buffers
            pltpu.make_async_copy(stream_ref.at[pl.ds(row, 1), :],
                                  stream_buf.at[slot],
                                  stream_sem.at[slot]).wait()
            if has_ks:
                pltpu.make_async_copy(ks_ref.at[pl.ds(row, 1), :],
                                      ks_buf.at[slot],
                                      ks_sem.at[slot]).wait()

        @pl.when(j == 0)
        def _dma():
            @pl.when(b == 0)
            def _warm_up():
                for i in range(min(n_buffers - 1, b_rows)):
                    _start(i)

            nxt = b + n_buffers - 1

            @pl.when(nxt < b_rows)
            def _prefetch_ahead():
                _start(nxt)

            _wait(b)

    def _load_row(start, width, ks=False):
        # one row window [start, start+width) of the stream (or keystream):
        # from this row's VMEM staging slot when DMA-pipelined, else from
        # the blocked operand directly
        if n_buffers:
            buf = ks_buf if ks else stream_buf
            return pl.load(buf, (pl.dslice(b % n_buffers, 1), pl.dslice(0, 1),
                                 pl.dslice(start, width)))[0, 0]
        ref = ks_ref if ks else stream_ref
        return pl.load(ref, (pl.dslice(0, 1), pl.dslice(start, width)))[0]

    # ---- anchor + egress gather (j >= 1; j == 0 routed to scratch) ----
    jj = jnp.maximum(j - 1, 0)
    pid = tables_ref[b, jj]
    start = jnp.minimum(mlen + jj * page, s - page)  # in-bounds (caller pads S)
    toks = _load_row(start, page)
    if has_ks:
        # hw-kTLS RX: decrypt on the fly, inside the one placement pass
        toks = jnp.bitwise_xor(toks, _load_row(start, page, ks=True))
    rel = jj * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    valid = (j > 0) & (pid >= 0) & (rel + mlen < tlen)
    pool_ref[0, :] = jnp.where(valid, toks, pool_in_ref[0, :])

    @pl.when(j > 0)
    def _gather():
        # egress half fused in: the freshly anchored tokens are still in
        # registers, so the gather re-reads nothing from the pool. Anchor
        # validity (rel + mlen < tlen) IS gather validity (rel < plen).
        gtoks = toks
        if has_txks:
            # speculative hw-kTLS TX encrypt for the hinted destination
            gtoks = jnp.bitwise_xor(gtoks, txks_ref[0, :])
        out_ref[0, :] = jnp.where(valid, gtoks, 0)

    @pl.when(j == 0)
    def _meta():
        idx = jax.lax.broadcasted_iota(jnp.int32, (meta_max,), 0)
        window = _load_row(0, meta_max)
        meta_ref[0, :] = jnp.where(idx < mlen, window, 0)
        if has_policy:
            plen = tlen - mlen
            row = window
            if has_meta_ks:
                row = jnp.bitwise_xor(row, metaks_ref[0, :])
            # payload-prefix window: the first anchored page, decrypted
            # with the same rx-keystream lanes the anchoring step consumes.
            # Whenever plen >= 1 the caller's S >= mlen + page invariant
            # makes the clamp a no-op; at plen == 0 the ppos < plen gate
            # discards the window, so its content is irrelevant.
            pstart = jnp.minimum(mlen, s - page)
            prow = _load_row(pstart, page)
            if has_ks:
                prow = jnp.bitwise_xor(prow, _load_row(pstart, page, ks=True))
            rule_ok = _policy_rule_match(
                row, mlen, off_ref[:, :], lo_ref[:, :], hi_ref[:, :],
                m=meta_max, r=r, k=k, payload=prow, plen=plen, w=page)
            if has_live:
                rule_ok &= live_ref[0, :] > 0
            ridx = jax.lax.broadcasted_iota(jnp.int32, (r,), 0)
            verdict_ref[0, 0] = jnp.min(jnp.where(rule_ok, ridx, r))


def _fused_round_impl(
    stream: jax.Array,     # [B, S] int32
    meta_len: jax.Array,   # [B] int32
    total_len: jax.Array,  # [B] int32
    pool: jax.Array,       # [P+1, page] int32; last row = reserved scratch
    tables: jax.Array,     # [B, pps] int32
    keystream: jax.Array = None,      # [B, S] int32 hw-kTLS RX or None
    tx_keystream: jax.Array = None,   # [B, pps*page] int32 hw-kTLS TX or None
    cond_off: jax.Array = None,       # [R, K] int32 policy table or None
    cond_lo: jax.Array = None,
    cond_hi: jax.Array = None,
    live: jax.Array = None,           # [R] int32 health column or None
    meta_ks: jax.Array = None,        # [B, meta_max] int32 meta ks or None
    *,
    meta_max: int,
    interpret: bool = False,
    n_buffers: int = 0,
):
    """The **one-kernel scheduling round**: a single ``pallas_call`` chains
    selective-copy anchoring, the hw-kTLS keystream XOR, the policy-table
    first-match pass (live health column + payload-prefix conditions
    included), and the egress gather — one launch per round instead of
    three, against the resident pool. Returns ``(meta [B, meta_max],
    new_pool, verdict [B] | None, out [B, pps*page])``; matches
    ``kernels.ref.fused_round_ref`` bit-for-bit.

    Grid ``(B, 1 + pps)``: step ``j == 0`` of each row compacts metadata
    AND produces the policy verdict (the first-page window is loaded once
    and shared); steps ``j >= 1`` anchor payload page ``j - 1`` in place
    (pool aliased/donated, scratch-row routing — no pool-sized copy) and
    write the same tokens, optionally TX-encrypted, to the gather output
    while they are still in registers.

    ``n_buffers >= 2`` enables DMA pipelining: the stream (and RX
    keystream) operands move to off-chip ``ANY`` memory and each [1, S]
    row is staged into one of ``n_buffers`` VMEM slots by an async copy
    issued one row ahead of compute (double/quad buffering; depth chosen
    by :mod:`repro.kernels.dma_profile`). ``n_buffers == 0`` compiles the
    plain blocked layout.

    Caller invariants (both hold for `_recv_batch_device` streams and
    ``testing.fused_round_case``): ``S`` is page-aligned with ``S >=
    meta_max``, and ``S >= meta_len[i] + pps_i * page`` per row, so the
    page-window clamp never fires on a lane that passes the valid gate."""
    b, s = stream.shape
    p_ext, page = pool.shape
    pps = tables.shape[1]
    assert s % page == 0 and s >= page and s >= meta_max, (s, page, meta_max)
    assert pps >= 1, "fused_round needs >= 1 table column (pad tables)"
    assert n_buffers == 0 or n_buffers >= 2, n_buffers
    has_ks = keystream is not None
    has_txks = tx_keystream is not None
    has_policy = cond_off is not None
    has_live = live is not None
    has_meta_ks = meta_ks is not None
    if has_ks:
        assert keystream.shape == stream.shape, (keystream.shape, stream.shape)
    if has_txks:
        assert tx_keystream.shape == (b, pps * page), tx_keystream.shape
    r = k = 0
    if has_policy:
        r, k = cond_off.shape
        if has_meta_ks:
            assert meta_ks.shape == (b, meta_max), (meta_ks.shape, b, meta_max)
    else:
        assert not (has_live or has_meta_ks)
    scratch = p_ext - 1

    def _pool_index(b_, j, ml, tl, tbl):
        # invalid table entries (-1) and the metadata step are routed to the
        # scratch row so no real page is ever revisited by a non-owner step
        pid = tbl[b_, jnp.maximum(j - 1, 0)]
        return (jnp.where((j == 0) | (pid < 0), scratch, pid), 0)

    if n_buffers:
        # stream rows live off-chip and are staged by the kernel's own DMAs
        stream_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    else:
        stream_spec = pl.BlockSpec((1, s), lambda b_, j, ml, tl, tbl: (b_, 0))
    row_spec = pl.BlockSpec((1, meta_max), lambda b_, j, ml, tl, tbl: (b_, 0))
    gather_spec = pl.BlockSpec(
        (1, page), lambda b_, j, ml, tl, tbl: (b_, jnp.maximum(j - 1, 0)))
    in_specs = [stream_spec]
    operands = [stream]
    if has_ks:
        in_specs.append(stream_spec)        # keystream rides the stream layout
        operands.append(keystream)
    if has_txks:
        in_specs.append(gather_spec)        # payload-relative TX keystream
        operands.append(tx_keystream)
    in_specs.append(pl.BlockSpec((1, page), _pool_index))
    operands.append(pool)
    # pool operand index counts the 3 scalar-prefetch args
    pool_operand = 3 + len(operands) - 1
    if has_policy:
        table_spec = pl.BlockSpec((r, k), lambda b_, j, ml, tl, tbl: (0, 0))
        in_specs += [table_spec, table_spec, table_spec]
        operands += [cond_off, cond_lo, cond_hi]
        if has_meta_ks:
            in_specs.append(row_spec)
            operands.append(meta_ks)
        if has_live:
            assert live.shape == (r,), (live.shape, r)
            in_specs.append(
                pl.BlockSpec((1, r), lambda b_, j, ml, tl, tbl: (0, 0)))
            operands.append(jnp.asarray(live, jnp.int32).reshape(1, r))

    out_specs = [row_spec,
                 pl.BlockSpec((1, page), _pool_index),
                 gather_spec]
    out_shape = [jax.ShapeDtypeStruct((b, meta_max), stream.dtype),
                 jax.ShapeDtypeStruct((p_ext, page), pool.dtype),
                 jax.ShapeDtypeStruct((b, pps * page), stream.dtype)]
    if has_policy:
        out_specs.append(
            pl.BlockSpec((1, 1), lambda b_, j, ml, tl, tbl: (b_, 0)))
        out_shape.append(jax.ShapeDtypeStruct((b, 1), jnp.int32))
    scratch_shapes = []
    if n_buffers:
        scratch_shapes += [pltpu.VMEM((n_buffers, 1, s), stream.dtype),
                           pltpu.SemaphoreType.DMA((n_buffers,))]
        if has_ks:
            scratch_shapes += [pltpu.VMEM((n_buffers, 1, s), stream.dtype),
                               pltpu.SemaphoreType.DMA((n_buffers,))]

    res = pl.pallas_call(
        functools.partial(_fused_round_kernel, page=page, s=s,
                          meta_max=meta_max, b_rows=b, r=r, k=k,
                          has_ks=has_ks, has_txks=has_txks,
                          has_policy=has_policy, has_live=has_live,
                          has_meta_ks=has_meta_ks, n_buffers=n_buffers),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, 1 + pps),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch_shapes,
        ),
        out_shape=out_shape,
        input_output_aliases={pool_operand: 1},
        interpret=interpret,
    )(meta_len, total_len, tables, *operands)
    verdict = res[3][:, 0] if has_policy else None
    return res[0], res[1], verdict, res[2]


_FUSED_STATICS = ("meta_max", "interpret", "n_buffers")

#: default fused-round entry — pool buffer NOT donated (parity checks)
fused_round = jax.jit(_fused_round_impl, static_argnames=_FUSED_STATICS)

#: donating fused-round entry — the pool argument (index 3) is donated so
#: the resident device pool is updated truly in place across one-kernel
#: rounds (see DevicePool.fused_round_device)
fused_round_donated = jax.jit(_fused_round_impl,
                              static_argnames=_FUSED_STATICS,
                              donate_argnums=(3,))
