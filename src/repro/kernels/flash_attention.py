"""Flash attention Pallas TPU kernel (training / prefill).

Blockwise online-softmax attention with GQA, causal and sliding-window
masking. TPU-native design:

  * q/k/v blocks are tiled (block_q × head_dim) / (block_k × head_dim) with
    head_dim padded to the 128-lane boundary by the caller;
  * scores live entirely in VMEM scratch — the [Sq, Skv] matrix never
    touches HBM (this removes the memory-roofline term the pure-XLA
    blockwise path pays; see EXPERIMENTS.md §Perf);
  * the kv grid dimension is 'arbitrary' (sequential) so the running
    (m, l, acc) scratch carries across kv blocks; causal block skipping is
    done with @pl.when so skipped tiles issue no MXU work.

Layout: q [B, Hq, Sq, hd], k/v [B, Hkv, Skv, hd] -> out [B, Hq, Sq, hd].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nkv: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skipping: tiles entirely above the diagonal do nothing
    q_start = i * block_q
    k_start = j * block_k
    run = True
    if causal:
        run = (k_start <= q_start + block_q - 1)
    if window > 0:
        run = run & (q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T                                        # [bq, bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok = ok & (rows >= cols)
        if window > 0:
            ok = ok & (rows - cols < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(
    q: jax.Array,   # [B, Hq, Sq, hd]
    k: jax.Array,   # [B, Hkv, Skv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv, block_q, block_k)
    nq, nkv = sq // block_q, skv // block_k
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nkv=nkv)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            # m, l, acc persist across the sequential kv dimension
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")) if not interpret else None,
    )(q, k, v)
