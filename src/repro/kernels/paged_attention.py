"""Paged decode attention Pallas TPU kernel — the Libra fast path.

The block table (VPI-resolved page metadata) rides in SMEM via scalar
prefetch: page addresses are known *before* each DMA issues, which is the
kernel-level expression of the paper's parse-then-move structure (RX-Prog
decides, the data plane moves). Anchored KV pages stream HBM→VMEM in place —
no gather materialisation, no contiguous copy.

Per chip the kernel produces partial softmax statistics (acc, m, l) over the
pages this chip owns; the serving layer psum-combines them across the
combine axes (flash-decode). Semantics match kernels.ref.paged_attention_ref.

Layout: q [B, Hq, hd]; pool [P, page, 2, Hkv, hd]; tables/page_pos [B, pps].
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, ppos_ref, slens_ref,  # scalar prefetch (SMEM)
                  q_ref, pool_ref,                   # VMEM blocks
                  acc_out, m_out, l_out,             # outputs
                  m_s, l_s, acc_s,                   # scratch
                  *, scale: float, window: int, pps: int, page: int,
                  hkv: int, g: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    pid = tables_ref[b, j]
    base = ppos_ref[b, j]
    slen = slens_ref[b]

    @pl.when(pid >= 0)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale           # [Hq, hd]
        kv = pool_ref[0].astype(jnp.float32)               # [page, 2, Hkv, hd]
        k = kv[:, 0]                                       # [page, Hkv, hd]
        v = kv[:, 1]
        qg = q.reshape(hkv, g, q.shape[-1])                # [Hkv, G, hd]
        # scores per kv head: [Hkv, G, page]
        s = jax.lax.dot_general(
            qg, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)            # [Hkv, G, page]
        off = base + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
        ok = off <= slen
        if window > 0:
            ok = ok & (slen - off < window)
        s = jnp.where(ok, s, NEG_INF)
        sm = s.reshape(hkv * g, page)
        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(sm, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sm - m_new[:, None])
        p = jnp.where(ok.reshape(1, page), p, 0.0)
        l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(hkv, g, page), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)            # [Hkv, G, hd]
        acc_s[...] = acc_s[...] * alpha[:, None] + pv.reshape(hkv * g, -1)
        m_s[...] = m_new

    @pl.when(j == pps - 1)
    def _finalize():
        acc_out[0] = acc_s[...].astype(acc_out.dtype)
        m_out[0] = m_s[...].astype(m_out.dtype)
        l_out[0] = l_s[...].astype(l_out.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(
    q: jax.Array,        # [B, Hq, hd]
    pool: jax.Array,     # [P, page, 2, Hkv, hd]
    tables: jax.Array,   # [B, pps] int32 local page ids (-1 invalid)
    page_pos: jax.Array, # [B, pps] int32 base positions
    seq_lens: jax.Array, # [B] int32 highest valid position (inclusive)
    *,
    window: int = 0,
    interpret: bool = False,
):
    """Returns partial (acc [B,Hq,hd] f32, m [B,Hq] f32, l [B,Hq] f32)."""
    b, hq, hd = q.shape
    p_, page, _, hkv, _ = pool.shape
    pps = tables.shape[1]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               pps=pps, page=page, hkv=hkv, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, pps),
        in_specs=[
            pl.BlockSpec((1, hq, hd), lambda b_, j, tbl, pp, sl: (b_, 0, 0)),
            pl.BlockSpec((1, page, 2, hkv, hd),
                         lambda b_, j, tbl, pp, sl: (
                             jnp.maximum(tbl[b_, j], 0), 0, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hq, hd), lambda b_, j, tbl, pp, sl: (b_, 0, 0)),
            pl.BlockSpec((1, hq), lambda b_, j, tbl, pp, sl: (b_, 0)),
            pl.BlockSpec((1, hq), lambda b_, j, tbl, pp, sl: (b_, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hq,), jnp.float32),
            pltpu.VMEM((hq,), jnp.float32),
            pltpu.VMEM((hq, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, hq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
        if not interpret else None,
    )(tables, page_pos, seq_lens, q, pool)
