"""Public jit'd wrappers for the Pallas kernels with backend dispatch.

``impl='auto'`` picks the Pallas kernel on TPU and the pure-jnp oracle on
CPU (the dry-run and tests run on CPU; interpret=True executes the kernel
body in Python for correctness validation). The serving/training layers
call these wrappers so the kernel/oracle switch is one flag.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

import functools

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.mlstm_scan import mlstm_scan as _mlstm_pallas
from repro.kernels.paged_attention import paged_attention as _paged_pallas
from repro.kernels.selective_copy import selective_copy as _selcopy_pallas
from repro.kernels.selective_copy import (
    selective_copy_donated as _selcopy_pallas_donated,
)
from repro.kernels.selective_copy import policy_match as _polmatch_pallas
from repro.kernels.selective_copy import selective_gather as _selgather_pallas
from repro.kernels.selective_copy import fused_round as _fused_pallas
from repro.kernels.selective_copy import (
    fused_round_donated as _fused_pallas_donated,
)

# donated oracle entries: same jnp bodies, outer jit donates the pool arg —
# the resident DevicePool's rounds keep one pool buffer instead of two
_selcopy_ref_donated = functools.partial(
    jax.jit, static_argnames=("meta_max",), donate_argnums=(3,))
_selcopy_ref_donated_plain = _selcopy_ref_donated(_ref.selective_copy_ref)
_selcopy_ref_donated_crypto = _selcopy_ref_donated(
    _ref.selective_copy_crypto_ref)
_fused_ref = jax.jit(_ref.fused_round_ref, static_argnames=("meta_max",))
_fused_ref_donated = jax.jit(_ref.fused_round_ref,
                             static_argnames=("meta_max",),
                             donate_argnums=(3,))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if _on_tpu() else "ref"


def flash_attention(q, k, v, *, causal=True, window=0, impl="auto",
                    block_q=512, block_k=512):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         interpret=(impl == "interpret"))


def paged_attention(q, pool, tables, page_pos, seq_lens, *, window=0,
                    impl="auto"):
    """Per-chip partial (acc, m, l) over owned anchored pages."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.paged_attention_ref(q, pool, tables, page_pos, seq_lens,
                                        window=window)
    return _paged_pallas(q, pool, tables, page_pos, seq_lens, window=window,
                         interpret=(impl == "interpret"))


def selective_copy(stream, meta_len, total_len, pool, tables, *, meta_max,
                   impl="auto", reserved_scratch=False, keystream=None,
                   donate_pool=False):
    """``reserved_scratch=True`` marks the pool's last row as the scratch
    page :class:`AnchorPool` reserved at allocation time — the fused kernel
    then runs with zero pool-sized copies (tables must never reference it).
    The oracle needs no flag: it never touches a row tables don't name.

    ``keystream`` ([B, S] int32, zeros outside the payload region) is the
    kTLS-analogue hw mode: payload tokens are XORed with it inside the
    anchoring pass (NIC-inline decrypt, zero extra passes).

    ``donate_pool=True`` donates the pool argument through the outer jit
    (every backend): the anchoring updates the caller's buffer in place —
    ONE live pool allocation per round instead of input + output. Only for
    callers that hand over ownership (the resident DevicePool); the input
    array is deleted by XLA afterwards."""
    impl = _resolve(impl)
    if impl == "ref":
        if donate_pool:
            if keystream is None:
                return _selcopy_ref_donated_plain(
                    stream, meta_len, total_len, pool, tables,
                    meta_max=meta_max)
            return _selcopy_ref_donated_crypto(
                stream, meta_len, total_len, pool, tables,
                jnp.asarray(keystream), meta_max=meta_max)
        if keystream is None:
            return _ref.selective_copy_ref(stream, meta_len, total_len, pool,
                                           tables, meta_max=meta_max)
        return _ref.selective_copy_crypto_ref(
            stream, meta_len, total_len, pool, tables,
            jnp.asarray(keystream), meta_max=meta_max)
    ks = None if keystream is None else jnp.asarray(keystream)
    entry = _selcopy_pallas_donated if donate_pool else _selcopy_pallas
    return entry(stream, meta_len, total_len, pool, tables,
                 meta_max=meta_max, interpret=(impl == "interpret"),
                 reserved_scratch=reserved_scratch, keystream=ks)


def selective_gather(pool, tables, lengths, *, impl="auto", keystream=None):
    """Egress mirror of :func:`selective_copy`: one fused gather of each
    message's anchored payload out of the resident pool ([B, pps*page],
    zero past the lengths). The pool's last row must be the reserved
    scratch page (invalid table entries route there). ``keystream``
    (payload-relative [B, pps*page] int32) fuses hw-kTLS TX encryption
    into the gather."""
    impl = _resolve(impl)
    ks = None if keystream is None else jnp.asarray(keystream)
    if impl == "ref":
        return _ref.selective_gather_ref(pool, tables, lengths, ks)
    return _selgather_pallas(pool, tables, lengths,
                             interpret=(impl == "interpret"), keystream=ks)


def policy_match(meta, meta_len, cond_off, cond_lo, cond_hi, *, impl="auto",
                 keystream=None, live=None, payload=None, payload_len=None):
    """L7 policy-table first-match pass over one batched round's metadata
    block: [B, M] meta × dense [R, K] conditions → [B] first matching rule
    (R = no match). ``keystream`` (0 on plaintext lanes) fuses the hw-kTLS
    metadata decrypt into the match. ``live`` ([R] int32, the backend
    HealthTable rule mask; ``None`` = all live) masks dead rules out of
    the scan. ``payload``/``payload_len`` ([B, W] plaintext first-page
    window + [B] lengths) serve payload-prefix conditions (``cond_off <=
    -2``); omitted, those conditions never match. The routing-decision
    half of the in-data-plane policy engine (:mod:`repro.core.policy`
    resolves actions host-side)."""
    impl = _resolve(impl)
    ks = None if keystream is None else jnp.asarray(keystream)
    lv = None if live is None else jnp.asarray(live, jnp.int32)
    pw = None if payload is None else jnp.asarray(payload)
    pln = None if payload_len is None else jnp.asarray(payload_len, jnp.int32)
    if impl == "ref":
        return _ref.policy_match_ref(meta, meta_len, cond_off, cond_lo,
                                     cond_hi, ks, lv, payload=pw,
                                     payload_len=pln)
    return _polmatch_pallas(meta, meta_len, cond_off, cond_lo, cond_hi,
                            interpret=(impl == "interpret"), keystream=ks,
                            live=lv, payload=pw, payload_len=pln)


def fused_round(stream, meta_len, total_len, pool, tables, *, meta_max,
                impl="auto", keystream=None, tx_keystream=None,
                cond_off=None, cond_lo=None, cond_hi=None, live=None,
                meta_ks=None, n_buffers=0, donate_pool=False):
    """The one-kernel scheduling round: anchor + hw-kTLS RX decrypt +
    policy first-match (payload-prefix conditions included) + egress
    gather in a SINGLE device launch against the resident pool (the pool's
    last row must be the reserved scratch page). Returns ``(meta,
    new_pool, verdict | None, out)``. ``tx_keystream`` speculatively
    TX-encrypts the gather output for a hinted destination session;
    ``n_buffers >= 2`` enables the kernel's internal DMA pipelining
    (ignored by the oracle). ``donate_pool=True`` donates the pool through
    the outer jit — one live pool buffer per round (see
    DevicePool.fused_round_device)."""
    impl = _resolve(impl)
    ks = None if keystream is None else jnp.asarray(keystream)
    tks = None if tx_keystream is None else jnp.asarray(tx_keystream)
    mks = None if meta_ks is None else jnp.asarray(meta_ks)
    lv = None if live is None else jnp.asarray(live, jnp.int32)
    if impl == "ref":
        entry = _fused_ref_donated if donate_pool else _fused_ref
        return entry(stream, meta_len, total_len, pool, tables,
                     meta_max=meta_max, keystream=ks, tx_keystream=tks,
                     cond_off=cond_off, cond_lo=cond_lo, cond_hi=cond_hi,
                     live=lv, meta_ks=mks)
    entry = _fused_pallas_donated if donate_pool else _fused_pallas
    return entry(stream, meta_len, total_len, pool, tables,
                 keystream=ks, tx_keystream=tks, cond_off=cond_off,
                 cond_lo=cond_lo, cond_hi=cond_hi, live=lv, meta_ks=mks,
                 meta_max=meta_max, interpret=(impl == "interpret"),
                 n_buffers=n_buffers)


def mlstm_scan(q, k, v, log_i, log_f, *, chunk=64, impl="auto"):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.mlstm_scan_ref(q, k, v, log_i, log_f)
    return _mlstm_pallas(q, k, v, log_i, log_f, chunk=chunk,
                         interpret=(impl == "interpret"))
