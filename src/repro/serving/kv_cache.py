"""Device KV pool + host allocator glue for the serving engines.

``PagedKVPool`` pairs the device-resident anchored pool tensor with the
host-side AnchorPool allocator and produces the int32 metadata arrays
(block tables, page positions, write coordinates) that the device
mechanisms consume — the control-plane half of the Libra datapath.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.anchor_pool import AnchorPool, PageRef, PoolExhausted
from repro.core.vpi import VpiRegistry


@dataclasses.dataclass
class SeqHandle:
    """Anchored-payload handle for one active sequence (VPI-backed)."""
    vpi: int
    pages: List[PageRef]
    seq_len: int          # tokens currently anchored
    header_len: int


class PagedKVPool:
    def __init__(self, model, n_shards: Optional[int] = None,
                 pages_per_shard: Optional[int] = None,
                 page_size: int = 16, registry: Optional[VpiRegistry] = None,
                 max_pages_per_seq: int = 0, dtype=jnp.float32,
                 alloc: Optional[AnchorPool] = None):
        self.model = model
        # either an external allocator (a LibraStack's — its geometry defines
        # the device pool shape) or explicit geometry, never both
        if alloc is not None:
            assert n_shards is None and pages_per_shard is None, \
                "pass geometry via alloc= OR n_shards/pages_per_shard, not both"
            assert alloc.page_size == page_size, (alloc.page_size, page_size)
        else:
            alloc = AnchorPool(n_shards, pages_per_shard, page_size,
                               max_pages_per_seq=max_pages_per_seq)
        self.alloc = alloc
        self.page_size = alloc.page_size
        self.registry = registry or VpiRegistry()
        self.pool = jnp.zeros(model.kv_pool_shape(alloc.total_pages), dtype)
        self.n_shards = alloc.n_shards

    # -- sequence lifecycle -------------------------------------------------
    def anchor_sequence(self, prompt_len: int, header_len: int,
                        reserve: int = 0) -> SeqHandle:
        pages = self.alloc.alloc_sequence(prompt_len + reserve)
        vpi = self.registry.register(
            "kv-pool", [(p.shard, p.local_pid, p.base_pos) for p in pages],
            prompt_len, meta={"header_len": header_len})
        return SeqHandle(vpi, pages, prompt_len, header_len)

    def extend(self, h: SeqHandle, new_len: int) -> None:
        """Grow the anchored region (decode appends)."""
        have = len(h.pages) * self.page_size
        while have < new_len:
            shard = (len(h.pages)) % self.n_shards
            h.pages.append(self.alloc.alloc_page(
                len(h.pages) * self.page_size, shard))
            have += self.page_size
        h.seq_len = new_len

    def release(self, h: SeqHandle) -> None:
        if self.registry.release(h.vpi):
            self.alloc.free_pages_list(h.pages)

    def share(self, h: SeqHandle) -> SeqHandle:
        """Prefix sharing / zero-copy forwarding: bump refcounts, same pages."""
        self.registry.retain(h.vpi)
        self.alloc.retain(h.pages)
        return SeqHandle(h.vpi, list(h.pages), h.seq_len, h.header_len)

    # -- device metadata ------------------------------------------------------
    def batch_tables(self, handles: Sequence[SeqHandle],
                     pps: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        return self.alloc.tables_for([h.pages for h in handles], pps)

    def write_coords(self, handles: Sequence[SeqHandle],
                     positions: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        return AnchorPool.write_coords([h.pages for h in handles], positions,
                                       self.n_shards, self.page_size)

    def token_coords(self, handles: Sequence[SeqHandle], seq_len: int):
        return self.alloc.token_coords([h.pages for h in handles], seq_len)
