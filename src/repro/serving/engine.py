"""Serving engines — the paper's four stacks, as continuous-batching LLM
servers (see DESIGN.md §2 datapaths):

* ``LibraEngine``    — selective copy: paged anchored KV (donated, in-place),
                       parser policy splits header/payload, only token ids +
                       O(pages) int32 metadata cross the host boundary, VPI
                       handles support zero-copy forwarding/prefix sharing.
* ``StandardEngine`` — standard stack: contiguous KV re-materialised every
                       step (undonated buffer = the per-message full copy),
                       full logits shipped to the host *per connection*.
* ``CopierEngine``   — Copier [24]: identical data volume, but all per-
                       connection transfers batched into one fused copy per
                       step (the single async kernel copy).
* ``StaticEngine``   — F-Stack/DPDK analogue: fast fixed preallocated dense
                       buffers; a fixed memory budget caps concurrency, so
                       large payloads collapse attainable batch (the paper's
                       F-Stack large-payload inversion).

All engines expose the same submit()/run() interface and an EngineStats
block mirroring the paper's Figure 9 cost categories.
"""
from __future__ import annotations

import dataclasses
import time
from functools import lru_cache, partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.sharding import make_mesh
from repro.core.anchor_pool import PoolExhausted
from repro.core.parser import TokenStreamParser
from repro.core.stack import LibraStack
from repro.models.attention import plan_decode_sharding
from repro.serving.kv_cache import PagedKVPool, SeqHandle


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # full token stream (header + payload)
    header_len: int
    max_new_tokens: int
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    output: List[int] = dataclasses.field(default_factory=list)
    handle: Optional[SeqHandle] = None
    slot: int = -1

    @property
    def done(self) -> bool:
        return len(self.output) >= self.max_new_tokens


@dataclasses.dataclass
class EngineStats:
    # host<->device boundary traffic (the kernel/user boundary analogue)
    h2d_bytes: int = 0           # tokens + metadata uploaded
    d2h_bytes: int = 0           # tokens / logits downloaded
    d2h_calls: int = 0           # per-connection transfer count
    # device-side payload movement
    payload_copy_bytes: int = 0  # full-cache copies (Std/Copier copy tax)
    anchored_bytes: int = 0      # payload written once into the pool
    zero_copy_bytes: int = 0     # ownership transfers (VPI forwarding)
    steps: int = 0
    prefills: int = 0
    completed: int = 0
    alloc_events: int = 0


def _pad_to(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    if len(x) >= n:
        return x[:n]
    return np.concatenate([x, np.full(n - len(x), fill, x.dtype)])


class _EngineBase:
    name = "base"

    def __init__(self, model, params, *, max_batch: int = 8,
                 max_len: int = 512, parser: Optional[TokenStreamParser] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.parser = parser or TokenStreamParser(header_len=8)
        self.stats = EngineStats()
        self.waiting: List[Request] = []
        self.active: List[Request] = []
        self.completed: List[Request] = []
        self._rid = 0
        self.mesh = make_mesh((1, 1), ("data", "model"))

    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16) -> Request:
        self._rid += 1
        r = Request(self._rid, np.asarray(prompt, np.int32),
                    self.parser.parse(prompt).meta_len, max_new_tokens,
                    submitted_at=time.perf_counter())
        self.waiting.append(r)
        return r

    def run(self, max_steps: int = 10 ** 6) -> List[Request]:
        steps = 0
        while (self.waiting or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed

    # latency metrics -------------------------------------------------------
    def p99_latency(self) -> float:
        lats = sorted((r.done_at - r.submitted_at) for r in self.completed
                      if r.done_at)
        if not lats:
            return 0.0
        return lats[min(len(lats) - 1, int(0.99 * len(lats)))]

    def throughput_tokens(self) -> int:
        return sum(len(r.output) for r in self.completed)


# ---------------------------------------------------------------------------
# Libra engine
# ---------------------------------------------------------------------------

class LibraEngine(_EngineBase):
    name = "libra"

    def __init__(self, model, params, *, max_batch: int = 8, max_len: int = 512,
                 page_size: int = 16, parser=None, pool_pages: int = 0,
                 stack: Optional[LibraStack] = None,
                 kv_pool: Optional[PagedKVPool] = None):
        super().__init__(model, params, max_batch=max_batch, max_len=max_len,
                         parser=parser)
        self.page_size = page_size
        b_axis, combine = plan_decode_sharding(max_batch, self.mesh)
        self.b_axis, self.combine = b_axis, combine
        # one LibraStack per engine "kernel": it owns the page allocator, the
        # VPI registry, the tick clock, and the copy counters. A shared
        # ``stack`` pools that host state across engines; zero-copy
        # CROSS-ENGINE handoff additionally needs the device KV itself
        # shared — pass the first engine's ``kv_pool`` to the second
        # (handles forwarded into an engine with its own pool would index a
        # different, zero-filled device array).
        if stack is None:
            pages = pool_pages or (max_batch * (max_len // page_size + 2) + 4)
            stack = LibraStack(n_shards=1, pages_per_shard=pages,
                               page_size=page_size)
        elif pool_pages:
            raise ValueError("pool_pages conflicts with an external stack: "
                             "the stack's allocator defines the geometry")
        assert stack.alloc.page_size == page_size, \
            (stack.alloc.page_size, page_size)
        self.stack = stack
        if kv_pool is not None:
            assert kv_pool.alloc is stack.alloc, \
                "a shared kv_pool must be backed by the shared stack's allocator"
            self.pool = kv_pool
        else:
            self.pool = PagedKVPool(model, page_size=page_size,
                                    alloc=stack.alloc, registry=stack.registry)
        self.pps = max_len // page_size + 2
        # parking page for inactive slots (keeps decode NaN-free)
        self._parking = self.pool.alloc.alloc_page(0, 0)
        if self.cfg.family == "hybrid":
            d_inner = self.cfg.ssm_expand * self.cfg.d_model
            self.ssm_state = {
                "ssm": jnp.zeros((self.cfg.num_layers, max_batch, d_inner,
                                  self.cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((self.cfg.num_layers, max_batch,
                                   self.cfg.ssm_conv - 1, d_inner), jnp.float32),
            }
        else:
            self.ssm_state = None
        self._jit_decode = jax.jit(
            partial(self.model.decode_step, mesh=self.mesh, batch_axis=b_axis,
                    combine_axes=combine, compute_dtype=jnp.float32),
            donate_argnums=(3,))
        self._jit_prefill_cache: Dict[Tuple[int, int], object] = {}

    # -- ingress (prefill anchors the payload) -------------------------------
    def _prefill_group(self, group: List[Request]) -> None:
        pad_b = len(group)
        s = max(len(r.prompt) for r in group)
        s = max(self.page_size, -(-s // self.page_size) * self.page_size)
        handles = [r.handle for r in group]  # allocated at admission
        tokens = np.stack([_pad_to(r.prompt, s) for r in group])
        seq_lens = np.array([len(r.prompt) for r in group], np.int32)
        tables, _ = self.pool.batch_tables(handles, self.pps)
        tsh, tsl, toff, tval = self.pool.token_coords(handles, s)

        key = (pad_b, s)
        if key not in self._jit_prefill_cache:
            self._jit_prefill_cache[key] = jax.jit(
                partial(self.model.prefill, mesh=self.mesh,
                        batch_axis=self.b_axis, combine_axes=self.combine,
                        compute_dtype=jnp.float32),
                donate_argnums=(3,))
        first, new_pool = self._jit_prefill_cache[key](
            self.params, jnp.array(tokens), jnp.array(seq_lens),
            self.pool.pool, jnp.array(tables), jnp.array(tsh),
            jnp.array(tsl), jnp.array(toff), jnp.array(tval))
        self.pool.pool = new_pool
        first = np.asarray(first)
        now = time.perf_counter()
        for i, r in enumerate(group):
            r.output.append(int(first[i]))
            r.first_token_at = now
        # stats: selective copy — tokens up, ONLY sampled ids down
        self.stats.h2d_bytes += tokens.nbytes + tables.nbytes + tsh.nbytes * 3
        self.stats.d2h_bytes += first.nbytes
        self.stats.d2h_calls += 1
        self.stats.anchored_bytes += int(
            sum(seq_lens) * self._kv_bytes_per_token())
        self.stats.prefills += 1
        self.stats.alloc_events += len(group)

    def _kv_bytes_per_token(self) -> int:
        c = self.cfg
        return c.num_layers * 2 * c.num_kv_heads * c.head_dim * 4

    def step(self) -> None:
        # each engine step advances the stack clock: deferred teardowns from
        # closed connections expire on the engine's cadence (§A.4)
        self.stack.tick()
        # admit
        free = self.max_batch - len(self.active)
        group = []
        while self.waiting and free > 0:
            r = self.waiting[0]
            try:
                # reserve prompt + decode room at admission so an admitted
                # request can always finish (vLLM-style admission soundness);
                # allocation here keeps multi-request waves accounted
                r.handle = self.pool.anchor_sequence(
                    len(r.prompt), r.header_len, reserve=r.max_new_tokens)
            except PoolExhausted:
                break
            c = self.stack.counters
            c.anchored += len(r.prompt) - r.header_len
            c.meta_copied += r.header_len
            c.vpi_injected += 1
            c.allocs += 1
            self.waiting.pop(0)
            group.append(r)
            free -= 1
        if group:
            self._prefill_group(group)
            now = time.perf_counter()
            for r in group:  # gen=1 requests complete at prefill
                if r.done:
                    r.done_at = now
                    self.pool.release(r.handle)
                    self.completed.append(r)
                    self.stats.completed += 1
                else:
                    self.active.append(r)
        if not self.active:
            return

        # decode one token for every active request
        b = self.max_batch
        handles = []
        seq_lens = np.zeros(b, np.int32)
        tokens = np.zeros(b, np.int32)
        slot_req: List[Optional[Request]] = [None] * b
        for i, r in enumerate(self.active):
            r.slot = i
            slot_req[i] = r
            pos = len(r.prompt) + len(r.output) - 1
            self.pool.extend(r.handle, pos + 1)
            handles.append(r.handle)
            seq_lens[i] = pos
            tokens[i] = r.output[-1]
        # inactive slots park on a scratch page
        parking = SeqHandle(0, [self._parking], 0, 0)
        while len(handles) < b:
            handles.append(parking)
        tables, page_pos = self.pool.batch_tables(handles, self.pps)
        wsh, wsl = self.pool.write_coords(handles, seq_lens.tolist())

        out = self._jit_decode(self.params, jnp.array(tokens),
                               jnp.array(seq_lens), self.pool.pool,
                               jnp.array(tables), jnp.array(page_pos),
                               jnp.array(wsh), jnp.array(wsl),
                               ssm_state=self.ssm_state)
        next_tokens, self.pool.pool, new_ssm = out
        if new_ssm is not None:
            self.ssm_state = new_ssm
        next_tokens = np.asarray(next_tokens)

        self.stats.h2d_bytes += (tokens.nbytes + seq_lens.nbytes + tables.nbytes
                                 + page_pos.nbytes + wsh.nbytes + wsl.nbytes)
        self.stats.d2h_bytes += next_tokens.nbytes
        self.stats.d2h_calls += 1
        self.stats.anchored_bytes += len(self.active) * self._kv_bytes_per_token()
        self.stats.steps += 1

        now = time.perf_counter()
        still = []
        for r in self.active:
            r.output.append(int(next_tokens[r.slot]))
            if r.done:
                r.done_at = now
                self.pool.release(r.handle)
                self.completed.append(r)
                self.stats.completed += 1
            else:
                still.append(r)
        self.active = still

    # -- egress: zero-copy forwarding (VPI handoff) ---------------------------
    def forward_handle(self, r: Request) -> SeqHandle:
        """Proxy forwarding: hand the anchored context to another consumer
        without moving payload bytes (refcounted ownership share)."""
        h = self.pool.share(r.handle)
        self.stats.zero_copy_bytes += h.seq_len * self._kv_bytes_per_token()
        self.stack.counters.zero_copied += h.seq_len
        return h

    def release_handle(self, h: SeqHandle) -> None:
        """Drop a forwarded handle (the backend finished with the shared
        context). Facade call so call-sites never touch the pool."""
        self.pool.release(h)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

class StandardEngine(_EngineBase):
    """Contiguous KV re-copied per step + per-connection logits transfers."""
    name = "standard"
    donate_cache = False
    fused_d2h = False

    def __init__(self, model, params, *, max_batch: int = 8, max_len: int = 512,
                 parser=None):
        super().__init__(model, params, max_batch=max_batch, max_len=max_len,
                         parser=parser)
        c = self.cfg
        self.cache = jnp.zeros((c.num_layers, max_batch, max_len, 2,
                                c.num_kv_heads, c.head_dim), jnp.float32)
        self.slot_free = list(range(max_batch))
        donate = (3,) if self.donate_cache else ()
        self._jit_decode = jax.jit(
            lambda p, t, s, cache: model.decode_step_dense(
                p, t, s, cache, compute_dtype=jnp.float32),
            donate_argnums=donate)
        self._jit_prefill_cache: Dict[Tuple[int, int], object] = {}

    def _cache_bytes(self) -> int:
        return int(np.prod(self.cache.shape)) * 4

    def step(self) -> None:
        group = []
        while self.waiting and self.slot_free:
            r = self.waiting.pop(0)
            r.slot = self.slot_free.pop(0)
            group.append(r)
        if group:
            s = max(self.cfg.head_dim // self.cfg.head_dim * 8,
                    max(len(r.prompt) for r in group))
            key = (len(group), s)
            if key not in self._jit_prefill_cache:
                self._jit_prefill_cache[key] = jax.jit(partial(
                    self.model.prefill_dense, max_len=self.max_len,
                    compute_dtype=jnp.float32))
            tokens = np.stack([_pad_to(r.prompt, s) for r in group])
            seq_lens = np.array([len(r.prompt) for r in group], np.int32)
            first, kv = self._jit_prefill_cache[key](
                self.params, jnp.array(tokens), jnp.array(seq_lens))
            first = np.asarray(first)
            now = time.perf_counter()
            for i, r in enumerate(group):
                self.cache = self.cache.at[:, r.slot].set(kv[:, i])
                r.output.append(int(first[i]))
                r.first_token_at = now
                if r.done:  # gen=1 completes at prefill
                    r.done_at = now
                    self.slot_free.append(r.slot)
                    self.completed.append(r)
                    self.stats.completed += 1
                else:
                    self.active.append(r)
            self.stats.h2d_bytes += tokens.nbytes
            self.stats.d2h_bytes += first.nbytes
            self.stats.prefills += 1
            # the prefill KV lands in a fresh contiguous buffer: full copy
            self.stats.payload_copy_bytes += int(np.prod(np.shape(kv))) * 4
            self.stats.alloc_events += len(group)
        if not self.active:
            return

        b = self.max_batch
        tokens = np.zeros(b, np.int32)
        seq_lens = np.zeros(b, np.int32)
        for r in self.active:
            tokens[r.slot] = r.output[-1]
            seq_lens[r.slot] = len(r.prompt) + len(r.output) - 1
        logits, new_cache = self._jit_decode(self.params, jnp.array(tokens),
                                             jnp.array(seq_lens), self.cache)
        self.cache = new_cache
        if not self.donate_cache:
            # undonated contiguous cache: XLA materialises a fresh copy —
            # the standard stack's per-message payload copy
            self.stats.payload_copy_bytes += self._cache_bytes()
        # recv path: logits cross to the host
        if self.fused_d2h:
            host_logits = np.asarray(logits)
            self.stats.d2h_bytes += host_logits.nbytes
            self.stats.d2h_calls += 1
        else:
            host_logits = np.zeros((b, logits.shape[-1]), np.float32)
            for r in self.active:  # per-connection recv copies
                host_logits[r.slot] = np.asarray(logits[r.slot])
                self.stats.d2h_bytes += host_logits[r.slot].nbytes
                self.stats.d2h_calls += 1
        self.stats.h2d_bytes += tokens.nbytes + seq_lens.nbytes
        self.stats.steps += 1

        now = time.perf_counter()
        still = []
        for r in self.active:
            r.output.append(int(np.argmax(host_logits[r.slot])))
            if r.done:
                r.done_at = now
                self.slot_free.append(r.slot)
                self.completed.append(r)
                self.stats.completed += 1
            else:
                still.append(r)
        self.active = still


class CopierEngine(StandardEngine):
    """Copier [24]: same volume, fused into one async copy per step."""
    name = "copier"
    donate_cache = False
    fused_d2h = True


class StaticEngine(StandardEngine):
    """F-Stack analogue: preallocated fixed-budget buffers (fast per step,
    concurrency collapses with payload size)."""
    name = "static"
    donate_cache = True
    fused_d2h = True

    def __init__(self, model, params, *, memory_budget: int, max_len: int = 512,
                 parser=None):
        c = model.cfg
        per_slot = c.num_layers * max_len * 2 * c.num_kv_heads * c.head_dim * 4
        max_batch = max(1, memory_budget // per_slot)
        super().__init__(model, params, max_batch=max_batch, max_len=max_len,
                         parser=parser)
