"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per leaf (flattened key
path) + ``meta.json`` (tree structure, shapes, dtypes, step, data-iterator
state). Commit protocol: write into ``step_<N>.tmp`` then atomic rename —
a crash mid-save never corrupts the latest checkpoint. Saves run on a
background thread (compute/IO overlap); ``wait()`` joins before the next
save or exit.

Restore is mesh-agnostic: leaves are loaded and ``jax.device_put`` against
whatever sharding the *new* mesh prescribes — this is the elastic-restart
path (e.g. 2-pod -> 1-pod re-mesh after a pod loss). On multi-host,
per-host shard files + a global index replace the single .npy per leaf;
the commit/rename protocol is unchanged (noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat = _flatten(host_tree)
            meta = {"step": step, "extra": extra or {}, "leaves": {}}
            for key, leaf in flat.items():
                fn = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), leaf)
                meta["leaves"][key] = {"file": fn,
                                       "shape": list(np.shape(leaf)),
                                       "dtype": str(np.asarray(leaf).dtype)}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Returns (tree, extra). ``like`` provides structure; ``shardings``
        (optional matching pytree) re-shards for the current mesh."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        meta = json.load(open(os.path.join(path, "meta.json")))
        flat_like = _flatten(like)
        loaded = {}
        for key in flat_like:
            info = meta["leaves"][key]
            loaded[key] = np.load(os.path.join(path, info["file"]))
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        keys = list(_flatten(like).keys())
        ordered = [loaded[k] for k in keys]
        tree = jax.tree_util.tree_unflatten(treedef, ordered)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree, shardings)
        return tree, meta["extra"]
