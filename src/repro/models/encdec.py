"""Whisper-style encoder-decoder backbone (whisper-medium).

The conv/audio frontend is a stub per the assignment: ``input_specs``
supplies precomputed frame embeddings [B, enc_frames, D]. The Libra analogue
is at its cleanest here: the encoder output — projected once per layer into
cross-attention K/V — is the bulk payload, anchored on device; the decoder
consumes it in place via the anchored handle. Decoder self-attention uses
the same paged pool as the decoder-only models.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.common.sharding import constrain
from repro.common.types import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    ParamSpec,
    abstract_params,
    apply_rope,
    count_template_params,
    init_params,
    layer_norm,
    mlp_apply,
    mlp_template,
    param_axes,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.transformer import REMAT_POLICIES, stack_template


class EncDecModel:
    def __init__(self, cfg: ModelConfig, page_size: int = 64):
        self.cfg = cfg
        self.page_size = page_size

    # -- params -----------------------------------------------------------
    def _attn_tmpl(self, kv: bool = True) -> Dict:
        c = self.cfg
        t = {
            "wq": ParamSpec((c.d_model, c.q_dim), ("fsdp", "tensor")),
            "wo": ParamSpec((c.q_dim, c.d_model), ("tensor", "fsdp")),
        }
        if kv:
            t["wk"] = ParamSpec((c.d_model, c.kv_dim), ("fsdp", "tensor"))
            t["wv"] = ParamSpec((c.d_model, c.kv_dim), ("fsdp", "tensor"))
        return t

    def enc_layer_template(self) -> Dict:
        c = self.cfg
        return {
            "ln1": ParamSpec((c.d_model,), (None,), init="zeros"),
            "attn": self._attn_tmpl(),
            "ln2": ParamSpec((c.d_model,), (None,), init="zeros"),
            "mlp": mlp_template(c.d_model, c.d_ff, "gelu"),
        }

    def dec_layer_template(self) -> Dict:
        c = self.cfg
        return {
            "ln1": ParamSpec((c.d_model,), (None,), init="zeros"),
            "self_attn": self._attn_tmpl(),
            "ln_x": ParamSpec((c.d_model,), (None,), init="zeros"),
            "cross_attn": self._attn_tmpl(),
            "ln2": ParamSpec((c.d_model,), (None,), init="zeros"),
            "mlp": mlp_template(c.d_model, c.d_ff, "gelu"),
        }

    def template(self) -> Dict:
        c = self.cfg
        return {
            "embed": ParamSpec((c.vocab_size, c.d_model), ("tensor", None),
                               fan_in_dims=(1,)),
            "enc_final_norm": ParamSpec((c.d_model,), (None,), init="zeros"),
            "dec_final_norm": ParamSpec((c.d_model,), (None,), init="zeros"),
            "enc_layers": stack_template(self.enc_layer_template(), c.enc_layers),
            "dec_layers": stack_template(self.dec_layer_template(), c.num_layers),
        }

    def init_params(self, key, dtype=jnp.float32):
        return init_params(key, self.template(), dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.template(), dtype)

    def param_axes(self):
        return param_axes(self.template())

    def param_count(self) -> int:
        return count_template_params(self.template())

    # -- attention helper ----------------------------------------------------
    def _mha(self, p, hq, hkv, pos_q, pos_kv, causal, head_sharded):
        c = self.cfg
        b, sq, _ = hq.shape
        skv = hkv.shape[1]
        q = (hq @ p["wq"]).reshape(b, sq, c.num_heads, c.head_dim)
        k = (hkv @ p["wk"]).reshape(b, skv, c.num_kv_heads, c.head_dim)
        v = (hkv @ p["wv"]).reshape(b, skv, c.num_kv_heads, c.head_dim)
        if head_sharded:
            q = constrain(q, ("batch", None, "act_heads", None))
            k = constrain(k, ("batch", None, "act_heads", None))
            v = constrain(v, ("batch", None, "act_heads", None))
        if max(sq, skv) <= 1024:
            out = attn.dense_attention(q, k, v, pos_q, pos_kv, causal=causal)
        else:
            out = attn.blockwise_attention(q, k, v, pos_q, pos_kv, causal=causal)
        return out.reshape(b, sq, c.q_dim) @ p["wo"]

    # -- encode ----------------------------------------------------------------
    def encode(self, params, frames: jax.Array, *, remat: str = "full",
               head_sharded: bool = True) -> jax.Array:
        """frames [B, F, D] (precomputed frontend embeddings) -> enc out."""
        c = self.cfg
        b, f, _ = frames.shape
        pe = sinusoidal_positions(f, c.d_model).astype(frames.dtype)
        x = constrain(frames + pe[None], ("batch", None, "embed"))
        pos = jnp.broadcast_to(jnp.arange(f), (b, f))
        policy = REMAT_POLICIES["none" if remat == "none" else remat]

        def body(x, lp):
            def f_(xx):
                h = rms_norm(xx, lp["ln1"], c.norm_eps)
                xx = xx + self._mha(lp["attn"], h, h, pos, pos, False,
                                    head_sharded)
                h2 = rms_norm(xx, lp["ln2"], c.norm_eps)
                return xx + mlp_apply(lp["mlp"], h2, "gelu")
            if remat != "none":
                f_ = jax.checkpoint(f_, policy=policy)
            return f_(x), None

        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return rms_norm(x, params["enc_final_norm"], c.norm_eps)

    # -- decode (teacher forcing / prefill) ----------------------------------
    def decode_stack(self, params, tokens, enc_out, *, remat: str = "full",
                     head_sharded: bool = True) -> jax.Array:
        c = self.cfg
        b, s = tokens.shape
        f = enc_out.shape[1]
        pe = sinusoidal_positions(s, c.d_model)
        x = jnp.take(params["embed"], tokens, axis=0) + pe[None].astype(
            params["embed"].dtype)
        x = constrain(x, ("batch", None, "embed"))
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        pos_f = jnp.broadcast_to(jnp.arange(f), (b, f))
        policy = REMAT_POLICIES["none" if remat == "none" else remat]

        def body(x, lp):
            def f_(xx):
                h = rms_norm(xx, lp["ln1"], c.norm_eps)
                xx = xx + self._mha(lp["self_attn"], h, h, pos, pos, True,
                                    head_sharded)
                hx = rms_norm(xx, lp["ln_x"], c.norm_eps)
                xx = xx + self._mha(lp["cross_attn"], hx, enc_out, pos, pos_f,
                                    False, head_sharded)
                h2 = rms_norm(xx, lp["ln2"], c.norm_eps)
                return xx + mlp_apply(lp["mlp"], h2, "gelu")
            if remat != "none":
                f_ = jax.checkpoint(f_, policy=policy)
            return f_(x), None

        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        return rms_norm(x, params["dec_final_norm"], c.norm_eps)

    def forward(self, params, tokens, frames=None, *, compute_dtype=jnp.bfloat16,
                remat: str = "full", tp_size: int = 1, **_unused):
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        head_sharded = c.num_heads % max(tp_size, 1) == 0
        enc_out = self.encode(params, frames.astype(compute_dtype), remat=remat,
                              head_sharded=head_sharded)
        x = self.decode_stack(params, tokens, enc_out, remat=remat,
                              head_sharded=head_sharded)
        return x, jnp.zeros((), jnp.float32)

    def logits(self, params, hidden, compute_dtype=jnp.bfloat16):
        out = hidden @ params["embed"].astype(compute_dtype).T
        return constrain(out, ("batch", None, "vocab"))

    def loss_fn(self, params, batch, *, remat: str = "full", tp_size: int = 1,
                rngs=None):
        hidden, _ = self.forward(params, batch["tokens"], batch["frames"],
                                 remat=remat, tp_size=tp_size)
        logits = self.logits(jax.tree.map(lambda a: a, params), hidden
                             ).astype(jnp.float32)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                                   axis=-1)[..., 0]
        ntok = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum((lse - gold) * mask) / ntok
        return loss, {"loss": loss, "ntok": ntok}

    # -- serving ------------------------------------------------------------
    def kv_pool_shape(self, total_pages: int) -> Tuple[int, ...]:
        c = self.cfg
        return (c.num_layers, total_pages, self.page_size, 2, c.num_kv_heads,
                c.head_dim)

    def cross_kv_shape(self, batch: int) -> Tuple[int, ...]:
        c = self.cfg
        return (c.num_layers, batch, c.enc_frames, 2, c.num_kv_heads, c.head_dim)

    def encode_anchor(self, params, frames, *, compute_dtype=jnp.bfloat16,
                      tp_size: int = 1):
        """Ingress for the audio payload: encode once, project cross K/V per
        decoder layer, anchor [L, B, F, 2, Hkv, hd] on device."""
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        head_sharded = c.num_heads % max(tp_size, 1) == 0
        enc_out = self.encode(params, frames.astype(compute_dtype), remat="none",
                              head_sharded=head_sharded)
        b, f, _ = enc_out.shape

        def per_layer(carry, lp):
            k = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, f, c.num_kv_heads,
                                                           c.head_dim)
            v = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, f, c.num_kv_heads,
                                                           c.head_dim)
            return carry, jnp.stack([k, v], axis=2)  # [B, F, 2, Hkv, hd]

        _, cross_kv = jax.lax.scan(per_layer, 0, params["dec_layers"])
        return cross_kv

    def prefill(self, params, tokens, seq_lens, pool, tables, token_shard,
                token_slot, token_off, token_valid, frames, *, mesh: Mesh,
                batch_axis, combine_axes, compute_dtype=jnp.bfloat16,
                tp_size: int = 1, **_unused):
        """Ingress: anchor the audio payload (cross K/V) and the decoder
        prompt's self-attention KV pages; return (first_tokens, pool,
        cross_kv)."""
        c = self.cfg
        cross_kv = self.encode_anchor(params, frames,
                                      compute_dtype=compute_dtype,
                                      tp_size=tp_size)
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        head_sharded = c.num_heads % max(tp_size, 1) == 0
        b, s = tokens.shape
        f = c.enc_frames
        pe = sinusoidal_positions(s, c.d_model)
        x = jnp.take(params["embed"], tokens, axis=0) + pe[None].astype(
            params["embed"].dtype)
        x = constrain(x, ("batch", None, "embed"))
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        pos_f = jnp.broadcast_to(jnp.arange(f), (b, f))

        def body(x, xs):
            lp, pool_l, ckv_l = xs
            h = rms_norm(x, lp["ln1"], c.norm_eps)
            q = (h @ lp["self_attn"]["wq"]).reshape(b, s, c.num_heads, c.head_dim)
            k = (h @ lp["self_attn"]["wk"]).reshape(b, s, c.num_kv_heads,
                                                    c.head_dim)
            v = (h @ lp["self_attn"]["wv"]).reshape(b, s, c.num_kv_heads,
                                                    c.head_dim)
            pool_l = attn.prefill_write_pages(
                k, v, pool_l, tables, token_shard, token_slot, token_off,
                token_valid, mesh=mesh, batch_axis=batch_axis,
                combine_axes=combine_axes)
            if s <= 1024:
                out = attn.dense_attention(q, k, v, pos, pos, causal=True)
            else:
                out = attn.blockwise_attention(q, k, v, pos, pos, causal=True)
            x = x + out.reshape(b, s, c.q_dim) @ lp["self_attn"]["wo"]
            hx = rms_norm(x, lp["ln_x"], c.norm_eps)
            kk, vv = ckv_l[:, :, 0], ckv_l[:, :, 1]
            qx = (hx @ lp["cross_attn"]["wq"]).reshape(b, s, c.num_heads,
                                                       c.head_dim)
            if max(s, f) <= 1024:
                ox = attn.dense_attention(qx, kk, vv, pos, pos_f, causal=False)
            else:
                ox = attn.blockwise_attention(qx, kk, vv, pos, pos_f,
                                              causal=False)
            x = x + ox.reshape(b, s, c.q_dim) @ lp["cross_attn"]["wo"]
            h2 = rms_norm(x, lp["ln2"], c.norm_eps)
            x = x + mlp_apply(lp["mlp"], h2, "gelu")
            return x, pool_l

        x, new_pool = jax.lax.scan(body, x, (params["dec_layers"], pool,
                                             cross_kv))
        x = rms_norm(x, params["dec_final_norm"], c.norm_eps)
        idx = jnp.maximum(seq_lens - 1, 0)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self.logits(params, last, compute_dtype)[:, 0]
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return first, new_pool, cross_kv

    def decode_step(self, params, tokens, seq_lens, pool, tables, page_pos,
                    write_shard, write_slot, cross_kv, *, mesh: Mesh,
                    batch_axis, combine_axes, compute_dtype=jnp.bfloat16):
        """One decoder token: paged self-attention + anchored cross-attention.
        Returns (next_tokens [B], new self-KV pool)."""
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        b = tokens.shape[0]
        pe = sinusoidal_positions(2 ** 20, c.d_model)  # static table, sliced
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + pe[seq_lens].astype(x.dtype)

        def layer_step(x, xs):
            lp, pool_l, ckv_l = xs
            h = rms_norm(x, lp["ln1"], c.norm_eps)
            q = (h @ lp["self_attn"]["wq"]).reshape(b, c.num_heads, c.head_dim)
            k = (h @ lp["self_attn"]["wk"]).reshape(b, c.num_kv_heads, c.head_dim)
            v = (h @ lp["self_attn"]["wv"]).reshape(b, c.num_kv_heads, c.head_dim)
            out, pool_l = attn.paged_decode_attention(
                q, k, v, pool_l, tables, page_pos, seq_lens, write_shard,
                write_slot, mesh=mesh, batch_axis=batch_axis,
                combine_axes=combine_axes)
            x = x + out.reshape(b, c.q_dim) @ lp["self_attn"]["wo"]
            # cross-attention over the anchored encoder payload (in place)
            hx = rms_norm(x, lp["ln_x"], c.norm_eps)
            qx = (hx @ lp["cross_attn"]["wq"]).reshape(b, 1, c.num_heads,
                                                       c.head_dim)
            kk, vv = ckv_l[:, :, 0], ckv_l[:, :, 1]
            f = kk.shape[1]
            pos_f = jnp.broadcast_to(jnp.arange(f), (b, f))
            ox = attn.dense_attention(qx, kk, vv, seq_lens[:, None], pos_f,
                                      causal=False)[:, 0]
            x = x + ox.reshape(b, c.q_dim) @ lp["cross_attn"]["wo"]
            h2 = rms_norm(x, lp["ln2"], c.norm_eps)
            x = x + mlp_apply(lp["mlp"], h2, "gelu")
            return x, pool_l

        x, new_pool = jax.lax.scan(layer_step, x,
                                   (params["dec_layers"], pool, cross_kv))
        x = rms_norm(x, params["dec_final_norm"], c.norm_eps)
        logits = self.logits(params, x[:, None])[:, 0]
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_pool
