"""Shared model primitives: param templates, norms, RoPE, MLPs.

A ``ParamSpec`` template is the single source of truth per architecture:
``init_params`` (real arrays, smoke tests), ``abstract_params``
(ShapeDtypeStruct, dry-run — never allocates) and ``param_axes`` (logical
sharding names) are all derived from it, so they cannot diverge.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any  # pytree of arrays


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0    # stddev multiplier for 'normal' (fan-in scaled)
    fan_in_dims: Tuple[int, ...] = ()  # dims whose product is fan-in; () -> second-to-last

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Template = Dict[str, Any]  # nested dict of ParamSpec


def _fan_in(spec: ParamSpec) -> int:
    if spec.init != "normal":
        return 1
    if spec.fan_in_dims:
        f = 1
        for d in spec.fan_in_dims:
            f *= spec.shape[d]
        return f
    if len(spec.shape) >= 2:
        return spec.shape[-2]
    return spec.shape[-1]


def init_params(key: jax.Array, template: Template, dtype=jnp.float32) -> Params:
    leaves, treedef = jax.tree.flatten(
        template, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, spec in zip(keys, leaves):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            std = spec.scale / math.sqrt(_fan_in(spec))
            out.append((jax.random.normal(k, spec.shape) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(template: Template, dtype=jnp.float32) -> Params:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        template,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def param_axes(template: Template) -> Params:
    return jax.tree.map(
        lambda s: s.axes, template, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def count_template_params(template: Template) -> int:
    leaves = jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def group_norm(x: jax.Array, w: jax.Array, num_groups: int, eps: float = 1e-5) -> jax.Array:
    """Per-head group norm used by xLSTM cells. x [..., H*dh] grouped by H."""
    dt = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x.reshape(*lead, d) * w.astype(jnp.float32)).astype(dt)


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., seq, heads, head_dim]; positions broadcastable to [..., seq]."""
    dt = x.dtype
    freqs = rope_freqs(x.shape[-1], theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(ang)[..., None, :]  # [..., seq, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


def sinusoidal_positions(length: int, d_model: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d_model, 2, dtype=jnp.float32) * (-math.log(10000.0) / d_model))
    pe = jnp.zeros((length, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# MLP blocks
# ---------------------------------------------------------------------------

def mlp_template(d_model: int, d_ff: int, act: str) -> Template:
    if act == "swiglu":
        return {
            "w_gate": ParamSpec((d_model, d_ff), ("fsdp", "tensor")),
            "w_up": ParamSpec((d_model, d_ff), ("fsdp", "tensor")),
            "w_down": ParamSpec((d_ff, d_model), ("tensor", "fsdp")),
        }
    return {
        "w_up": ParamSpec((d_model, d_ff), ("fsdp", "tensor")),
        "b_up": ParamSpec((d_ff,), ("tensor",), init="zeros"),
        "w_down": ParamSpec((d_ff, d_model), ("tensor", "fsdp")),
        "b_down": ParamSpec((d_model,), (None,), init="zeros"),
    }


def mlp_apply(p: Params, x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        return h @ p["w_down"]
    h = gelu(x @ p["w_up"] + p["b_up"])
    return h @ p["w_down"] + p["b_down"]


def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x [B, S, C], w [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is tiny (4); unrolled adds are cheap and fusible
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    if b is not None:
        out = out + b
    return out


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Single decode step of the causal conv. conv_state [B, K-1, C]."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        out = out + b
    return out, window[:, 1:, :]
