"""Attention paths.

Three implementations, chosen by shape/mesh (see repro.distributed.sharding):

* ``dense_attention`` — reference/small-shape path (heads sharded over
  'model' when divisible).
* ``blockwise_attention`` — memory-efficient online-softmax scan over KV
  blocks; used for long prefill / training where the sequence dimension is
  sharded ('model' sequence parallelism). Works for any head count.
* ``paged_decode_attention`` — the Libra fast path: anchored KV pages are
  read in place via block-table metadata; each chip attends over the pages
  it owns and partial softmax statistics are combined across the combine
  axes (flash-decode). Implemented with shard_map; the Pallas kernel in
  repro.kernels.paged_attention computes the same per-chip partials on TPU.

Mechanism/policy split (the paper's core design): the device functions here
are pure *mechanisms* — every placement decision (which page, which shard,
which slot/offset, each page's base position) arrives as explicit int32
metadata from the control plane, exactly as Libra's eBPF programs feed the
kernel data plane. This also makes ring-buffer (sliding-window) pages free:
the engine just reuses slots and updates ``page_pos``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.common.sharding import shard_map

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B, Sq, Hkv, G, hd], k [B, Skv, Hkv, hd] -> [B, Hkv, G, Sq, Skv]."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)


def _mask_bias(pos_q, pos_kv, causal: bool, window) -> jax.Array:
    """pos_q [B, Sq], pos_kv [B, Skv] -> additive bias [B, 1, 1, Sq, Skv].

    ``window`` may be a traced scalar (<=0 means no windowing) so that a
    per-layer window array can ride through lax.scan.
    """
    dq = pos_q[:, :, None]
    dk = pos_kv[:, None, :]
    ok = jnp.ones((dq.shape[0], dq.shape[1], dk.shape[2]), bool)
    if causal:
        ok = ok & (dq >= dk)
    window = jnp.asarray(window)
    ok = ok & ((window <= 0) | (dq - dk < window))
    return jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]


def dense_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos_q: jax.Array,
    pos_kv: jax.Array,
    *,
    causal: bool = True,
    window=0,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference attention. q [B,Sq,Hq,hd], k/v [B,Skv,Hkv,hd]."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = (q * (1.0 / math.sqrt(hd))).reshape(b, sq, hkv, g, hd)
    scores = _gqa_scores(qg, k)  # [B,Hkv,G,Sq,Skv]
    scores = scores + _mask_bias(pos_q, pos_kv, causal, window)
    if kv_valid is not None:
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, hd)


def _block_pairs(nq: int, nkv: int, causal: bool, window_blocks: int):
    """Statically enumerate the (q_chunk, kv_block) pairs that can contain
    unmasked entries. This is how the implementation keeps HLO FLOPs equal
    to the *useful* attention FLOPs: masked-out blocks are never emitted,
    so causal attention costs exactly n(n+1)/2 block matmuls and windowed
    attention only its band — no 2x rectangle waste in the roofline."""
    pairs = []
    for i in range(nq):
        for j in range(nkv):
            if causal and j > i:
                continue
            if window_blocks > 0 and j < i - window_blocks:
                continue
            pairs.append((i, j))
    return pairs


def blockwise_attention(q, k, v, pos_q, pos_kv, *, causal=True, window=0,
                        q_chunk=512, kv_chunk=512):
    """Keyword-friendly wrapper over the custom-VJP implementation."""
    return _blockwise_cv(q, k, v, pos_q, pos_kv, causal, int(window),
                         q_chunk, kv_chunk)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _blockwise_cv(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos_q: jax.Array,
    pos_kv: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jax.Array:
    """Flash-structured online-softmax attention over (q_chunk × kv_block)
    tiles (never materialises [Sq, Skv]).

    A single lax.scan runs over the statically-enumerated valid tile list;
    per step it updates the running (m, l, acc) slice of its q chunk. With
    the q sequence dim sharded over 'model' this is sequence-parallel
    attention with no head-count divisibility requirement. ``window`` must
    be a Python int here (block enumeration is static); per-layer windows
    are handled by the caller grouping layers.

    The backward pass is a custom VJP that RECOMPUTES each tile's scores
    from (q, k, lse) — flash-attention backward. Without it, autodiff
    stashes every tile's score matrix ([n_pairs, B, H, Sq/c, c] — 1.2 GB
    per layer for phi3@4k) and that stash dominated the training-memory
    roofline term (EXPERIMENTS §Perf hillclimb).
    """
    out, _lse = _blockwise_fwd_impl(q, k, v, pos_q, pos_kv, causal, window,
                                    q_chunk, kv_chunk)
    return out


def _blockwise_fwd_impl(q, k, v, pos_q, pos_kv, causal, window, q_chunk,
                        kv_chunk):
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nkv = -(-sq // q_chunk), -(-skv // kv_chunk)
    pad_q = nq * q_chunk - sq
    pad_kv = nkv * kv_chunk - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        pos_q = jnp.pad(pos_q, ((0, 0), (0, pad_q)), constant_values=-(2**30))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        pos_kv = jnp.pad(pos_kv, ((0, 0), (0, pad_kv)), constant_values=2**30)
    sq_p, skv_p = nq * q_chunk, nkv * kv_chunk
    qg = (q * (1.0 / math.sqrt(hd))).reshape(b, sq_p, hkv, g, hd)

    wblocks = -(-window // kv_chunk) + 1 if window > 0 else 0
    pairs = _block_pairs(nq, nkv, causal, wblocks)
    pair_arr = jnp.array(pairs, jnp.int32)  # [n_pairs, 2]

    def body(carry, pair):
        m, l, acc = carry  # [B,H,G,Sq], [B,H,G,Sq], [B,H,G,Sq,hd]
        i, j = pair[0], pair[1]
        qb = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
        pq = jax.lax.dynamic_slice_in_dim(pos_q, i * q_chunk, q_chunk, 1)
        kb = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, 1)
        pb = jax.lax.dynamic_slice_in_dim(pos_kv, j * kv_chunk, kv_chunk, 1)
        s = _gqa_scores(qb, kb) + _mask_bias(pq, pb, causal, window)  # [B,H,G,cq,ck]
        m_i = jax.lax.dynamic_slice_in_dim(m, i * q_chunk, q_chunk, 3)
        l_i = jax.lax.dynamic_slice_in_dim(l, i * q_chunk, q_chunk, 3)
        a_i = jax.lax.dynamic_slice_in_dim(acc, i * q_chunk, q_chunk, 3)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        a_new = a_i * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * q_chunk, 3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * q_chunk, 3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * q_chunk, 3)
        return (m, l, acc), None

    m0 = jnp.full((b, hkv, g, sq_p), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq_p), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq_p, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), pair_arr)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq_p, hq, hd)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # [B,Hkv,G,Sq_p]
    return out[:, :sq].astype(q.dtype), lse


def _blockwise_fwd(q, k, v, pos_q, pos_kv, causal, window, q_chunk, kv_chunk):
    out, lse = _blockwise_fwd_impl(q, k, v, pos_q, pos_kv, causal, window,
                                   q_chunk, kv_chunk)
    return out, (q, k, v, pos_q, pos_kv, out, lse)


def _blockwise_bwd(causal, window, q_chunk, kv_chunk, res, dout):
    """Flash backward: recompute tile scores from (q, k, lse); accumulate
    dq/dk/dv per tile. Nothing tile-sized is ever saved."""
    q, k, v, pos_q, pos_kv, out, lse = res
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nkv = -(-sq // q_chunk), -(-skv // kv_chunk)
    pad_q = nq * q_chunk - sq
    pad_kv = nkv * kv_chunk - skv
    scale = 1.0 / math.sqrt(hd)

    def padq(x, fill=0.0):
        return jnp.pad(x, ((0, 0), (0, pad_q)) + ((0, 0),) * (x.ndim - 2),
                       constant_values=fill) if pad_q else x

    def padkv(x, fill=0.0):
        return jnp.pad(x, ((0, 0), (0, pad_kv)) + ((0, 0),) * (x.ndim - 2),
                       constant_values=fill) if pad_kv else x

    qp = padq(q)
    kp, vp = padkv(k), padkv(v)
    pos_qp = padq(pos_q, -(2 ** 30))
    pos_kvp = padkv(pos_kv, 2 ** 30)
    doutp = padq(dout)
    outp = padq(out)
    sq_p, skv_p = nq * q_chunk, nkv * kv_chunk

    qg = (qp * scale).reshape(b, sq_p, hkv, g, hd)
    dog = doutp.reshape(b, sq_p, hkv, g, hd)
    og = outp.reshape(b, sq_p, hkv, g, hd)
    # D_i = rowsum(dout * out)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)
    delta = delta.transpose(0, 2, 3, 1)  # [B,Hkv,G,Sq]

    wblocks = -(-window // kv_chunk) + 1 if window > 0 else 0
    pair_arr = jnp.array(_block_pairs(nq, nkv, causal, wblocks), jnp.int32)

    def body(carry, pair):
        dq, dk, dv = carry
        i, j = pair[0], pair[1]
        qb = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
        pq = jax.lax.dynamic_slice_in_dim(pos_qp, i * q_chunk, q_chunk, 1)
        dob = jax.lax.dynamic_slice_in_dim(dog, i * q_chunk, q_chunk, 1)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * q_chunk, q_chunk, 3)
        dl_i = jax.lax.dynamic_slice_in_dim(delta, i * q_chunk, q_chunk, 3)
        kb = jax.lax.dynamic_slice_in_dim(kp, j * kv_chunk, kv_chunk, 1)
        vb = jax.lax.dynamic_slice_in_dim(vp, j * kv_chunk, kv_chunk, 1)
        pb = jax.lax.dynamic_slice_in_dim(pos_kvp, j * kv_chunk, kv_chunk, 1)
        s = _gqa_scores(qb, kb) + _mask_bias(pq, pb, causal, window)
        p = jnp.exp(s - lse_i[..., None])                       # [B,H,G,cq,ck]
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dob.astype(jnp.float32),
                        vb.astype(jnp.float32))
        ds = p * (dp - dl_i[..., None])                         # f32
        dqb = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb.astype(jnp.float32)) * scale
        dkb = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qb.astype(jnp.float32))
        dvb = jnp.einsum("bhgqk,bqhgd->bkhd", p, dob.astype(jnp.float32))
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * q_chunk, q_chunk, 1)
            + dqb.reshape(b, q_chunk, hq, hd), i * q_chunk, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * kv_chunk, kv_chunk, 1)
            + dkb, j * kv_chunk, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * kv_chunk, kv_chunk, 1)
            + dvb, j * kv_chunk, 1)
        return (dq, dk, dv), None

    dq0 = jnp.zeros((b, sq_p, hq, hd), jnp.float32)
    dk0 = jnp.zeros((b, skv_p, hkv, hd), jnp.float32)
    dv0 = jnp.zeros((b, skv_p, hkv, hd), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), pair_arr)
    return (dq[:, :sq].astype(q.dtype), dk[:, :skv].astype(k.dtype),
            dv[:, :skv].astype(v.dtype), None, None)


_blockwise_cv.defvjp(_blockwise_fwd, _blockwise_bwd)


# ---------------------------------------------------------------------------
# Libra fast path: paged decode attention over anchored pages
# ---------------------------------------------------------------------------

def plan_decode_sharding(global_batch: int, mesh: Mesh) -> Tuple[Optional[object], Tuple[str, ...]]:
    """Decide batch sharding axis + softmax combine axes for decode.

    Requests are sharded over the data axes when divisible; each request's
    pages stripe over the remaining (combine) axes and partial softmax
    stats are psum-combined — flash-decode. Tiny batches (long_500k)
    replicate the batch and stripe pages over every axis.
    """
    sizes = dict(mesh.shape)
    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    dsize = math.prod([sizes[a] for a in data_axes]) if data_axes else 1
    if data_axes and global_batch % dsize == 0:
        return (data_axes if len(data_axes) > 1 else data_axes[0],
                ("model",) if "model" in sizes else ())
    return None, tuple(mesh.axis_names)


def num_combine_shards(mesh: Mesh, combine_axes: Tuple[str, ...]) -> int:
    sizes = dict(mesh.shape)
    return math.prod([sizes[a] for a in combine_axes]) if combine_axes else 1


def _combined_axis_index(axes: Tuple[str, ...]):
    if not axes:
        return 0
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def paged_decode_attention(
    q: jax.Array,            # [B, Hq, hd]
    k_new: jax.Array,        # [B, Hkv, hd] current token's K
    v_new: jax.Array,        # [B, Hkv, hd]
    pool: jax.Array,         # [P, page, 2, Hkv, hd] anchored pages (sharded on P)
    block_tables: jax.Array, # [B, n_shards, pages_per_shard] local page ids, -1 invalid
    page_pos: jax.Array,     # [B, n_shards, pages_per_shard] base position of each page
    seq_lens: jax.Array,     # [B] position of the incoming token (0-indexed)
    write_shard: jax.Array,  # [B] shard owning the incoming token's page
    write_slot: jax.Array,   # [B] table slot of that page
    *,
    mesh: Mesh,
    batch_axis,
    combine_axes: Tuple[str, ...],
    window=0,
) -> Tuple[jax.Array, jax.Array]:
    """Write the new token's KV into its anchored page, then attend over all
    anchored pages in place. Returns (attn_out [B,Hq,hd], updated pool).

    All placement metadata is control-plane supplied (Libra's mechanism /
    policy split); windowed layers just get ring-buffer tables + page_pos.
    """
    page_size = pool.shape[1]
    bspec = P(batch_axis)
    pool_spec = P(tuple(mesh.axis_names))

    def local(q, k_new, v_new, pool, tables, page_pos, seq_lens, wshard, wslot):
        midx = _combined_axis_index(combine_axes)
        b, hq, hd = q.shape
        hkv = k_new.shape[1]
        g = hq // hkv
        pps = tables.shape[2]

        # ---- write the incoming token's KV into its page (owner only) ----
        owner_rows = tables[jnp.arange(b), wshard]           # [B, pps]
        local_pid = jnp.take_along_axis(owner_rows, wslot[:, None], axis=1)[:, 0]
        pos_rows = page_pos[jnp.arange(b), wshard]
        base = jnp.take_along_axis(pos_rows, wslot[:, None], axis=1)[:, 0]
        off = seq_lens - base
        ok = (wshard == midx) & (local_pid >= 0) & (off >= 0) & (off < page_size)
        write_pid = jnp.where(ok, local_pid, pool.shape[0])
        kv_stack = jnp.stack([k_new, v_new], axis=1)          # [B, 2, Hkv, hd]
        pool = pool.at[write_pid, jnp.clip(off, 0, page_size - 1)].set(
            kv_stack.astype(pool.dtype), mode="drop")

        # ---- attend over locally-owned pages ----
        tbl = tables[:, midx, :]                              # [B, pps]
        ppos = page_pos[:, midx, :]                           # [B, pps]
        pages = pool[jnp.clip(tbl, 0)]                        # [B, pps, page, 2, Hkv, hd]
        kk = pages[:, :, :, 0].reshape(b, pps * page_size, hkv, hd)
        vv = pages[:, :, :, 1].reshape(b, pps * page_size, hkv, hd)
        pos = ppos[:, :, None] + jnp.arange(page_size)[None, None, :]
        w = jnp.asarray(window)
        valid = (tbl[:, :, None] >= 0) & (pos <= seq_lens[:, None, None])
        valid = valid & ((w <= 0) | (seq_lens[:, None, None] - pos < w))
        valid = valid.reshape(b, pps * page_size)

        # keep both einsum inputs in the pool dtype: mixed-precision inputs
        # make XLA pre-convert the WHOLE pool to f32 (8+ GB of traffic at
        # production scale); bf16 x bf16 -> f32 accumulate is MXU-native.
        qg = (q * (1.0 / math.sqrt(hd))).reshape(b, hkv, g, hd).astype(kk.dtype)
        s = jnp.einsum("bhgd,bthd->bhgt", qg, kk, preferred_element_type=jnp.float32)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_p = jnp.max(s, axis=-1)                             # [B,Hkv,G]
        p = jnp.exp(s - m_p[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_p = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bhgt,bthd->bhgd", p.astype(vv.dtype), vv).astype(jnp.float32)

        # ---- combine partial softmax stats across combine axes ----
        if combine_axes:
            m_g = jax.lax.pmax(m_p, combine_axes)
            scale = jnp.exp(m_p - m_g)
            l_g = jax.lax.psum(l_p * scale, combine_axes)
            acc_g = jax.lax.psum(acc * scale[..., None], combine_axes)
        else:
            l_g, acc_g = l_p, acc
        out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
        return out.reshape(b, hq, hd).astype(q.dtype), pool

    shard = shard_map(
        local,
        mesh=mesh,
        in_specs=(bspec, bspec, bspec, pool_spec, bspec, bspec, bspec, bspec, bspec),
        out_specs=(bspec, pool_spec),
        check_vma=False,
    )
    return shard(q, k_new, v_new, pool, block_tables, page_pos, seq_lens,
                 write_shard, write_slot)


def prefill_write_pages(
    k: jax.Array,            # [B, S, Hkv, hd]
    v: jax.Array,
    pool: jax.Array,         # [P, page, 2, Hkv, hd]
    block_tables: jax.Array, # [B, n_shards, pages_per_shard]
    token_shard: jax.Array,  # [B, S] owner shard per token
    token_slot: jax.Array,   # [B, S] table slot per token
    token_off: jax.Array,    # [B, S] in-page offset per token
    token_valid: jax.Array,  # [B, S] bool
    *,
    mesh: Mesh,
    batch_axis,
    combine_axes: Tuple[str, ...],
) -> jax.Array:
    """Anchor a full prompt's KV into pages (ingress path). Each chip writes
    only the pages it owns — no cross-chip payload movement."""
    page_size = pool.shape[1]
    bspec = P(batch_axis)
    pool_spec = P(tuple(mesh.axis_names))

    def local(k, v, pool, tables, tsh, tsl, toff, tval):
        midx = _combined_axis_index(combine_axes)
        b, s, hkv, hd = k.shape
        pid = jnp.take_along_axis(
            tables[jnp.arange(b)[:, None], tsh], tsl[..., None], axis=2
        )[..., 0]                                              # [B, S]
        mine = (tsh == midx) & tval & (pid >= 0)
        write_pid = jnp.where(mine, pid, pool.shape[0])
        kv = jnp.stack([k, v], axis=2).astype(pool.dtype)      # [B, S, 2, Hkv, hd]
        pool = pool.at[write_pid.reshape(-1), toff.reshape(-1)].set(
            kv.reshape(b * s, 2, hkv, hd), mode="drop")
        return pool

    shard = shard_map(
        local,
        mesh=mesh,
        in_specs=(bspec, bspec, pool_spec, bspec, bspec, bspec, bspec, bspec),
        out_specs=pool_spec,
        check_vma=False,
    )
    return shard(k, v, pool, block_tables, token_shard, token_slot, token_off,
                 token_valid)
