"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation: instead of a one-hot dispatch einsum (O(T·E·C·D) FLOPs) or a
megablocks-style CUDA grouped GEMM, tokens are sorted by expert id and
gathered into a capacity-bounded [E, C, D] buffer (sharded expert→'model',
EP). The per-expert FFN is a single batched einsum that the MXU executes at
full tilt; combine is a scatter-add weighted by the router gates. Dropped
tokens (capacity overflow) pass through the residual, standard for
capacity-based MoE.

Router top-k metadata is exactly the paper's "header" traffic: a few int32s
per token steering where the bulk activation payload is processed.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.sharding import constrain, shard_map
from repro.common.types import ModelConfig
from repro.models.layers import ParamSpec


def moe_template(cfg: ModelConfig) -> Dict:
    e = cfg.padded_experts
    t: Dict = {
        "router": ParamSpec((cfg.d_model, e), (None, None)),  # tiny; replicated
        "w_gate": ParamSpec((e, cfg.d_model, cfg.expert_d_ff), ("expert", "fsdp", "tensor")),
        "w_up": ParamSpec((e, cfg.d_model, cfg.expert_d_ff), ("expert", "fsdp", "tensor")),
        "w_down": ParamSpec((e, cfg.expert_d_ff, cfg.d_model), ("expert", "tensor", "fsdp")),
    }
    if cfg.num_shared_experts:
        sf = cfg.num_shared_experts * cfg.expert_d_ff
        t["shared_gate"] = ParamSpec((cfg.d_model, sf), ("fsdp", "tensor"))
        t["shared_up"] = ParamSpec((cfg.d_model, sf), ("fsdp", "tensor"))
        t["shared_down"] = ParamSpec((sf, cfg.d_model), ("tensor", "fsdp"))
        t["shared_gate_proj"] = ParamSpec((cfg.d_model, 1), ("fsdp", None))
    return t


def router_topk(
    logits: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """[T, E_padded] -> (gates [T,k], expert_ids [T,k]). Padded experts are
    masked out before top-k so they can never be selected."""
    if cfg.padded_experts > cfg.num_experts:
        pad_mask = jnp.arange(cfg.padded_experts) >= cfg.num_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids


def aux_load_balance_loss(logits: jax.Array, ids: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    e = cfg.num_experts
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)[..., :e]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(ids, cfg.padded_experts, dtype=jnp.float32)[..., :e]
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0) / cfg.top_k
    return e * jnp.sum(me * ce)


def _dispatch_compute_combine(x, p_gate, p_up, p_down, gates, ids, cfg,
                              capacity_factor: float, e_first: int, e_count: int):
    """Sort-based dispatch for experts [e_first, e_first+e_count) over local
    tokens x [T, D]; returns the weighted combined output [T, D]."""
    t, d = x.shape
    k = cfg.top_k
    e_total = cfg.padded_experts
    cap = max(8, int(math.ceil(t * k / e_total * capacity_factor)))
    cap = min(cap, t)

    flat_e = ids.reshape(-1)                       # [T*k] global expert ids
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    first = jnp.searchsorted(se, jnp.arange(e_total), side="left")
    pos = jnp.arange(t * k) - first[se]            # position within expert group
    token_idx = order // k
    local_e = se - e_first
    mine = (local_e >= 0) & (local_e < e_count) & (pos < cap)
    dst = jnp.where(mine, local_e * cap + pos, e_count * cap)  # OOB -> dropped

    buf = jnp.zeros((e_count * cap, d), x.dtype)
    buf = buf.at[dst].set(x[token_idx], mode="drop")
    buf = buf.reshape(e_count, cap, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, p_up)
    h = jax.nn.silu(h) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, p_down).reshape(e_count * cap, d)

    contrib = jnp.where(mine[:, None],
                        out_e[jnp.clip(dst, 0, e_count * cap - 1)], 0.0)
    gate_per = gates.reshape(-1)[order][:, None].astype(x.dtype)
    return jnp.zeros((t, d), x.dtype).at[token_idx].add(contrib * gate_per)


def _shared_expert(p, x):
    sh = jax.nn.silu(x @ p["shared_gate"]) * (x @ p["shared_up"])
    sg = jax.nn.sigmoid(x @ p["shared_gate_proj"])
    return sg * (sh @ p["shared_down"])


def moe_ffn(
    p: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    capacity_factor: float = 1.25,
    return_aux: bool = False,
):
    """x [T, D] -> [T, D].

    Expert-parallel path (shard_map): activations are batch-sharded over the
    data axes and replicated over 'model'; experts are sharded over 'model'.
    Dispatch to the local experts is therefore a LOCAL gather (zero
    communication — the Libra selective-copy idea applied to MoE routing:
    the router's top-k ids are the metadata; token payloads never move), and
    the combine is one psum over 'model', the same collective a dense TP FFN
    pays. FSDP weight shards are all-gathered over 'data' per layer.
    """
    from repro.common.sharding import _current_mesh

    mesh = _current_mesh()
    e = cfg.padded_experts
    use_ep = (
        mesh is not None
        and "model" in mesh.axis_names
        and dict(mesh.shape)["model"] > 1
        and e % dict(mesh.shape)["model"] == 0
    )

    if not use_ep:
        logits = x @ p["router"]
        gates, ids = router_topk(logits, cfg)
        out = _dispatch_compute_combine(x, p["w_gate"], p["w_up"], p["w_down"],
                                        gates, ids, cfg, capacity_factor, 0, e)
        if cfg.num_shared_experts:
            out = out + _shared_expert(p, x)
        if return_aux:
            return out, aux_load_balance_loss(logits, ids, cfg)
        return out

    sizes = dict(mesh.shape)
    m_size = sizes["model"]
    e_local = e // m_size
    data_axes = tuple(a for a in ("pod", "data") if a in sizes)
    t = x.shape[0]
    dshard = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    x_spec = P(dshard) if (dshard and t % math.prod(
        [sizes[a] for a in (data_axes or ())]) == 0) else P(None)
    # weight specs mirror the declared param sharding (expert->model, fsdp->data)
    w_spec = P("model", "data" if "data" in sizes else None, None)
    wd_spec = P("model", None, "data" if "data" in sizes else None)

    def body(x, router, wg, wu, wd, shared):
        if "data" in sizes:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        logits = x @ router
        gates, ids = router_topk(logits, cfg)
        e_first = jax.lax.axis_index("model") * e_local
        partial = _dispatch_compute_combine(x, wg, wu, wd, gates, ids, cfg,
                                            capacity_factor, e_first, e_local)
        aux = aux_load_balance_loss(logits, ids, cfg)
        if shared is not None:
            # shared-expert FFN is TP-sharded over 'model' (sf dim): its
            # contribution is partial over 'model' too — fold into one psum.
            sg, su, sd, sgp = shared
            if "data" in sizes:
                sg = jax.lax.all_gather(sg, "data", axis=0, tiled=True)
                su = jax.lax.all_gather(su, "data", axis=0, tiled=True)
                sd = jax.lax.all_gather(sd, "data", axis=1, tiled=True)
            sh = jax.nn.silu(x @ sg) * (x @ su)
            gate = jax.nn.sigmoid(x @ sgp)
            partial = partial + gate * (sh @ sd)
        # combine in bf16: halves the dominant collective (hillclimb #3;
        # same as the TP-reduce precision production frameworks use)
        out = jax.lax.psum(partial.astype(x.dtype), "model")
        return out, aux

    shared = None
    shared_specs = None
    if cfg.num_shared_experts:
        sf_spec = P("data" if "data" in sizes else None, "model")
        shared = (p["shared_gate"], p["shared_up"], p["shared_down"],
                  p["shared_gate_proj"])
        shared_specs = (sf_spec, sf_spec, P("model", "data" if "data" in sizes
                                            else None), P(None, None))

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, wd_spec, shared_specs),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)

    if return_aux:
        return out, aux
    return out
