"""Model registry: config -> model instance, plus the dry-run input contract.

``input_specs(model, shape, mesh)`` returns ShapeDtypeStruct stand-ins for
every input of the step function a cell lowers — weak-type-correct,
shardable, zero allocation (the multi-pod dry-run requirement).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import MeshSpec, ModelConfig, ShapeSpec

PAGE_SIZE = 64  # tokens per anchored KV page (A.5 granularity matching)


def build_model(cfg: ModelConfig, page_size: int = PAGE_SIZE):
    if cfg.family == "ssm":
        from repro.models.xlstm_model import XLSTMModel

        return XLSTMModel(cfg, page_size)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecModel

        return EncDecModel(cfg, page_size)
    from repro.models.transformer import TransformerLM

    return TransformerLM(cfg, page_size)


def count_params_from_config(cfg: ModelConfig, active_only: bool = False) -> int:
    model = build_model(cfg)
    total = model.param_count()
    if active_only and cfg.family == "moe":
        per_expert = 3 * cfg.d_model * cfg.expert_d_ff
        inactive = (cfg.padded_experts - cfg.top_k) * per_expert * cfg.num_layers
        total -= inactive
    return total


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def decode_layout(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshSpec,
                  page_size: int = PAGE_SIZE) -> Dict[str, int]:
    """Static paged-pool geometry for a decode cell."""
    data = mesh.axis_size("pod") * mesh.axis_size("data")
    model_ax = mesh.axis_size("model")
    if shape.global_batch % max(data, 1) == 0 and data > 1:
        n_shards = model_ax
    else:
        n_shards = mesh.num_devices
    pages_per_seq = -(-shape.seq_len // page_size) + 1  # +1 for the new token
    pps = -(-pages_per_seq // n_shards)
    total_pages = shape.global_batch * n_shards * pps
    # round up so every chip gets an equal slice
    total_pages = -(-total_pages // mesh.num_devices) * mesh.num_devices
    return {"n_shards": n_shards, "pps": pps, "total_pages": total_pages,
            "page_size": page_size}


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshSpec,
                page_size: int = PAGE_SIZE) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the step function of (cfg, shape)."""
    b, s = shape.global_batch, shape.seq_len
    i32, f32, bf16 = jnp.int32, jnp.float32, jnp.bfloat16

    if shape.kind == "train":
        if cfg.family == "vlm":
            st = s - cfg.img_tokens
            return {"tokens": _sds((b, st), i32), "labels": _sds((b, st), i32),
                    "img_embeds": _sds((b, cfg.img_tokens, cfg.d_model), bf16)}
        if cfg.family == "encdec":
            return {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32),
                    "frames": _sds((b, cfg.enc_frames, cfg.d_model), bf16)}
        return {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32)}

    model = build_model(cfg, page_size)

    if cfg.family == "ssm":
        state = {k: _sds(v, f32)
                 for k, v in model.decode_state_shapes(b).items()}
        if shape.kind == "prefill":
            return {"tokens": _sds((b, s), i32), "seq_lens": _sds((b,), i32)}
        return {"tokens": _sds((b,), i32), "seq_lens": _sds((b,), i32),
                "state": state}

    lay = decode_layout(cfg, shape, mesh, page_size)
    nsh, pps, total = lay["n_shards"], lay["pps"], lay["total_pages"]
    pool = _sds(model.kv_pool_shape(total), bf16)
    tables = _sds((b, nsh, pps), i32)

    if shape.kind == "prefill":
        specs = {
            "tokens": _sds((b, s if cfg.family != "vlm" else s - cfg.img_tokens), i32),
            "seq_lens": _sds((b,), i32),
            "pool": pool,
            "tables": tables,
            "token_shard": _sds((b, s), i32),
            "token_slot": _sds((b, s), i32),
            "token_off": _sds((b, s), i32),
            "token_valid": _sds((b, s), jnp.bool_),
        }
        if cfg.family == "vlm":
            specs["img_embeds"] = _sds((b, cfg.img_tokens, cfg.d_model), bf16)
        if cfg.family == "encdec":
            specs["frames"] = _sds((b, cfg.enc_frames, cfg.d_model), bf16)
        return specs

    # decode
    specs = {
        "tokens": _sds((b,), i32),
        "seq_lens": _sds((b,), i32),
        "pool": pool,
        "tables": tables,
        "page_pos": _sds((b, nsh, pps), i32),
        "write_shard": _sds((b,), i32),
        "write_slot": _sds((b,), i32),
    }
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        specs["ssm_state"] = {
            "ssm": _sds((cfg.num_layers, b, d_inner, cfg.ssm_state), f32),
            "conv": _sds((cfg.num_layers, b, cfg.ssm_conv - 1, d_inner), f32),
        }
    if cfg.family == "encdec":
        specs["cross_kv"] = _sds(model.cross_kv_shape(b), bf16)
    return specs
