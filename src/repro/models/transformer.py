"""Unified decoder-only LM: dense / MoE / hybrid(attn+SSM) / VLM families.

One implementation serves phi3, phi4, minicpm, mistral-nemo, hymba, qwen3-moe,
qwen2-moe, internvl2 and the libra-proxy model. Layers are scanned
(``lax.scan``) in homogeneous *groups* (hymba's per-layer attention windows
split the scan into segments) so 80-layer × 512-device dry-runs compile in
seconds. Per-layer remat policy is configurable.

Serving follows the Libra datapath: ``prefill`` anchors KV into pool pages
in place (ingress), ``decode_step`` reads them via block-table metadata and
returns *only sampled token ids* to the host (selective copy). The
contiguous-KV baseline (``decode_step_dense``) implements the standard-stack
comparison: it re-gathers the full KV every step and ships full logits.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.common.sharding import constrain
from repro.common.types import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    ParamSpec,
    abstract_params,
    apply_rope,
    count_template_params,
    init_params,
    mlp_apply,
    mlp_template,
    param_axes,
    rms_norm,
)

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def stack_template(tmpl: Dict, n: int) -> Dict:
    """Prepend a scanned 'layers' dim to every ParamSpec in a template."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale,
                            tuple(d + 1 for d in s.fan_in_dims)),
        tmpl,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    start: int
    end: int
    window: int  # 0 = global attention


def layer_groups(cfg: ModelConfig) -> List[LayerGroup]:
    if cfg.family != "hybrid" or not cfg.global_attn_layers:
        return [LayerGroup(0, cfg.num_layers, cfg.window if cfg.family == "hybrid" else 0)]
    groups: List[LayerGroup] = []
    cur = 0
    for g in sorted(cfg.global_attn_layers):
        if g > cur:
            groups.append(LayerGroup(cur, g, cfg.window))
        groups.append(LayerGroup(g, g + 1, 0))
        cur = g + 1
    if cur < cfg.num_layers:
        groups.append(LayerGroup(cur, cfg.num_layers, cfg.window))
    return groups


class TransformerLM:
    def __init__(self, cfg: ModelConfig, page_size: int = 64):
        self.cfg = cfg
        self.page_size = page_size

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def layer_template(self) -> Dict:
        c = self.cfg
        t: Dict[str, Any] = {
            "ln1": ParamSpec((c.d_model,), (None,), init="zeros"),
            "wq": ParamSpec((c.d_model, c.q_dim), ("fsdp", "tensor")),
            "wk": ParamSpec((c.d_model, c.kv_dim), ("fsdp", "tensor")),
            "wv": ParamSpec((c.d_model, c.kv_dim), ("fsdp", "tensor")),
            "wo": ParamSpec((c.q_dim, c.d_model), ("tensor", "fsdp")),
            "ln2": ParamSpec((c.d_model,), (None,), init="zeros"),
        }
        if c.qk_norm:
            t["q_norm"] = ParamSpec((c.head_dim,), (None,), init="zeros")
            t["k_norm"] = ParamSpec((c.head_dim,), (None,), init="zeros")
        if c.family == "moe":
            t["moe"] = moe_lib.moe_template(c)
        else:
            t["mlp"] = mlp_template(c.d_model, c.d_ff, c.act)
        if c.family == "hybrid":
            t["ssm"] = ssm_lib.mamba_template(c.d_model, c.ssm_state, c.ssm_conv,
                                              c.ssm_expand)
            t["attn_branch_norm"] = ParamSpec((c.d_model,), (None,), init="zeros")
            t["ssm_branch_norm"] = ParamSpec((c.d_model,), (None,), init="zeros")
        return t

    def template(self) -> Dict:
        c = self.cfg
        t: Dict[str, Any] = {
            "embed": ParamSpec((c.vocab_size, c.d_model), ("tensor", None), scale=1.0,
                               fan_in_dims=(1,)),
            "final_norm": ParamSpec((c.d_model,), (None,), init="zeros"),
            "layers": stack_template(self.layer_template(), c.num_layers),
        }
        if not c.tie_embeddings:
            t["lm_head"] = ParamSpec((c.d_model, c.vocab_size), ("fsdp", "tensor"))
        if c.family == "vlm":
            # projection stub applied to precomputed patch embeddings
            t["img_proj"] = ParamSpec((c.d_model, c.d_model), ("fsdp", "tensor"))
        return t

    def init_params(self, key, dtype=jnp.float32):
        return init_params(key, self.template(), dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.template(), dtype)

    def param_axes(self):
        return param_axes(self.template())

    def param_count(self) -> int:
        return count_template_params(self.template())

    # ------------------------------------------------------------------
    # layer forward (training / prefill)
    # ------------------------------------------------------------------
    def _attention_block(self, p, h, positions, window: int, head_sharded: bool,
                         kv_writer=None):
        """h = normed input [B,S,D]. Returns (attn_out [B,S,D-proj], (k, v))."""
        c = self.cfg
        b, s, _ = h.shape
        q = (h @ p["wq"]).reshape(b, s, c.num_heads, c.head_dim)
        k = (h @ p["wk"]).reshape(b, s, c.num_kv_heads, c.head_dim)
        v = (h @ p["wv"]).reshape(b, s, c.num_kv_heads, c.head_dim)
        if c.qk_norm:
            q = rms_norm(q, p["q_norm"], c.norm_eps)
            k = rms_norm(k, p["k_norm"], c.norm_eps)
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
        if head_sharded:
            q = constrain(q, ("batch", None, "act_heads", None))
            k = constrain(k, ("batch", None, "act_heads", None))
            v = constrain(v, ("batch", None, "act_heads", None))
        else:  # sequence-parallel attention (head count not divisible)
            q = constrain(q, ("batch", "seq", None, None))
        if kv_writer is not None:
            kv_writer(k, v)
        if s <= 1024:
            out = attn.dense_attention(q, k, v, positions, positions,
                                       causal=True, window=window)
        else:
            out = attn.blockwise_attention(q, k, v, positions, positions,
                                           causal=True, window=window)
        out = out.reshape(b, s, c.q_dim)
        if head_sharded:
            out = constrain(out, ("batch", None, "act_ff"))
        return out @ p["wo"]

    def _layer(self, p, x, positions, window: int, head_sharded: bool,
               kv_writer=None, capacity_factor: float = 1.25):
        """One transformer block. Returns (x, aux_loss)."""
        c = self.cfg
        b, s, _ = x.shape
        h = rms_norm(x, p["ln1"], c.norm_eps)
        attn_out = self._attention_block(p, h, positions, window, head_sharded,
                                         kv_writer)
        if c.family == "hybrid":
            ssm_out = ssm_lib.mamba_forward(p["ssm"], h)
            mixed = 0.5 * (rms_norm(attn_out, p["attn_branch_norm"], c.norm_eps)
                           + rms_norm(ssm_out, p["ssm_branch_norm"], c.norm_eps))
            x = x + mixed * c.residual_scale
        else:
            x = x + attn_out * c.residual_scale
        h2 = rms_norm(x, p["ln2"], c.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if c.family == "moe":
            flat, aux = moe_lib.moe_ffn(p["moe"], h2.reshape(b * s, c.d_model), c,
                                        capacity_factor=capacity_factor,
                                        return_aux=True)
            mlp_out = flat.reshape(b, s, c.d_model)
        else:
            mlp_out = mlp_apply(p["mlp"], h2, c.act)
        x = x + mlp_out * c.residual_scale
        x = constrain(x, ("batch", None, "embed"))
        return x, aux

    # ------------------------------------------------------------------
    # full forward
    # ------------------------------------------------------------------
    def embed(self, params, tokens, img_embeds=None):
        c = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        if c.family == "vlm":
            assert img_embeds is not None, "vlm needs patch embeddings"
            img = img_embeds @ params["img_proj"]
            x = jnp.concatenate([img.astype(x.dtype), x], axis=1)
        return x

    def forward(
        self,
        params,
        tokens: jax.Array,                 # [B, S_text]
        img_embeds: Optional[jax.Array] = None,
        positions: Optional[jax.Array] = None,
        *,
        compute_dtype=jnp.bfloat16,
        remat: str = "full",
        head_sharded: Optional[bool] = None,
        tp_size: int = 1,
        capacity_factor: float = 1.25,
    ) -> Tuple[jax.Array, jax.Array]:
        """Returns (final hidden [B, S, D], total aux loss)."""
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        x = self.embed(params, tokens, img_embeds)
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        if head_sharded is None:
            head_sharded = (c.num_heads % max(tp_size, 1) == 0)
        x = constrain(x, ("batch", None, "embed"))

        policy = REMAT_POLICIES["none" if remat == "none" else remat]
        aux_total = jnp.zeros((), jnp.float32)
        for grp in layer_groups(c):
            gp = jax.tree.map(lambda a: a[grp.start : grp.end], params["layers"])

            def body(x, lp, _window=grp.window):
                f = lambda xx: self._layer(lp, xx, positions, _window,
                                           head_sharded, None, capacity_factor)
                if remat != "none":
                    f = jax.checkpoint(f, policy=policy)
                return f(x)

            x, auxs = jax.lax.scan(body, x, gp)
            aux_total = aux_total + jnp.sum(auxs)
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        return x, aux_total

    def logits(self, params, hidden, compute_dtype=jnp.bfloat16):
        c = self.cfg
        if c.tie_embeddings:
            w = params["embed"].astype(compute_dtype).T
        else:
            w = params["lm_head"].astype(compute_dtype)
        out = hidden @ w
        if c.embed_scale != 1.0:
            out = out * c.embed_scale
        if c.logit_soft_cap > 0:
            out = jnp.tanh(out / c.logit_soft_cap) * c.logit_soft_cap
        return constrain(out, ("batch", None, "vocab"))

    def loss_fn(self, params, batch: Dict[str, jax.Array], *, remat: str = "full",
                tp_size: int = 1, rngs=None) -> Tuple[jax.Array, Dict]:
        """batch: tokens [B,S], labels [B,S] (-1 = masked), optional
        img_embeds [B,Timg,D]."""
        c = self.cfg
        hidden, aux = self.forward(params, batch["tokens"],
                                   img_embeds=batch.get("img_embeds"),
                                   remat=remat, tp_size=tp_size)
        labels = batch["labels"]
        if c.family == "vlm":  # img prefix carries no loss
            t_img = hidden.shape[1] - labels.shape[1]
            hidden = hidden[:, t_img:]
        logits = self.logits(params, hidden).astype(jnp.float32)
        mask = (labels >= 0).astype(jnp.float32)
        safe_labels = jnp.maximum(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mask
        ntok = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / ntok
        zloss = 1e-4 * jnp.sum(jnp.square(lse) * mask) / ntok
        total = loss + zloss + c.router_aux_coef * aux
        return total, {"loss": loss, "zloss": zloss, "aux": aux,
                       "ntok": ntok}

    # ------------------------------------------------------------------
    # serving: Libra fast path (paged, anchored)
    # ------------------------------------------------------------------
    def kv_pool_shape(self, total_pages: int) -> Tuple[int, ...]:
        c = self.cfg
        return (c.num_layers, total_pages, self.page_size, 2, c.num_kv_heads,
                c.head_dim)

    def decode_step(
        self,
        params,
        tokens: jax.Array,       # [B] current token ids
        seq_lens: jax.Array,     # [B] position of the incoming token
        pool: jax.Array,         # [L, P, page, 2, Hkv, hd]
        tables: jax.Array,       # [B, nsh, pps]
        page_pos: jax.Array,     # [B, nsh, pps]
        write_shard: jax.Array,  # [B]
        write_slot: jax.Array,   # [B]
        *,
        mesh: Mesh,
        batch_axis,
        combine_axes,
        ssm_state: Optional[Dict[str, jax.Array]] = None,  # hybrid only
        compute_dtype=jnp.bfloat16,
    ):
        """One Libra decode step. Returns (next_tokens [B] int32, pool, ssm_state).

        Host↔device traffic: token ids + O(pages) int32 metadata in; token
        ids out. The KV payload never leaves the pool (selective copy)."""
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        x = jnp.take(params["embed"], tokens, axis=0)  # [B, D]
        positions = seq_lens

        groups = layer_groups(c)
        windows = [0] * c.num_layers
        for grp in groups:
            for li in range(grp.start, grp.end):
                windows[li] = grp.window
        window_arr = jnp.array(windows, jnp.int32)

        def layer_step(carry, xs):
            x = carry
            if c.family == "hybrid":
                lp, pool_l, window, ssm_l, conv_l = xs
            else:
                lp, pool_l, window = xs
                ssm_l = conv_l = None
            b = x.shape[0]
            h = rms_norm(x, lp["ln1"], c.norm_eps)
            q = (h @ lp["wq"]).reshape(b, c.num_heads, c.head_dim)
            k = (h @ lp["wk"]).reshape(b, c.num_kv_heads, c.head_dim)
            v = (h @ lp["wv"]).reshape(b, c.num_kv_heads, c.head_dim)
            if c.qk_norm:
                q = rms_norm(q, lp["q_norm"], c.norm_eps)
                k = rms_norm(k, lp["k_norm"], c.norm_eps)
            q = apply_rope(q[:, None], positions[:, None], c.rope_theta)[:, 0]
            k = apply_rope(k[:, None], positions[:, None], c.rope_theta)[:, 0]
            out, pool_l = attn.paged_decode_attention(
                q, k, v, pool_l, tables, page_pos, seq_lens, write_shard,
                write_slot, mesh=mesh, batch_axis=batch_axis,
                combine_axes=combine_axes, window=window)
            attn_out = out.reshape(b, c.q_dim) @ lp["wo"]
            new_ssm = new_conv = None
            if c.family == "hybrid":
                ssm_out, st = ssm_lib.mamba_step(lp["ssm"], h,
                                                 {"ssm": ssm_l, "conv": conv_l})
                new_ssm, new_conv = st["ssm"], st["conv"]
                mixed = 0.5 * (rms_norm(attn_out, lp["attn_branch_norm"], c.norm_eps)
                               + rms_norm(ssm_out, lp["ssm_branch_norm"], c.norm_eps))
                x = x + mixed * c.residual_scale
            else:
                x = x + attn_out * c.residual_scale
            h2 = rms_norm(x, lp["ln2"], c.norm_eps)
            if c.family == "moe":
                mlp_out = moe_lib.moe_ffn(lp["moe"], h2, c, capacity_factor=2.0)
            else:
                mlp_out = mlp_apply(lp["mlp"], h2, c.act)
            x = x + mlp_out * c.residual_scale
            if c.family == "hybrid":
                return x, (pool_l, new_ssm, new_conv)
            return x, (pool_l,)

        if c.family == "hybrid":
            xs = (params["layers"], pool, window_arr, ssm_state["ssm"],
                  ssm_state["conv"])
        else:
            xs = (params["layers"], pool, window_arr)
        x, ys = jax.lax.scan(layer_step, x, xs)
        new_pool = ys[0]
        new_ssm_state = None
        if c.family == "hybrid":
            new_ssm_state = {"ssm": ys[1], "conv": ys[2]}
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        logits = self.logits(params, x[:, None])[:, 0]
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_pool, new_ssm_state

    def prefill(
        self,
        params,
        tokens: jax.Array,       # [B, S]
        seq_lens: jax.Array,     # [B]
        pool: jax.Array,         # [L, P, page, 2, Hkv, hd]
        tables: jax.Array,
        token_shard: jax.Array,  # [B, S]
        token_slot: jax.Array,
        token_off: jax.Array,
        token_valid: jax.Array,
        *,
        mesh: Mesh,
        batch_axis,
        combine_axes,
        img_embeds: Optional[jax.Array] = None,
        compute_dtype=jnp.bfloat16,
        tp_size: int = 1,
    ):
        """Ingress: run the prompt, anchor its KV into pool pages in place,
        return (first sampled tokens [B], updated pool). Only metadata
        (token ids) ever surfaces to the host. Layers are scanned per group
        with the pool slice threaded as scan xs/ys."""
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        x = self.embed(params, tokens, img_embeds)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        head_sharded = (c.num_heads % max(tp_size, 1) == 0)
        x = constrain(x, ("batch", None, "embed"))

        def writer_for(pool_l):
            box = {}

            def write(k, v):
                box["pool"] = attn.prefill_write_pages(
                    k, v, pool_l, tables, token_shard, token_slot,
                    token_off, token_valid, mesh=mesh,
                    batch_axis=batch_axis, combine_axes=combine_axes)
            return write, box

        new_pool_groups = []
        for grp in layer_groups(c):
            gp = jax.tree.map(lambda a: a[grp.start : grp.end], params["layers"])
            pool_g = pool[grp.start : grp.end]

            def body(x, xs, _window=grp.window):
                lp, pool_l = xs
                write, box = writer_for(pool_l)
                x, _aux = self._layer(lp, x, positions, _window, head_sharded,
                                      write, 2.0)
                return x, box["pool"]

            x, pool_g_new = jax.lax.scan(body, x, (gp, pool_g))
            new_pool_groups.append(pool_g_new)
        new_pool = jnp.concatenate(new_pool_groups, axis=0) \
            if len(new_pool_groups) > 1 else new_pool_groups[0]
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        # sample the first output token from the last valid position
        idx = jnp.maximum(seq_lens - 1, 0)
        if c.family == "vlm":
            idx = idx + (x.shape[1] - tokens.shape[1])
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self.logits(params, last, compute_dtype)[:, 0]
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return first, new_pool

    # ------------------------------------------------------------------
    # serving baseline: the "standard stack" contiguous-copy datapath
    # ------------------------------------------------------------------
    def prefill_dense(self, params, tokens, seq_lens, max_len: int,
                      *, compute_dtype=jnp.bfloat16):
        """Baseline prefill: returns (first_tokens [B], kv_cache
        [L, B, max_len, 2, Hkv, hd]) — the contiguous cache the standard
        stack re-copies every step."""
        c = self.cfg
        params_c = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        b, s = tokens.shape
        x = jnp.take(params_c["embed"], tokens, axis=0)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))

        def body(x, lp):
            h = rms_norm(x, lp["ln1"], c.norm_eps)
            q = (h @ lp["wq"]).reshape(b, s, c.num_heads, c.head_dim)
            k = (h @ lp["wk"]).reshape(b, s, c.num_kv_heads, c.head_dim)
            v = (h @ lp["wv"]).reshape(b, s, c.num_kv_heads, c.head_dim)
            if c.qk_norm:
                q = rms_norm(q, lp["q_norm"], c.norm_eps)
                k = rms_norm(k, lp["k_norm"], c.norm_eps)
            q = apply_rope(q, positions, c.rope_theta)
            k = apply_rope(k, positions, c.rope_theta)
            out = attn.dense_attention(q, k, v, positions, positions,
                                       causal=True) if s <= 1024 else \
                attn.blockwise_attention(q, k, v, positions, positions,
                                         causal=True)
            x = x + out.reshape(b, s, c.q_dim) @ lp["wo"] * c.residual_scale
            h2 = rms_norm(x, lp["ln2"], c.norm_eps)
            if c.family == "moe":
                mlp_out = moe_lib.moe_ffn(lp["moe"], h2.reshape(b * s, -1), c,
                                          capacity_factor=2.0
                                          ).reshape(b, s, c.d_model)
            else:
                mlp_out = mlp_apply(lp["mlp"], h2, c.act)
            x = x + mlp_out * c.residual_scale
            kv = jnp.stack([k, v], axis=2)            # [B, S, 2, Hkv, hd]
            kv = jnp.pad(kv, ((0, 0), (0, max_len - s), (0, 0), (0, 0), (0, 0)))
            return x, kv

        x, cache = jax.lax.scan(body, x, params_c["layers"])
        x = rms_norm(x, params_c["final_norm"], c.norm_eps)
        idx = jnp.maximum(seq_lens - 1, 0)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self.logits(params_c, last, compute_dtype)[:, 0]
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return first, cache

    def decode_step_dense(self, params, tokens, seq_lens, kv_cache,
                          *, compute_dtype=jnp.bfloat16):
        """Standard-stack analogue: contiguous KV [L, B, Smax, 2, Hkv, hd];
        every step concatenates/gathers the full cache (the copy tax) and
        returns FULL logits (shipped to the host in the baseline engine).
        """
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        x = jnp.take(params["embed"], tokens, axis=0)
        positions = seq_lens

        def layer_step(x, xs):
            lp, cache_l = xs  # cache_l [B, Smax, 2, Hkv, hd]
            b = x.shape[0]
            h = rms_norm(x, lp["ln1"], c.norm_eps)
            q = (h @ lp["wq"]).reshape(b, c.num_heads, c.head_dim)
            k = (h @ lp["wk"]).reshape(b, c.num_kv_heads, c.head_dim)
            v = (h @ lp["wv"]).reshape(b, c.num_kv_heads, c.head_dim)
            if c.qk_norm:
                q = rms_norm(q, lp["q_norm"], c.norm_eps)
                k = rms_norm(k, lp["k_norm"], c.norm_eps)
            q = apply_rope(q[:, None], positions[:, None], c.rope_theta)[:, 0]
            k = apply_rope(k[:, None], positions[:, None], c.rope_theta)[:, 0]
            # the "copy": rebuild the contiguous KV with the new token placed
            kv_new = jnp.stack([k, v], axis=1)[:, None]        # [B,1,2,Hkv,hd]
            cache_l = jax.vmap(
                lambda cl, sl, kvn: jax.lax.dynamic_update_slice_in_dim(
                    cl, kvn.astype(cl.dtype), sl, 0)
            )(cache_l, seq_lens, kv_new[:, 0][:, None])
            kk, vv = cache_l[:, :, 0], cache_l[:, :, 1]
            pos_kv = jnp.broadcast_to(jnp.arange(kk.shape[1]), (b, kk.shape[1]))
            valid = pos_kv <= seq_lens[:, None]
            out = attn.dense_attention(q[:, None], kk.astype(compute_dtype),
                                       vv.astype(compute_dtype),
                                       positions[:, None], pos_kv,
                                       causal=False, kv_valid=valid)[:, 0]
            x = x + out.reshape(b, c.q_dim) @ lp["wo"] * c.residual_scale
            h2 = rms_norm(x, lp["ln2"], c.norm_eps)
            if c.family == "moe":
                mlp_out = moe_lib.moe_ffn(lp["moe"], h2, c, capacity_factor=2.0)
            else:
                mlp_out = mlp_apply(lp["mlp"], h2, c.act)
            x = x + mlp_out * c.residual_scale
            return x, cache_l

        x, new_cache = jax.lax.scan(layer_step, x, (params["layers"], kv_cache))
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        logits = self.logits(params, x[:, None])[:, 0]
        return logits, new_cache
