"""State-space / recurrent mixers: Mamba (hymba), mLSTM + sLSTM (xlstm).

TPU adaptation notes (see DESIGN.md §2): GPU mamba relies on a fused
selective-scan CUDA kernel; the TPU-native form is *chunked*: a
``lax.scan`` over fixed-size chunks with an associative scan inside each
chunk. This bounds the materialised state tensor to [B, chunk, d, n]
(sharded over 'model' on d) instead of [B, S, d, n], and maps onto the
MXU/VPU instead of emulating warp-level scans.

Decode steps are O(1)-state recurrences; the state lives in the Libra
anchor pool (fixed-size anchored payload — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import constrain
from repro.models.layers import (
    ParamSpec,
    causal_conv1d,
    conv1d_step,
    gelu,
    group_norm,
    rms_norm,
)

# ---------------------------------------------------------------------------
# Mamba (selective SSM) — used by hymba's parallel SSM heads
# ---------------------------------------------------------------------------

def mamba_template(d_model: int, ssm_state: int, conv: int, expand: int) -> Dict:
    d_inner = expand * d_model
    dt_rank = -(-d_model // 16)
    return {
        "in_proj": ParamSpec((d_model, 2 * d_inner), ("fsdp", "tensor")),
        "conv_w": ParamSpec((conv, d_inner), ("conv", "tensor")),
        "conv_b": ParamSpec((d_inner,), ("tensor",), init="zeros"),
        "x_proj": ParamSpec((d_inner, dt_rank + 2 * ssm_state), ("tensor", None)),
        "dt_proj": ParamSpec((dt_rank, d_inner), (None, "tensor")),
        "dt_bias": ParamSpec((d_inner,), ("tensor",), init="zeros"),
        "A_log": ParamSpec((d_inner, ssm_state), ("tensor", "state"), init="zeros"),
        "D": ParamSpec((d_inner,), ("tensor",), init="ones"),
        "out_proj": ParamSpec((d_inner, d_model), ("tensor", "fsdp")),
    }


def _mamba_gates(p, u):
    """u [B,*,d_inner] -> (dt [B,*,d_inner], Bc [B,*,n], Cc [B,*,n])."""
    n = p["A_log"].shape[1]
    dt_rank = p["x_proj"].shape[1] - 2 * n
    proj = u @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    Bc = proj[..., dt_rank : dt_rank + n]
    Cc = proj[..., dt_rank + n :]
    return dt, Bc, Cc


def selective_scan_chunked(
    u: jax.Array,   # [B, S, d]
    dt: jax.Array,  # [B, S, d]
    Bc: jax.Array,  # [B, S, n]
    Cc: jax.Array,  # [B, S, n]
    A: jax.Array,   # [d, n]  (negative)
    h0: Optional[jax.Array] = None,
    chunk: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,d], h_final [B,d,n])."""
    b, s, d = u.shape
    n = A.shape[1]
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    uc = u.reshape(b, nchunks, chunk, d).swapaxes(0, 1)
    dtc = dt.reshape(b, nchunks, chunk, d).swapaxes(0, 1)
    bc = Bc.reshape(b, nchunks, chunk, n).swapaxes(0, 1)
    cc = Cc.reshape(b, nchunks, chunk, n).swapaxes(0, 1)

    def body(h, xs):
        u_c, dt_c, b_c, c_c = xs
        a = jnp.exp(dt_c[..., None] * A)                       # [B,c,d,n]
        x_in = (dt_c * u_c)[..., None] * b_c[:, :, None, :]    # [B,c,d,n]

        def comb(x, y):
            return (y[0] * x[0], y[0] * x[1] + y[1])

        a_s, b_s = jax.lax.associative_scan(comb, (a, x_in), axis=1)
        hs = a_s * h[:, None] + b_s                            # [B,c,d,n]
        y = jnp.einsum("bcdn,bcn->bcd", hs, c_c)
        return hs[:, -1], y

    h = h0 if h0 is not None else jnp.zeros((b, d, n), u.dtype)
    h, ys = jax.lax.scan(body, h, (uc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(b, nchunks * chunk, d)[:, :s]
    return y, h


def mamba_forward(p, x: jax.Array, *, chunk: int = 128) -> jax.Array:
    """Full-sequence mamba mixer. x [B, S, D] -> [B, S, D]."""
    d_inner = p["conv_w"].shape[1]
    ug = x @ p["in_proj"]
    u, z = ug[..., :d_inner], ug[..., d_inner:]
    u = jax.nn.silu(causal_conv1d(u, p["conv_w"], p["conv_b"]))
    u = constrain(u, ("batch", None, "act_ff"))  # shard d_inner over 'model'
    dt, Bc, Cc = _mamba_gates(p, u)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = selective_scan_chunked(
        u.astype(jnp.float32), dt.astype(jnp.float32),
        Bc.astype(jnp.float32), Cc.astype(jnp.float32), A, chunk=chunk)
    y = y.astype(x.dtype) + u * p["D"]
    return (y * jax.nn.silu(z)) @ p["out_proj"]


def mamba_state_shape(cfg_d_model: int, ssm_state: int, conv: int, expand: int):
    d_inner = expand * cfg_d_model
    return {
        "ssm": (d_inner, ssm_state),
        "conv": (conv - 1, d_inner),
    }


def mamba_step(p, x_t: jax.Array, state: Dict[str, jax.Array]):
    """One decode step. x_t [B, D]; state {'ssm' [B,d,n], 'conv' [B,K-1,d]}."""
    d_inner = p["conv_w"].shape[1]
    ug = x_t @ p["in_proj"]
    u, z = ug[..., :d_inner], ug[..., d_inner:]
    u, conv_state = conv1d_step(u, state["conv"], p["conv_w"], p["conv_b"])
    u = jax.nn.silu(u).astype(x_t.dtype)
    dt, Bc, Cc = _mamba_gates(p, u)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)  # [B,d,n]
    h = a * state["ssm"] + ((dt * u)[..., None] * Bc[:, None, :]).astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)).astype(x_t.dtype) \
        + u * p["D"]
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"ssm": h.astype(state["ssm"].dtype),
                 "conv": conv_state.astype(state["conv"].dtype)}


# ---------------------------------------------------------------------------
# mLSTM — matrix-memory LSTM (xLSTM), chunkwise-parallel + O(1) decode step
# ---------------------------------------------------------------------------

def mlstm_block_template(d_model: int, num_heads: int, conv: int, expand: int) -> Dict:
    ud = expand * d_model
    return {
        "ln_w": ParamSpec((d_model,), (None,), init="zeros"),
        "up_proj": ParamSpec((d_model, 2 * ud), ("fsdp", "tensor")),
        "conv_w": ParamSpec((conv, ud), ("conv", "tensor")),
        "conv_b": ParamSpec((ud,), ("tensor",), init="zeros"),
        "wq": ParamSpec((ud, ud), ("fsdp", "tensor")),
        "wk": ParamSpec((ud, ud), ("fsdp", "tensor")),
        "wv": ParamSpec((ud, ud), ("fsdp", "tensor")),
        "w_gates": ParamSpec((d_model, 2 * num_heads), ("fsdp", None)),
        "b_gates": ParamSpec((2 * num_heads,), (None,), init="zeros"),
        "gn_w": ParamSpec((ud,), ("tensor",), init="ones"),
        "down_proj": ParamSpec((ud, d_model), ("tensor", "fsdp")),
    }


def mlstm_cell_sequential(q, k, v, log_i, log_f, state=None):
    """Sequential oracle. q/k/v [B,S,H,dh]; log_i/log_f [B,S,H].

    Returns (h [B,S,H,dh], state (C [B,H,dh,dh], n [B,H,dh], m [B,H])).
    """
    b, s, h, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    if state is None:
        C = jnp.zeros((b, h, dh, dh), jnp.float32)
        n = jnp.zeros((b, h, dh), jnp.float32)
        m = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C, n, m = state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs  # [B,H,dh], [B,H]
        m_new = jnp.maximum(lf + m, li)
        fg = jnp.exp(lf + m - m_new)[..., None]
        ig = jnp.exp(li - m_new)[..., None]
        C = fg[..., None] * C + ig[..., None] * (kt[..., None] * vt[..., None, :]) * scale
        n = fg * n + ig * kt * scale
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        hout = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        return (C, n, m_new), hout

    xs = (q.swapaxes(0, 1).astype(jnp.float32), k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32), log_i.swapaxes(0, 1), log_f.swapaxes(0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    return hs.swapaxes(0, 1).astype(q.dtype), (C, n, m)


def mlstm_cell_chunked(q, k, v, log_i, log_f, state=None, chunk: int = 64):
    """Chunkwise-parallel mLSTM (TFLA-style) — the TPU-native form.

    Matches ``mlstm_cell_sequential`` to fp32 tolerance; validated in tests
    and mirrored by the Pallas kernel in repro.kernels.mlstm_scan.
    """
    b, s, h, dh = q.shape
    scale = 1.0 / math.sqrt(dh)
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    if state is None:
        C0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def resh(x, extra=()):
        return x.reshape((b, nchunks, chunk) + extra).swapaxes(0, 1)

    qs, ks, vs = (resh(x.astype(jnp.float32), (h, dh)) for x in (q, k, v))
    lis, lfs = resh(log_i, (h,)), resh(log_f, (h,))

    def body(carry, xs):
        C, n, m = carry
        qc, kc, vc, li, lf = xs  # [B,c,H,dh], [B,c,H]
        A = jnp.cumsum(lf, axis=1)                       # [B,c,H] inclusive
        # intra-chunk log weights W[t,s] = A_t - A_s + li_s  (s <= t)
        W = A[:, :, None, :] - A[:, None, :, :] + li[:, None, :, :]
        tmask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, :, :, None]
        W = jnp.where(tmask, W, -1e30)
        # inter-chunk log factor for the carried state
        binter = A + m[:, None, :]                       # [B,c,H]
        m_loc = jnp.maximum(jnp.max(W, axis=2), binter)  # [B,c,H]
        S_intra = jnp.exp(W - m_loc[:, :, None, :])      # [B,c,c,H]
        qk = jnp.einsum("bthd,bshd->btsh", qc, kc) * scale
        num = jnp.einsum("btsh,btsh,bshe->bthe", S_intra, qk, vc)
        num = num + jnp.exp(binter - m_loc)[..., None] * jnp.einsum("bthd,bhde->bthe", qc, C)
        den = jnp.einsum("btsh,btsh->bth", S_intra, qk)
        den = den + jnp.exp(binter - m_loc) * jnp.einsum("bthd,bhd->bth", qc, n)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))[..., None]
        # ---- state update to end of chunk ----
        A_T = A[:, -1, :]                                # [B,H]
        w_end = A_T[:, None, :] - A + li                 # [B,c,H]
        m_new = jnp.maximum(A_T + m, jnp.max(w_end, axis=1))
        kv = jnp.einsum("bshd,bsh,bshe->bhde", kc * scale, jnp.exp(w_end - m_new[:, None, :]), vc)
        ksum = jnp.einsum("bshd,bsh->bhd", kc * scale, jnp.exp(w_end - m_new[:, None, :]))
        decay = jnp.exp(A_T + m - m_new)
        C = decay[..., None, None] * C + kv
        n = decay[..., None] * n + ksum
        return (C, n, m_new), hout

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks, vs, lis, lfs))
    hs = hs.swapaxes(0, 1).reshape(b, nchunks * chunk, h, dh)[:, :s]
    return hs.astype(q.dtype), (C, n, m)


def mlstm_cell_step(qt, kt, vt, li, lf, state):
    """One decode step. qt/kt/vt [B,H,dh]; li/lf [B,H]; state (C,n,m)."""
    C, n, m = state
    dh = qt.shape[-1]
    scale = 1.0 / math.sqrt(dh)
    qt, kt, vt = (x.astype(jnp.float32) for x in (qt, kt, vt))
    m_new = jnp.maximum(lf + m, li)
    fg = jnp.exp(lf + m - m_new)
    ig = jnp.exp(li - m_new)
    C = fg[..., None, None] * C + ig[..., None, None] * (kt[..., None] * vt[..., None, :]) * scale
    n = fg[..., None] * n + ig[..., None] * kt * scale
    num = jnp.einsum("bhd,bhde->bhe", qt, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return h, (C, n, m_new)


def mlstm_block_forward(p, x, cfg, *, chunk: int = 64, state=None, return_state=False):
    """Full mLSTM residual block. x [B,S,D]."""
    h = rms_norm(x, p["ln_w"], 1e-5)
    ud = p["conv_w"].shape[1]
    H = cfg.num_heads
    upg = h @ p["up_proj"]
    u, z = upg[..., :ud], upg[..., ud:]
    cu = jax.nn.silu(causal_conv1d(u, p["conv_w"], p["conv_b"]))
    b, s, _ = x.shape
    q = (cu @ p["wq"]).reshape(b, s, H, ud // H)
    k = (cu @ p["wk"]).reshape(b, s, H, ud // H)
    v = (u @ p["wv"]).reshape(b, s, H, ud // H)
    gates = (h @ p["w_gates"] + p["b_gates"]).astype(jnp.float32)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    hout, st = mlstm_cell_chunked(q, k, v, log_i, log_f, state=state, chunk=chunk)
    hout = group_norm(hout.reshape(b, s, ud), p["gn_w"], H)
    out = (hout * jax.nn.silu(z)) @ p["down_proj"]
    if return_state:
        return x + out, st
    return x + out


def mlstm_block_step(p, x_t, cfg, state):
    """Decode step. x_t [B,D]; state {'C','n','m','conv'}."""
    h = rms_norm(x_t, p["ln_w"], 1e-5)
    ud = p["conv_w"].shape[1]
    H = cfg.num_heads
    upg = h @ p["up_proj"]
    u, z = upg[..., :ud], upg[..., ud:]
    cu, conv_state = conv1d_step(u, state["conv"], p["conv_w"], p["conv_b"])
    cu = jax.nn.silu(cu)
    b = x_t.shape[0]
    q = (cu @ p["wq"]).reshape(b, H, ud // H)
    k = (cu @ p["wk"]).reshape(b, H, ud // H)
    v = (u @ p["wv"]).reshape(b, H, ud // H)
    gates = (h @ p["w_gates"] + p["b_gates"]).astype(jnp.float32)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])
    hc, (C, n, m) = mlstm_cell_step(q, k, v, log_i, log_f,
                                    (state["C"], state["n"], state["m"]))
    hc = group_norm(hc.reshape(b, ud), p["gn_w"], H)
    out = (hc.astype(x_t.dtype) * jax.nn.silu(z)) @ p["down_proj"]
    return x_t + out, {"C": C, "n": n, "m": m, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM — scalar-memory LSTM with recurrent weights (inherently sequential)
# ---------------------------------------------------------------------------

def slstm_block_template(d_model: int, num_heads: int) -> Dict:
    dh = d_model // num_heads
    pf = -(-4 * d_model // 3)  # post-FFN projection factor 4/3
    return {
        "ln_w": ParamSpec((d_model,), (None,), init="zeros"),
        "w_in": ParamSpec((d_model, 4 * d_model), ("fsdp", "tensor")),
        "b_in": ParamSpec((4 * d_model,), ("tensor",), init="zeros"),
        "r_rec": ParamSpec((4, num_heads, dh, dh), (None, "heads", None, None), scale=0.5),
        "gn_w": ParamSpec((d_model,), ("tensor",), init="ones"),
        "ffn_ln_w": ParamSpec((d_model,), (None,), init="zeros"),
        "ffn_up": ParamSpec((d_model, 2 * pf), ("fsdp", "tensor")),
        "ffn_down": ParamSpec((pf, d_model), ("tensor", "fsdp")),
    }


def _slstm_scan(p, hx, num_heads: int, state):
    """hx [B,S,4*D] precomputed input projections; sequential over S."""
    b, s, d4 = hx.shape
    d = d4 // 4
    dh = d // num_heads
    c0, n0, m0, h0 = state
    c0, n0, m0 = (t.astype(jnp.float32) for t in (c0, n0, m0))
    h0 = h0.astype(hx.dtype)

    def step(carry, xt):
        c, n, m, h_prev = carry  # [B,H,dh] except m [B,H,dh]
        zi = xt.reshape(b, 4, num_heads, dh)
        rec = jnp.einsum("bhd,khde->kbhe", h_prev, p["r_rec"])
        z_t = jnp.tanh(zi[:, 0] + rec[0])
        li = (zi[:, 1] + rec[1]).astype(jnp.float32)
        lf = jax.nn.log_sigmoid((zi[:, 2] + rec[2]).astype(jnp.float32))
        o = jax.nn.sigmoid(zi[:, 3] + rec[3])
        m_new = jnp.maximum(lf + m, li)
        fg = jnp.exp(lf + m - m_new)
        ig = jnp.exp(li - m_new)
        c_new = fg * c + ig * z_t.astype(jnp.float32)
        n_new = fg * n + ig
        h = (o.astype(jnp.float32)
             * (c_new / jnp.maximum(n_new, 1e-6))).astype(hx.dtype)
        return (c_new, n_new, m_new, h), h

    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0), hx.swapaxes(0, 1))
    return hs.swapaxes(0, 1).reshape(b, s, d), (c, n, m, h)


def slstm_init_state(b: int, num_heads: int, d_model: int):
    dh = d_model // num_heads
    z = jnp.zeros((b, num_heads, dh), jnp.float32)
    return (z, z, jnp.full((b, num_heads, dh), -1e30, jnp.float32), z.astype(jnp.bfloat16) * 0)


def slstm_block_forward(p, x, cfg, *, state=None, return_state=False):
    b, s, d = x.shape
    H = cfg.num_heads
    h = rms_norm(x, p["ln_w"], 1e-5)
    hx = h @ p["w_in"] + p["b_in"]
    if state is None:
        state = slstm_init_state(b, H, d)
        state = (state[0], state[1], state[2], jnp.zeros((b, H, d // H), x.dtype))
    hs, st = _slstm_scan(p, hx, H, state)
    hs = group_norm(hs, p["gn_w"], H)
    y = x + hs
    # gated FFN (4/3 projection factor)
    f = rms_norm(y, p["ffn_ln_w"], 1e-5)
    pf = p["ffn_down"].shape[0]
    up = f @ p["ffn_up"]
    f = gelu(up[..., :pf]) * up[..., pf:]
    out = y + f @ p["ffn_down"]
    if return_state:
        return out, st
    return out


def slstm_block_step(p, x_t, cfg, state):
    out, st = slstm_block_forward(p, x_t[:, None, :], cfg,
                                  state=(state["c"], state["n"], state["m"], state["h"]),
                                  return_state=True)
    return out[:, 0, :], {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}
