"""xLSTM language model (xlstm-350m): alternating mLSTM / sLSTM blocks.

Attention-free: the Libra anchored payload is the *recurrent state* (matrix
memory C per mLSTM block, scalar cells per sLSTM block) living in fixed-size
anchor-pool slots — selective copy degenerates to state-handle passing (see
DESIGN.md §Arch-applicability). Decode cost is O(1) in context length, which
is why long_500k runs here and not on the full-attention archs.

Blocks are stacked in two homogeneous groups (mLSTM stack + sLSTM stack) and
executed in position order via per-group scans over contiguous runs.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import constrain
from repro.common.types import ModelConfig
from repro.models.layers import (
    ParamSpec,
    abstract_params,
    count_template_params,
    init_params,
    param_axes,
    rms_norm,
)
from repro.models.ssm import (
    mlstm_block_forward,
    mlstm_block_step,
    mlstm_block_template,
    slstm_block_forward,
    slstm_block_step,
    slstm_block_template,
    slstm_init_state,
)
from repro.models.transformer import REMAT_POLICIES, stack_template


def block_kinds(cfg: ModelConfig) -> List[str]:
    """Position i is sLSTM iff (i + 1) % slstm_every == 0 (xLSTM[7:1])."""
    k = []
    for i in range(cfg.num_layers):
        if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0:
            k.append("slstm")
        else:
            k.append("mlstm")
    return k


def runs(kinds: List[str]) -> List[Tuple[str, int, int]]:
    """Contiguous (kind, start_within_kind_stack, length) runs in order."""
    out = []
    idx = {"mlstm": 0, "slstm": 0}
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        out.append((kinds[i], idx[kinds[i]], j - i))
        idx[kinds[i]] += j - i
        i = j
    return out


class XLSTMModel:
    def __init__(self, cfg: ModelConfig, page_size: int = 64):
        self.cfg = cfg
        self.page_size = page_size  # unused (no KV); kept for API parity
        self.kinds = block_kinds(cfg)
        self.n_mlstm = self.kinds.count("mlstm")
        self.n_slstm = self.kinds.count("slstm")

    # -- params -----------------------------------------------------------
    def template(self) -> Dict:
        c = self.cfg
        t = {
            "embed": ParamSpec((c.vocab_size, c.d_model), ("tensor", None),
                               fan_in_dims=(1,)),
            "final_norm": ParamSpec((c.d_model,), (None,), init="zeros"),
            "lm_head": ParamSpec((c.d_model, c.vocab_size), ("fsdp", "tensor")),
            "mlstm": stack_template(
                mlstm_block_template(c.d_model, c.num_heads, c.ssm_conv,
                                     c.ssm_expand), self.n_mlstm),
        }
        if self.n_slstm:
            t["slstm"] = stack_template(
                slstm_block_template(c.d_model, c.num_heads), self.n_slstm)
        return t

    def init_params(self, key, dtype=jnp.float32):
        return init_params(key, self.template(), dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_params(self.template(), dtype)

    def param_axes(self):
        return param_axes(self.template())

    def param_count(self) -> int:
        return count_template_params(self.template())

    # -- forward -----------------------------------------------------------
    def forward(self, params, tokens, *, compute_dtype=jnp.bfloat16,
                remat: str = "full", **_unused):
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        x = jnp.take(params["embed"], tokens, axis=0)
        x = constrain(x, ("batch", None, "embed"))
        policy = REMAT_POLICIES["none" if remat == "none" else remat]

        for kind, start, length in runs(self.kinds):
            gp = jax.tree.map(lambda a: a[start : start + length], params[kind])

            def body(x, lp, _kind=kind):
                if _kind == "mlstm":
                    f = lambda xx: mlstm_block_forward(lp, xx, c)
                else:
                    f = lambda xx: slstm_block_forward(lp, xx, c)
                if remat != "none":
                    f = jax.checkpoint(f, policy=policy)
                return f(x), jnp.zeros((), jnp.float32)

            x, _ = jax.lax.scan(body, x, gp)
        x = rms_norm(x, params["final_norm"], c.norm_eps)
        return x, jnp.zeros((), jnp.float32)

    def logits(self, params, hidden, compute_dtype=jnp.bfloat16):
        out = hidden @ params["lm_head"].astype(compute_dtype)
        return constrain(out, ("batch", None, "vocab"))

    def loss_fn(self, params, batch, *, remat: str = "full", tp_size: int = 1,
                rngs=None):
        hidden, _ = self.forward(params, batch["tokens"], remat=remat)
        logits = self.logits(params, hidden).astype(jnp.float32)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                                   axis=-1)[..., 0]
        ntok = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum((lse - gold) * mask) / ntok
        return loss, {"loss": loss, "ntok": ntok}

    # -- serving -----------------------------------------------------------
    def decode_state_shapes(self, batch: int) -> Dict[str, Tuple[int, ...]]:
        c = self.cfg
        ud = c.ssm_expand * c.d_model
        dh_m = ud // c.num_heads
        dh_s = c.d_model // c.num_heads
        shapes = {
            "m_C": (self.n_mlstm, batch, c.num_heads, dh_m, dh_m),
            "m_n": (self.n_mlstm, batch, c.num_heads, dh_m),
            "m_m": (self.n_mlstm, batch, c.num_heads),
            "m_conv": (self.n_mlstm, batch, c.ssm_conv - 1, ud),
        }
        if self.n_slstm:
            shapes.update({
                "s_c": (self.n_slstm, batch, c.num_heads, dh_s),
                "s_n": (self.n_slstm, batch, c.num_heads, dh_s),
                "s_m": (self.n_slstm, batch, c.num_heads, dh_s),
                "s_h": (self.n_slstm, batch, c.num_heads, dh_s),
            })
        return shapes

    def init_decode_state(self, batch: int, dtype=jnp.float32):
        shapes = self.decode_state_shapes(batch)
        st = {k: jnp.zeros(s, dtype) for k, s in shapes.items()}
        st["m_m"] = jnp.full(shapes["m_m"], -1e30, dtype)
        if self.n_slstm:
            st["s_m"] = jnp.full(shapes["s_m"], -1e30, dtype)
        return st

    def decode_step(self, params, tokens, seq_lens, state,
                    *, compute_dtype=jnp.bfloat16, **_unused):
        """O(1) decode: anchored recurrent state in, token ids out."""
        c = self.cfg
        params = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        x = jnp.take(params["embed"], tokens, axis=0)
        new_state = dict(state)

        for kind, start, length in runs(self.kinds):
            gp = jax.tree.map(lambda a: a[start : start + length], params[kind])
            if kind == "mlstm":
                xs = (gp, state["m_C"][start : start + length],
                      state["m_n"][start : start + length],
                      state["m_m"][start : start + length],
                      state["m_conv"][start : start + length])

                def body(x, s):
                    lp, C, n, m, conv = s
                    x, st = mlstm_block_step(lp, x, c,
                                             {"C": C, "n": n, "m": m, "conv": conv})
                    return x, (st["C"], st["n"], st["m"], st["conv"])

                x, ys = jax.lax.scan(body, x, xs)
                for key, val in zip(("m_C", "m_n", "m_m", "m_conv"), ys):
                    new_state[key] = new_state[key].at[start : start + length].set(
                        val.astype(new_state[key].dtype))
            else:
                xs = (gp, state["s_c"][start : start + length],
                      state["s_n"][start : start + length],
                      state["s_m"][start : start + length],
                      state["s_h"][start : start + length])

                def body(x, s):
                    lp, cc, nn, mm, hh = s
                    x, st = slstm_block_step(lp, x, c,
                                             {"c": cc, "n": nn, "m": mm, "h": hh})
                    return x, (st["c"], st["n"], st["m"], st["h"])

                x, ys = jax.lax.scan(body, x, xs)
                for key, val in zip(("s_c", "s_n", "s_m", "s_h"), ys):
                    new_state[key] = new_state[key].at[start : start + length].set(
                        val.astype(new_state[key].dtype))

        x = rms_norm(x, params["final_norm"], c.norm_eps)
        logits = self.logits(params, x[:, None])[:, 0]
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, new_state

    def prefill(self, params, tokens, seq_lens, *, compute_dtype=jnp.bfloat16,
                **_unused):
        """Anchor the prompt's recurrent state (run the full forward once,
        keeping final states). Returns (first_tokens, decode_state)."""
        c = self.cfg
        params_c = jax.tree.map(lambda a: a.astype(compute_dtype), params)
        b = tokens.shape[0]
        x = jnp.take(params_c["embed"], tokens, axis=0)
        state = self.init_decode_state(b)

        mi = si = 0
        for kind, start, length in runs(self.kinds):
            gp = jax.tree.map(lambda a: a[start : start + length], params_c[kind])
            for off in range(length):
                lp = jax.tree.map(lambda a: a[off], gp)
                if kind == "mlstm":
                    x2 = x
                    x, st = mlstm_block_forward(lp, x2, c, return_state=True)
                    C, n, m = st
                    state["m_C"] = state["m_C"].at[start + off].set(C)
                    state["m_n"] = state["m_n"].at[start + off].set(n)
                    state["m_m"] = state["m_m"].at[start + off].set(m)
                    # conv state: last K-1 inputs of the up-projected stream
                    ud = lp["conv_w"].shape[1]
                    u = (rms_norm(x2, lp["ln_w"], 1e-5) @ lp["up_proj"])[..., :ud]
                    state["m_conv"] = state["m_conv"].at[start + off].set(
                        u[:, -(c.ssm_conv - 1):, :].astype(jnp.float32))
                else:
                    x, st = slstm_block_forward(lp, x, c, return_state=True)
                    state["s_c"] = state["s_c"].at[start + off].set(st[0])
                    state["s_n"] = state["s_n"].at[start + off].set(st[1])
                    state["s_m"] = state["s_m"].at[start + off].set(st[2])
                    state["s_h"] = state["s_h"].at[start + off].set(
                        st[3].astype(jnp.float32))
        x = rms_norm(x, params_c["final_norm"], c.norm_eps)
        idx = jnp.maximum(seq_lens - 1, 0)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        logits = self.logits(params_c, last, compute_dtype)[:, 0]
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return first, state
