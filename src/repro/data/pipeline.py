"""Host data pipeline: synthetic corpus + deterministic, resumable iterator.

The pipeline mirrors the Libra ingress split at the data layer: per example
it stages a small *metadata* record (lengths, shard/offset, routing tag —
what the trainer's control plane inspects) separately from the bulk token
payload, and the payload buffers are reused in place across batches (no
per-batch reallocation). Iterator state (shard, position, epoch, rng) is
tiny and rides inside checkpoints for exact resume.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PipelineState:
    shard: int
    position: int
    epoch: int
    seed: int

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "PipelineState":
        return cls(**d)


class SyntheticCorpus:
    """Deterministic pseudo-corpus: shard s, document d reproducible from
    (seed, s, d) — stands in for a tokenized dataset on disk."""

    def __init__(self, vocab_size: int, num_shards: int = 16,
                 docs_per_shard: int = 1024, seed: int = 0):
        self.vocab_size = vocab_size
        self.num_shards = num_shards
        self.docs_per_shard = docs_per_shard
        self.seed = seed

    def doc(self, shard: int, idx: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + shard) * 1_000_003 + idx)
        n = int(rng.integers(64, 512))
        # mildly structured stream (zipf-ish) so loss actually decreases
        toks = rng.zipf(1.5, n) % (self.vocab_size - 2) + 1
        return toks.astype(np.int32)


class DataPipeline:
    def __init__(self, corpus: SyntheticCorpus, batch: int, seq_len: int,
                 state: Optional[PipelineState] = None, pad_id: int = 0):
        self.corpus = corpus
        self.batch = batch
        self.seq_len = seq_len
        self.pad_id = pad_id
        self.state = state or PipelineState(0, 0, 0, corpus.seed)
        # payload buffers reused across batches (anchored, never reallocated)
        self._tokens = np.zeros((batch, seq_len), np.int32)
        self._labels = np.zeros((batch, seq_len), np.int32)

    def _next_doc(self) -> np.ndarray:
        s = self.state
        doc = self.corpus.doc(s.shard, s.position)
        s.position += 1
        if s.position >= self.corpus.docs_per_shard:
            s.position = 0
            s.shard += 1
            if s.shard >= self.corpus.num_shards:
                s.shard = 0
                s.epoch += 1
        return doc

    def next_batch(self) -> Dict[str, np.ndarray]:
        self._tokens.fill(self.pad_id)
        self._labels.fill(-1)
        meta = []
        for i in range(self.batch):
            doc = self._next_doc()
            n = min(len(doc) - 1, self.seq_len)
            self._tokens[i, :n] = doc[:n]
            self._labels[i, :n] = doc[1 : n + 1]
            meta.append((n, self.state.shard, self.state.position))
        return {
            "tokens": self._tokens,
            "labels": self._labels,
            # control-plane metadata record (lengths/provenance) — the only
            # part the trainer's host logic ever inspects
            "meta": np.array(meta, np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
