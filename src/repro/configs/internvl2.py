# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""internvl2-76b [vlm] — arXiv:2404.16821 (InternViT-6B + LLaMA-3-70B-style LM).

Backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The InternViT frontend is a STUB per the assignment: ``input_specs()``
provides precomputed, already-projected patch embeddings
[B, img_tokens, d_model]; the backbone consumes them as prefix payload
(the Libra anchored-payload analogue) followed by text tokens (metadata).
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    img_tokens=256,
    rope_theta=500000.0,
    act="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="internvl2-reduced",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        img_tokens=8,
        act="swiglu",
    )
