# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""phi4-mini-3.8b [dense] — arXiv:2412.08905 / hf.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, RoPE + SwiGLU.
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    rope_theta=10000.0,
    act="swiglu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        act="swiglu",
        tie_embeddings=True,
    )
