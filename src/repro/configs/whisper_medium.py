# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""whisper-medium [audio] — arXiv:2212.04356.

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865 — encoder-decoder.
The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, enc_frames, d_model] (whisper: 1500 frames
for 30 s audio). The backbone is 24 encoder + 24 decoder layers with GELU
FFNs and cross-attention; sinusoidal positions so synthetic long-decoder
shapes remain well-defined.
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,       # decoder layers
    enc_layers=24,       # encoder layers
    enc_frames=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-reduced",
        family="encdec",
        num_layers=2,
        enc_layers=2,
        enc_frames=16,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        act="gelu",
        tie_embeddings=True,
    )
