# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""mistral-nemo-12b [dense] — hf:mistralai/Mistral-Nemo-Base-2407.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k context.
Nemo uses an explicit head_dim=128 (q_dim 4096 != d_model 5120).
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    act="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-reduced",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
        rope_theta=1000000.0,
        act="swiglu",
    )
