# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""xlstm-350m [ssm] — arXiv:2405.04517.

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304 — sLSTM + mLSTM blocks.
d_ff=0: blocks carry their own up/down projections (mLSTM pre-up x2,
sLSTM post-FFN 4/3 gated), per the paper. We use the xLSTM[7:1] layout:
one sLSTM block every 8 blocks, the rest mLSTM.
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    ssm_conv=4,
    ssm_expand=2,
    act="gelu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-reduced",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=256,
        slstm_every=2,
        ssm_conv=4,
        ssm_expand=2,
        act="gelu",
    )
