# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""minicpm-2b [dense] — arXiv:2404.06395 / hf (llama-like, WSD schedule).

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
MiniCPM uses mu-p style depth scaling of residual branches and a
warmup-stable-decay (WSD) LR schedule; both are first-class here
(``residual_scale``, ``lr_schedule='wsd'`` consumed by repro.training).
"""
import math

from repro.common.types import ModelConfig

_L = 40

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=_L,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    act="swiglu",
    tie_embeddings=True,
    # MiniCPM: residual branches scaled by 1.4/sqrt(num_layers)
    residual_scale=1.4 / math.sqrt(_L),
    # logits scaled by 1/(d_model/256) via embed_scale on the output head
    embed_scale=1.0 / (2304 / 256),
    lr_schedule="wsd",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=160,
        vocab_size=256,
        act="swiglu",
        tie_embeddings=True,
        residual_scale=1.4 / math.sqrt(2),
        embed_scale=0.25,
        lr_schedule="wsd",
    )
