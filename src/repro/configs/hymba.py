# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""hymba-1.5b [hybrid] — arXiv:2411.13676 / hf.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Parallel attention + mamba heads within each block; sliding-window
attention everywhere except three global-attention layers (first,
middle, last).
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    window=1024,
    global_attn_layers=(0, 15, 31),
    act="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-reduced",
        family="hybrid",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        ssm_state=8,
        ssm_conv=4,
        ssm_expand=2,
        window=16,
        global_attn_layers=(0,),
        act="swiglu",
    )
