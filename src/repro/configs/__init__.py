"""Architecture config registry.

One module per assigned architecture; each exports ``CONFIG`` (the exact
published configuration) and ``reduced()`` (a small same-family config for
CPU smoke tests). ``get_config(name)`` / ``get_reduced(name)`` look them up.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.common.types import ModelConfig

_ARCH_MODULES = {
    "phi3-mini-3.8b": "repro.configs.phi3_mini",
    "phi4-mini-3.8b": "repro.configs.phi4_mini",
    "minicpm-2b": "repro.configs.minicpm",
    "mistral-nemo-12b": "repro.configs.mistral_nemo",
    "hymba-1.5b": "repro.configs.hymba",
    "xlstm-350m": "repro.configs.xlstm",
    "whisper-medium": "repro.configs.whisper_medium",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe",
    "internvl2-76b": "repro.configs.internvl2",
    # the paper's own scenario: a tiny router/proxy LM used by the serving
    # examples and benchmarks (not part of the 10-arch assignment)
    "libra-proxy-125m": "repro.configs.libra_proxy",
}

ARCHS: List[str] = [k for k in _ARCH_MODULES if k != "libra-proxy-125m"]


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
