# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts (shared FFN d_ff = 4*1408).
Experts padded 60 -> 64 so EP-16 divides (padding noted in DESIGN.md).
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=0,
    expert_d_ff=1408,
    num_experts=60,
    expert_pad_to=64,
    num_shared_experts=4,
    top_k=4,
    vocab_size=151936,
    act="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        expert_d_ff=32,
        num_experts=6,
        expert_pad_to=8,
        num_shared_experts=2,
        top_k=2,
        vocab_size=256,
        act="swiglu",
    )
