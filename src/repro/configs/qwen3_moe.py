# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936,
128 experts top-8, QK-norm, no shared experts.
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    expert_d_ff=768,
    num_experts=128,
    top_k=8,
    vocab_size=151936,
    rope_theta=1000000.0,
    act="swiglu",
    qk_norm=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-reduced",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=0,
        expert_d_ff=32,
        num_experts=8,
        top_k=2,
        vocab_size=256,
        act="swiglu",
        qk_norm=True,
    )
