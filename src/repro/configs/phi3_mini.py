# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""phi3-mini-3.8b [dense] — arXiv:2404.14219.

32L d_model=3072 32H (GQA kv=32, i.e. MHA) d_ff=8192 vocab=32064,
RoPE + SwiGLU.
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    act="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        act="swiglu",
    )
