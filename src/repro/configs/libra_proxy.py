# libra: waive[IMPORT001] model-config data staged for the launch tooling (loaded by name via repro.configs)
"""libra-proxy-125m — the paper-scenario model.

A small dense LM standing in for the L7-proxy workload driver: the serving
examples/benchmarks run this model under the Libra engine (selective copy +
anchored KV + VPI forwarding) vs the Standard/Copier/Static engines, which
reproduces the paper's Nginx/HAProxy comparison shape at laptop scale.
"""
from repro.common.types import ModelConfig

CONFIG = ModelConfig(
    name="libra-proxy-125m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=32000,
    act="swiglu",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="libra-proxy-reduced",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        act="swiglu",
    )
