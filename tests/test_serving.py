"""Serving engine correctness: the Libra datapath must produce bit-identical
tokens to the standard-stack baseline and to a naive full-recompute
reference, while moving orders of magnitude fewer bytes across the host
boundary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.parser import TokenStreamParser
from repro.models.registry import build_model
from repro.serving.engine import (
    CopierEngine,
    LibraEngine,
    StandardEngine,
    StaticEngine,
)

ARCH = "libra-proxy-125m"


@pytest.fixture(scope="module")
def model_and_params():
    cfg = get_reduced(ARCH)
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    return model, params


def _reference_generate(model, params, prompt, n_new):
    """Naive reference: full forward over the whole context per token."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        hidden, _ = model.forward(params, jnp.array([toks], jnp.int32),
                                  remat="none", compute_dtype=jnp.float32)
        logits = model.logits(params, hidden[:, -1:], jnp.float32)
        nxt = int(jnp.argmax(logits[0, 0]))
        out.append(nxt)
        toks.append(nxt)
    return out


def _mk_requests(rng, n, lo=6, hi=20):
    return [rng.integers(1, 250, rng.integers(lo, hi)) for _ in range(n)]


def test_libra_matches_reference(model_and_params):
    model, params = model_and_params
    rng = np.random.default_rng(0)
    prompts = _mk_requests(rng, 3)
    eng = LibraEngine(model, params, max_batch=3, max_len=64, page_size=8,
                      parser=TokenStreamParser(header_len=4))
    reqs = [eng.submit(p, max_new_tokens=5) for p in prompts]
    eng.run()
    for r, p in zip(reqs, prompts):
        want = _reference_generate(model, params, p, 5)
        assert r.output == want, (r.output, want)


def test_all_engines_agree(model_and_params):
    model, params = model_and_params
    rng = np.random.default_rng(1)
    prompts = _mk_requests(rng, 4)
    outs = {}
    for cls, kw in [(LibraEngine, dict(max_batch=4, max_len=64, page_size=8)),
                    (StandardEngine, dict(max_batch=4, max_len=64)),
                    (CopierEngine, dict(max_batch=4, max_len=64)),
                    (StaticEngine, dict(memory_budget=1 << 30, max_len=64))]:
        eng = cls(model, params, **kw)
        reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
        eng.run()
        outs[cls.name] = [r.output for r in reqs]
    for name, o in outs.items():
        assert o == outs["libra"], (name, o, outs["libra"])


def test_selective_copy_traffic_advantage(model_and_params):
    """The Libra host-boundary traffic must be metadata-sized; the standard
    engine's must scale with vocab (logits) and its payload copies with the
    whole cache — the paper's Figure 9 relationships."""
    model, params = model_and_params
    rng = np.random.default_rng(2)
    prompts = _mk_requests(rng, 4)
    libra = LibraEngine(model, params, max_batch=4, max_len=64, page_size=8)
    std = StandardEngine(model, params, max_batch=4, max_len=64)
    for eng in (libra, std):
        for p in prompts:
            eng.submit(p, max_new_tokens=6)
        eng.run()
    # per decode step, Libra ships O(B) ids; Standard ships O(B·V) logits
    assert libra.stats.d2h_bytes * 50 < std.stats.d2h_bytes
    # Libra anchors payload once; Standard re-copies the cache every step
    assert libra.stats.payload_copy_bytes == 0
    assert std.stats.payload_copy_bytes > std.stats.steps * 1000
    # pool pages all returned after completion
    assert libra.pool.alloc.free_pages == libra.pool.alloc.total_pages - 1  # parking


def test_continuous_batching_admission(model_and_params):
    """More requests than slots: engine must admit in waves and finish all."""
    model, params = model_and_params
    rng = np.random.default_rng(3)
    prompts = _mk_requests(rng, 7)
    eng = LibraEngine(model, params, max_batch=2, max_len=64, page_size=8)
    reqs = [eng.submit(p, max_new_tokens=3) for p in prompts]
    eng.run()
    assert len(eng.completed) == 7
    for r, p in zip(reqs, prompts):
        want = _reference_generate(model, params, p, 3)
        assert r.output == want


def test_vpi_forwarding_zero_copy(model_and_params):
    """Zero-copy handoff: sharing a handle moves no payload bytes and both
    holders see the same anchored pages (refcounted)."""
    model, params = model_and_params
    rng = np.random.default_rng(4)
    eng = LibraEngine(model, params, max_batch=2, max_len=64, page_size=8)
    r = eng.submit(rng.integers(1, 250, 12), max_new_tokens=3)
    eng.run()
    # note: handle released at completion; re-anchor to exercise forwarding
    r2 = eng.submit(rng.integers(1, 250, 12), max_new_tokens=5)
    eng.step()  # prefill + first decode; r2 still active
    h2 = eng.forward_handle(r2)
    assert eng.stats.zero_copy_bytes > 0
    before = eng.pool.alloc.free_pages
    eng.pool.release(h2)
    assert eng.pool.alloc.free_pages == before  # refcount held by r2
    eng.run()
