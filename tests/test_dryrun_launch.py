"""Launch-layer tests: dry-run machinery on a small forced-device mesh.

Runs in a subprocess because repro.launch.dryrun pins the XLA host device
count at import (the production meshes need 512 placeholder devices; tests
here use 8 to keep CPU compile fast)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=900, env=env)


def test_mesh_function_does_not_touch_devices_on_import():
    r = _run("""
        import repro.launch.mesh as m
        import jax
        # importing the module must not initialise jax devices
        assert 'jax' in dir(m)
        print("OK")
    """)
    assert r.returncode == 0, r.stderr


def test_small_mesh_train_and_decode_cells():
    """Lower+compile a train cell and a decode cell on a 2x4 mesh with the
    same build path the 512-chip dry-run uses."""
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.common.sharding import AxisType, make_mesh
        from repro.common.types import ShapeSpec, MeshSpec
        from repro.configs import get_reduced
        from repro.launch import dryrun
        from repro.roofline.hlo_analysis import analyze_hlo_text

        mesh = make_mesh((2, 4), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        for arch, shape in [("phi4-mini-3.8b", ShapeSpec("t", 64, 8, "train")),
                            ("qwen2-moe-a2.7b", ShapeSpec("t", 64, 8, "train")),
                            ("phi3-mini-3.8b", ShapeSpec("d", 64, 8, "decode")),
                            ("hymba-1.5b", ShapeSpec("d", 64, 8, "decode"))]:
            cfg = get_reduced(arch)
            with mesh:
                fn, args, shards, donate = dryrun.build_cell(cfg, shape, mesh)
                compiled = jax.jit(fn, in_shardings=shards,
                                   donate_argnums=donate).lower(*args).compile()
            costs = analyze_hlo_text(compiled.as_text())
            assert costs.flops > 0, arch
            print("OK", arch, costs.flops)
    """)
    assert r.returncode == 0, r.stderr[-3000:]


def test_dryrun_artifacts_complete():
    """Every (arch x shape x mesh) cell must be present and OK/skip in the
    committed dry-run results (the deliverable-e acceptance check)."""
    d = os.path.join(REPO, "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run sweep not yet produced")
    from repro.configs import ARCHS

    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    missing, failed = [], []
    for arch in ARCHS:
        for shape in shapes:
            for pod in ("singlepod", "multipod"):
                p = os.path.join(d, f"{arch}__{shape}__{pod}.json")
                if not os.path.exists(p):
                    missing.append((arch, shape, pod))
                    continue
                r = json.load(open(p))
                if not r.get("ok"):
                    failed.append((arch, shape, pod, r.get("error", "")[:80]))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"
