"""Hypothesis with a deterministic fallback sampler.

The container may not ship ``hypothesis`` (see requirements-dev.txt). Rather
than skipping every property test, this module re-exports the real library
when present and otherwise provides a miniature, seeded implementation of
the tiny slice of its API the tests use (``given``, ``settings``,
``st.integers``, ``st.lists``, ``st.data``). The fallback draws a fixed
number of pseudo-random examples per test — weaker than real shrinking
hypothesis, but it keeps the invariants exercised on minimal installs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.sample(self._rng)

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.sample(rng) for _ in range(n)]

            return _Strategy(sample)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def data():
            return _Strategy(_Data)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            # the trailing parameters receive the drawn values — bind them
            # by name so pytest fixtures in the leading positions compose,
            # exactly as real @given does
            drawn_names = [p.name for p in params[-len(strategies):]]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                for i in range(n):
                    rng = np.random.default_rng(0x5EED + 7919 * i)
                    drawn = {name: s.sample(rng)
                             for name, s in zip(drawn_names, strategies)}
                    fn(*args, **kwargs, **drawn)

            # hide the drawn parameters from pytest's fixture resolution
            wrapper.__signature__ = sig.replace(
                parameters=params[: -len(strategies)])
            del wrapper.__wrapped__
            return wrapper

        return deco
