"""Property tests (hypothesis, with the deterministic fallback shim) for
the two allocation-free substrates of the batched datapath:

* :class:`RxRing` — push/advance/slide/doubling preserve contents and
  ``fingerprint()``, peek views are clamped, compaction never fires below
  ``min_compact``;
* :class:`AnchorPool.alloc_batch`/``free_batch`` — refcount and §A.3
  budget conservation, placement identical to sequential
  ``alloc_sequence`` calls.
"""
import numpy as np

from repro.core import AnchorPool
from repro.core.stream import RxRing

from _hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# RxRing invariants
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 64), st.lists(st.integers(0, 24), max_size=40),
       st.data())
def test_rx_ring_matches_list_model(min_compact, pushes, data):
    """Under arbitrary interleaved push/advance traffic the ring behaves
    exactly like an unbounded list with a read cursor — across slides,
    compactions and capacity doublings."""
    ring = RxRing(capacity=16, min_compact=min_compact)
    model = []                      # unread region
    pushed = consumed = 0
    rng_val = 0
    for n in pushes:
        data_arr = np.arange(rng_val, rng_val + n)
        rng_val += n
        ring.push(data_arr)
        model.extend(data_arr.tolist())
        pushed += n
        take = data.draw(st.integers(0, len(model)))
        # peek views are clamped to the unread region, any request size
        probe = data.draw(st.integers(0, 3 * (len(model) + 1)))
        view = ring.peek(probe)
        assert len(view) == min(probe, len(model))
        assert view.tolist() == model[:len(view)]
        ring.advance(take)
        del model[:take]
        consumed += take
        assert len(ring) == len(model)
        assert ring.fingerprint() == (consumed, pushed)
        assert ring.peek(1 << 30).tolist() == model
    # amortized capacity bound: proportional to the peak live region, not
    # to the total history
    peak = max((len(ring), 16, min_compact * 2, *(2 * n for n in pushes)))
    assert ring.capacity <= max(4 * peak, 16)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 64), st.integers(1, 80))
def test_rx_ring_never_compacts_below_min_compact(min_compact, n):
    """``advance`` only slides once the dead prefix reaches ``min_compact``
    (and dominates the live region) — small dead prefixes stay put so tiny
    queues never pay per-advance copies."""
    ring = RxRing(capacity=256, min_compact=min_compact)
    ring.push(np.arange(n))
    step = max(1, min_compact // 4)
    advanced = 0
    while advanced + step <= min(n, min_compact - 1):
        ring.advance(step)
        advanced += step
        # dead prefix below min_compact: the buffer offset must be intact
        # (no slide happened), proving compaction never fired
        assert ring._head == advanced
    assert ring.peek(1 << 30).tolist() == list(range(advanced, n))


def test_rx_ring_doubling_preserves_contents_and_fingerprint():
    ring = RxRing(capacity=16)
    ring.push(np.arange(10))
    ring.advance(4)
    before = ring.peek(1 << 30).copy()
    fp = ring.fingerprint()
    ring.push(np.arange(100, 400))          # forces repeated doubling
    assert ring.capacity >= 306
    assert ring.fingerprint() == (fp[0], fp[1] + 300)
    assert np.array_equal(ring.peek(1 << 30)[:6], before)


# ---------------------------------------------------------------------------
# alloc_batch / free_batch conservation
# ---------------------------------------------------------------------------

def _pool():
    return AnchorPool(4, 16, 8, max_pages_per_seq=6)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 80), min_size=0, max_size=20))
def test_alloc_batch_matches_sequential_alloc_sequence(sizes):
    """Bulk allocation must produce byte-identical placement to per-item
    alloc_sequence calls (pool layout parity between batched and scalar
    schedules), including which items fail admission."""
    bulk, seq = _pool(), _pool()
    got = bulk.alloc_batch(sizes)
    want = []
    for ln in sizes:
        try:
            want.append(seq.alloc_sequence(ln))
        except Exception:
            want.append(None)
    assert got == want
    assert bulk.free_pages == seq.free_pages
    assert bulk.accounted_pages == seq.accounted_pages
    assert bulk._refcount == seq._refcount
    assert bulk.stats == seq.stats


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 80), min_size=1, max_size=20), st.data())
def test_alloc_free_batch_conserves_refcounts_and_budget(sizes, data):
    pool = _pool()
    total, budget0 = pool.free_pages, pool.accounted_pages
    lists = pool.alloc_batch(sizes)
    live = [pg for pg in lists if pg]
    n_pages = sum(len(pg) for pg in live)
    assert pool.free_pages == total - n_pages
    assert pool.accounted_pages == budget0 + n_pages
    # every allocated page has refcount 1 and appears exactly once
    flat = [(p.shard, p.local_pid) for pg in live for p in pg]
    assert len(flat) == len(set(flat))
    assert all(pool._refcount[key] == 1 for key in flat)
    # retain a random subset (prefix sharing), then bulk-free everything
    shared = [pg for pg in live if data.draw(st.integers(0, 1))]
    for pg in shared:
        pool.retain(pg)
    freed = pool.free_batch(lists)
    assert freed == n_pages
    # retained lists are still live (refcount 1 now), rest fully returned
    assert pool.accounted_pages == budget0 + sum(len(pg) for pg in shared)
    assert pool.free_batch(shared) == sum(len(pg) for pg in shared)
    assert pool.free_pages == total
    assert pool.accounted_pages == budget0
    assert pool._refcount == {}


def test_alloc_batch_partial_admission_skips_only_losers():
    pool = AnchorPool(1, 4, 8)              # 4 pages total
    got = pool.alloc_batch([8, 999 * 8, 8, 8 * 3])
    assert got[0] is not None and got[2] is not None
    assert got[1] is None                   # too big for the pool
    assert got[3] is None                   # 3 pages left-but-2-free: no
    assert pool.free_pages == 2
    assert pool.stats["fallbacks"] == 2
    pool.free_batch(got)
    assert pool.free_pages == 4


def test_alloc_sequence_zero_len_owns_no_pages():
    """Regression: zero-length payloads used to burn a whole page
    (max(seq_len, 1)); they must not consume pool budget at all."""
    pool = _pool()
    free0, acct0 = pool.free_pages, pool.accounted_pages
    assert pool.alloc_sequence(0) == []
    assert pool.alloc_batch([0, 0]) == [[], []]
    assert (pool.free_pages, pool.accounted_pages) == (free0, acct0)
    assert pool.stats["allocs"] == 0


def test_write_coords_asserts_on_overlapping_pages():
    """Regression: overlapping pages used to resolve silently as
    last-match-wins; a corrupted table must assert instead."""
    import pytest

    from repro.core import PageRef

    ok = [[PageRef(0, 0, 0), PageRef(1, 0, 8)]]
    wsh, wsl = AnchorPool.write_coords(ok, [9], n_shards=2, page_size=8)
    assert (wsh[0], wsl[0]) == (1, 0)
    overlapping = [[PageRef(0, 0, 0), PageRef(1, 0, 4)]]   # both cover pos 5
    with pytest.raises(AssertionError):
        AnchorPool.write_coords(overlapping, [5], n_shards=2, page_size=8)
