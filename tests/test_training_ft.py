"""Training loop + fault tolerance: loss decreases, checkpoints are atomic,
kill/restart resumes exactly, elastic re-mesh restores, stragglers flagged,
gradient compression stays close to exact."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.sharding import make_mesh, shard_map
from repro.configs import get_reduced
from repro.data.pipeline import DataPipeline, SyntheticCorpus
from repro.distributed.fault_tolerance import StragglerMonitor, plan_elastic_restart
from repro.models.registry import build_model
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    compressed_psum,
    init_adamw,
    lr_at,
)
from repro.training.train_loop import Trainer


def _mk_trainer(tmp, steps_cfg=None, ckpt_every=5):
    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg)
    corpus = SyntheticCorpus(cfg.vocab_size, seed=1)
    pipe = DataPipeline(corpus, batch=4, seq_len=32)
    opt = steps_cfg or AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200)
    return Trainer(model, opt, pipe, checkpoint_dir=tmp,
                   checkpoint_every=ckpt_every, seed=0)


def test_loss_decreases(tmp_path):
    t = _mk_trainer(str(tmp_path))
    hist = t.train(30)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.5, (first, last)


def test_checkpoint_resume_exact(tmp_path):
    t1 = _mk_trainer(str(tmp_path / "a"), ckpt_every=10 ** 6)
    t1.train(10)
    t1.save(blocking=True)
    loss_continue = t1.train(5)[-1]["loss"]

    t2 = _mk_trainer(str(tmp_path / "a"), ckpt_every=10 ** 6)
    assert t2.resume()
    assert t2.step == 10
    loss_resumed = t2.train(5)[-1]["loss"]
    assert abs(loss_continue - loss_resumed) < 1e-5, \
        "restart must continue bit-exactly (params+opt+data state)"


def test_preemption_checkpoint(tmp_path):
    t = _mk_trainer(str(tmp_path))
    t.train(3)
    t._preempted = True  # simulated SIGTERM
    t.train(10)
    assert t.step == 3  # stopped immediately
    assert t.ckpt.latest_step() == 3  # final checkpoint written


def test_atomic_commit_survives_partial_save(tmp_path):
    t = _mk_trainer(str(tmp_path))
    t.train(6)
    t.save(blocking=True)
    # simulate a crash mid-save: stray .tmp dir must be ignored
    os.makedirs(str(tmp_path / "step_000000099.tmp"))
    t2 = _mk_trainer(str(tmp_path))
    assert t2.resume()
    assert t2.step in (5, 6)


def test_elastic_restore_other_mesh(tmp_path):
    """Restore a checkpoint onto a different mesh (elastic restart)."""
    t1 = _mk_trainer(str(tmp_path))
    t1.train(4)
    t1.save(blocking=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    t2 = _mk_trainer(str(tmp_path))
    assert t2.resume(mesh=mesh)
    # params usable on the new mesh
    h = t2.train(2)
    assert np.isfinite(h[-1]["loss"])


def test_elastic_plan():
    p = plan_elastic_restart(2, 1)
    assert p.mesh_shape == (16, 16) and p.global_batch_scale == 0.5


def test_straggler_monitor():
    mon = StragglerMonitor(n_slices=4, factor=1.5, patience=2)
    for step in range(12):
        for s in range(4):
            mon.record(s, 1.0 if s != 3 else 3.0)  # slice 3 is slow
        bad = mon.evaluate()
    assert bad == [3]


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd")
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] < 0.2              # warmup
    assert abs(lrs[10] - 1.0) < 1e-6  # stable plateau
    assert lrs[-1] < 0.05            # decay tail


def test_gradient_compression_close_to_exact():
    """int8 compressed psum with error feedback: single-participant mean
    must track the exact gradient closely; residual carries the error."""
    mesh = make_mesh((1,), ("pod",))
    g = {"w": jnp.array(np.random.default_rng(0).standard_normal((64, 64)),
                        jnp.float32)}
    err = jax.tree.map(jnp.zeros_like, g)

    def f(g, err):
        return compressed_psum(g, "pod", err)

    out, err2 = shard_map(
        f, mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),) * 2,
        out_specs=(jax.sharding.PartitionSpec(),) * 2, check_vma=False)(g, err)
    rel = float(jnp.linalg.norm(out["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02, rel
    # error feedback: residual equals the quantisation error
    assert float(jnp.abs(err2["w"]).max()) > 0
