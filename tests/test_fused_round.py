"""One-kernel scheduling rounds: ``batch_impl='fused-round'`` must serve
the batched proxy datapath byte-, counter-, and verdict-identically to
the classic three-launch path (anchor, policy match, egress gather) —
across plaintext and hw-kTLS records, single stacks and 4-worker
clusters, budget-truncated sends, punted slow-path verdicts, and seeded
chaos — while collapsing the per-round launch count to one and landing
speculative TX gathers (``tx_spec_hits``). The kernel itself is pinned
bit-exact against :func:`repro.kernels.ref.fused_round_ref` across the
optional-operand matrix and the DMA-staged buffer depths."""
import numpy as np
import pytest

from repro.core import (
    ClusterRuntime,
    FaultPlan,
    LibraCluster,
    LibraStack,
    PolicyTable,
    ProxyRuntime,
    PythonPolicyRouter,
    between,
    build_message,
    drop,
    eq,
    forward,
    punt,
    rule,
)
from repro.core.crypto import REC_HEADER
from repro.core.policy import payload_at
from repro.kernels import ops, ref
from repro.kernels.testing import fused_round_case

STACK_KW = dict(n_shards=4, pages_per_shard=128, page_size=16)

#: app metadata starts after the [MAGIC, len_meta, len_payload] header
TAG = 3


def _stack(**kw):
    for k, v in STACK_KW.items():
        kw.setdefault(k, v)
    kw.setdefault("secret", b"fr")
    return LibraStack(**kw)


def _frames(n, seed=0, tags=(100, 200), payload=24):
    rng = np.random.default_rng(seed)
    return [build_message(np.concatenate([[rng.integers(*tags)],
                                          rng.integers(100, 200, 3)]),
                          rng.integers(1000, 2000, payload))
            for _ in range(n)]


def _table(tls=None):
    """Metadata route + payload-prefix route + drop: every round needs
    the full anchor + match + gather launch triple on the multi-pass
    path. Offsets shift past the record header under hw-kTLS."""
    off = (REC_HEADER if tls else 0) + TAG
    return PolicyTable([
        rule(drop(), between(off, 196, 199)),
        rule(forward(1), payload_at(0, 1950, 2000)),
        rule(forward(0), between(off, 100, 199)),
    ])


def _run(impl, *, tls=None, policy=False, n_chans=6, n_msgs=5, seed=2,
         batched=True, **rt_kw):
    """One proxy run; returns (decrypted wires, Fig. 9 snapshot, msgs,
    stack, rt). Wires are compared decrypted because TLS keys derive
    from per-process connection ids (ciphertext differs across runs)."""
    stack = _stack()
    rt = ProxyRuntime(stack, tick_every=32, batched=batched,
                      batch_impl=impl,
                      policy=_table(tls) if policy else None, **rt_kw)
    for i in range(n_chans):
        src = stack.socket("length-prefixed", tls=tls)
        dsts = [stack.socket("length-prefixed", tls=tls) for _ in range(2)]
        rt.channel(src, dsts, name=f"ch{i}")
        frames = _frames(n_msgs, seed=seed + i)
        wire = (src.tls.seal_frames(frames, src.parser.inner) if tls
                else np.concatenate(frames))
        src.deliver(wire)
    rt.run()
    wires = tuple(
        (d.tls.open_wire(d.tx_wire()) if tls else d.tx_wire()).tobytes()
        for ch in rt.channels for d in ch.dsts)
    snap = stack.counters.snapshot()
    msgs = rt.messages_forwarded()
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    return wires, snap, msgs, stack, rt


# ---------------------------------------------------------------------------
# kernel: interpret-mode bit-exactness vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("crypto,policy,n_buffers", [
    (False, False, 0),
    (True, False, 0),
    (False, True, 0),
    (True, True, 2),
    (True, True, 4),
])
def test_fused_round_interpret_matches_oracle(crypto, policy, n_buffers):
    """``ops.fused_round(impl='interpret')`` is bit-exact against
    ``fused_round_ref`` — meta, pool, verdict, and gathered payload —
    including the DMA-staged buffer depths (the parity gate sweeps the
    full matrix; this pins the ops-layer entry point)."""
    rng = np.random.default_rng(23)
    case = fused_round_case(rng, b=2, page=8, pps=2, meta_max=8)
    base = (case["stream"], case["meta_len"], case["total_len"],
            case["pool"], case["tables"])
    kw = dict(meta_max=8)
    if crypto:
        kw.update(keystream=case["keystream"],
                  tx_keystream=case["tx_keystream"])
    if policy:
        kw.update(cond_off=case["cond_off"], cond_lo=case["cond_lo"],
                  cond_hi=case["cond_hi"], live=case["live"])
        if crypto:
            kw.update(meta_ks=case["meta_ks"])
    want = ref.fused_round_ref(*base, **kw)
    got = ops.fused_round(*base, impl="interpret", n_buffers=n_buffers,
                          **kw)
    for gi, wi, tag in zip(got, want, ("meta", "pool", "verdict", "out")):
        if wi is None:
            assert gi is None, tag
            continue
        assert np.array_equal(np.array(gi), np.array(wi)), tag


# ---------------------------------------------------------------------------
# datapath identity: single stack, plaintext / hw-kTLS × policy, + scalar
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tls", [None, "hw"])
@pytest.mark.parametrize("policy", [False, True])
def test_fused_round_identity_single_stack(tls, policy):
    """The one-kernel round forwards the exact bytes, Fig. 9 counters,
    and message count of the three-launch batched path AND the scalar
    schedule — plaintext and hw-kTLS, with and without the L7 table."""
    fused = _run("fused-round:ref", tls=tls, policy=policy)
    multi = _run("ref", tls=tls, policy=policy)
    scalar = _run("host", tls=tls, policy=policy, batched=False)
    assert fused[0] == multi[0] == scalar[0]
    assert fused[1] == multi[1] == scalar[1]
    assert fused[2] == multi[2] == scalar[2]
    # the fused rounds actually ran on the device plane (policy rounds
    # with no table still fuse anchor + gather)
    assert fused[3].pool.xfer["fused_rounds"] > 0


def test_fused_round_payload_prefix_matches_python_router():
    """The payload-prefix condition inside the fused kernel routes
    identically to the naive Python interpreter peeking the anchored
    first-page window — the full offload round-trip for satellite #3."""
    def run(offloaded):
        stack = _stack()
        src = stack.socket("length-prefixed")
        dsts = [stack.socket("length-prefixed") for _ in range(2)]
        t = _table()
        if offloaded:
            rt = ProxyRuntime(stack, policy=t, batched=True,
                              batch_impl="fused-round:ref")
            rt.channel(src, dsts)
        else:
            rt = ProxyRuntime(stack, batched=True)
            pr = PythonPolicyRouter(t, dsts, parser=src.parser,
                                    stack=stack, src=src)
            rt.channel(src, dsts, rewrite=pr.rewrite, router=pr.router)
        for f in _frames(16, seed=7, payload=12):
            src.deliver(f)
        rt.run()
        s = t.summary()
        s.pop("rounds")
        s.pop("buckets")
        return ([d.tx_wire().tolist() for d in dsts],
                stack.counters.snapshot(), s)

    off, py = run(True), run(False)
    assert off == py
    # backend 1 actually received payload-routed traffic
    assert len(off[0][1]) > 0


# ---------------------------------------------------------------------------
# launch accounting + TX speculation
# ---------------------------------------------------------------------------

def test_fused_round_is_one_launch_and_speculates_tx():
    """3 → 1 launches per round by construction: the fused path's device
    launches are exactly its fused rounds (no separate anchor / match /
    gather passes), strictly fewer than the multi-pass path's, and the
    speculative TX-encrypted gather lands (``tx_spec_hits``) so egress
    costs no extra launch either."""
    fused = _run("fused-round:ref", tls="hw", policy=True)
    multi = _run("ref", tls="hw", policy=True)
    fx, mx = fused[3].pool.xfer, multi[3].pool.xfer
    assert fx["fused_rounds"] > 0
    assert fx["device_rounds"] == fx["fused_rounds"]     # one launch/round
    assert fx["policy_match_rounds"] == 0                # folded in
    assert fx["tx_spec_hits"] > 0                        # egress rode along
    launches_fused = fx["device_rounds"] + fx["policy_match_rounds"]
    launches_multi = mx["device_rounds"] + mx["policy_match_rounds"]
    assert launches_multi > launches_fused
    # no bounce was needed to serve this workload
    assert fused[3].counters.device_fallbacks == 0


# ---------------------------------------------------------------------------
# budget truncation + punt slow path
# ---------------------------------------------------------------------------

def test_fused_round_budget_truncation_identity():
    """A channel send budget truncates messages mid-flight (continued on
    later rounds): the fused path must replay the exact same partial-send
    schedule and bytes as the multi-pass path."""
    def run(impl):
        stack = _stack()
        rt = ProxyRuntime(stack, tick_every=32, batched=True,
                          batch_impl=impl)
        src, dst = stack.socket_pair()
        ch = rt.channel(src, dst, budget=20)
        for f in _frames(8, seed=4, payload=40):
            src.deliver(f)
        rt.run()
        out = (dst.tx_wire().tobytes(), stack.counters.snapshot(),
               ch.stats.messages, ch.stats.partial_sends)
        rt.shutdown()
        return out

    fused, multi = run("fused-round:ref"), run("ref")
    assert fused == multi
    assert fused[3] > 0                 # the budget actually truncated


def test_fused_round_punt_slow_path_identity():
    """PUNT verdicts leave the fused round for the per-message Python
    slow path; byte/counter/stats identity must survive the detour."""
    off = TAG
    table = PolicyTable([
        rule(punt(), between(off, 150, 199)),
        rule(forward(0), between(off, 0, 10 ** 6)),
    ])

    def run(impl):
        stack = _stack()
        rt = ProxyRuntime(stack, tick_every=32, batched=True,
                          batch_impl=impl, policy=table.clone())
        src = stack.socket("length-prefixed")
        dsts = [stack.socket("length-prefixed") for _ in range(2)]
        rt.channel(src, dsts)
        for f in _frames(12, seed=6):
            src.deliver(f)
        rt.run()
        punts = stack.counters.policy_punts
        out = (tuple(d.tx_wire().tobytes() for d in dsts),
               stack.counters.snapshot(), punts)
        rt.shutdown()
        return out

    fused, multi = run("fused-round:ref"), run("ref")
    assert fused == multi
    assert fused[2] > 0                 # the punt path was exercised


# ---------------------------------------------------------------------------
# chaos: seeded FaultPlan replays identically across impls
# ---------------------------------------------------------------------------

def test_fused_round_chaos_identity_under_fault_plan():
    """The same seeded FaultPlan (EAGAIN storm + a reset + early
    corruption) fires the same events against the fused and multi-pass
    rounds — final wires, channel stats, and fired-event logs agree, and
    no pool page leaks through the retry/drop machinery."""
    def run(impl):
        stack = _stack()
        plan = (FaultPlan(seed=11)
                .eagain(0, start=1, until=9, p=0.6)
                .reset(1, at=4)
                .corrupt(p=0.3, start=0, until=2))
        rt = ProxyRuntime(stack, tick_every=8, batched=True,
                          batch_impl=impl, fault_plan=plan)
        src = stack.socket("length-prefixed")
        d0, d1 = (stack.socket("length-prefixed"),
                  stack.socket("length-prefixed"))
        ch = rt.channel(src, [d0, d1], max_retries=4, retry_timeout=64)
        for f in _frames(8, seed=3):
            src.deliver(f)
        rt.run()
        out = (list(plan.log), plan.summary(),
               (ch.stats.messages, ch.stats.retries, ch.stats.timeouts),
               d0.tx_wire().tobytes(), d1.tx_wire().tobytes())
        rt.shutdown()
        assert stack.alloc.free_pages == stack.alloc.total_pages
        return out

    assert run("fused-round:ref") == run("ref")


# ---------------------------------------------------------------------------
# 4-worker cluster identity
# ---------------------------------------------------------------------------

def test_fused_round_identity_four_worker_cluster():
    """Per-worker fused rounds on a 4-worker cluster: backend bytes,
    aggregated counters, and policy telemetry equal the multi-pass
    cluster run, with every page drained."""
    def run(impl):
        cl = LibraCluster(4, secret=b"frc", **STACK_KW)
        crt = ClusterRuntime(cl, policy=_table(), batched=True,
                             batch_impl=impl, tick_every=32)
        outs = []
        rng = np.random.default_rng(9)
        for i in range(8):
            w = cl.workers[i % 4]
            src = w.socket("length-prefixed")
            dsts = [w.socket("length-prefixed") for _ in range(2)]
            crt.runtimes[i % 4].channel(src, dsts, name=f"ch{i}")
            outs.append(dsts)
            for f in _frames(4, seed=int(rng.integers(1 << 30))):
                src.deliver(f)
        crt.run()
        wires = tuple(d.tx_wire().tobytes() for dsts in outs for d in dsts)
        agg = cl.counters_aggregate()
        summ = crt.policy_summary()["aggregate"]
        fused_rounds = sum(w.pool.xfer["fused_rounds"] for w in cl.workers)
        assert cl.pages_in_use == 0
        return wires, agg.snapshot(), summ, fused_rounds

    fw, fs, fp, fr = run("fused-round:ref")
    mw, ms, mp, mr = run("ref")
    assert fw == mw and fs == ms and fp == mp
    assert fr > 0 and mr == 0           # only the fused impl fuses
