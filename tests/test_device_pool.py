"""Device-resident batched datapath: the pool stays on the device across
rounds (zero O(pool) host<->device copies per round, asserted by transfer
instrumentation, not eyeball), dirty-row-tracked lazy host views, the
fused egress gather in forward_batch, and int32-range bounces."""
import numpy as np
import pytest

from repro.core import (
    DevicePool,
    LibraStack,
    ProxyRuntime,
    build_chunked_message,
    build_delimited_message,
    build_message,
    open_stream,
)
from repro.core.stream import TokenPool

RNG = np.random.default_rng(41)

BUILDERS = {
    "length-prefixed": build_message,
    "delimiter": build_delimited_message,
    "chunked": lambda m, p: build_chunked_message(
        [p[i : i + 24] for i in range(0, len(p), 24)]),
}


def _stack(device_pool=True, **kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("pages_per_shard", 64)
    kw.setdefault("page_size", 16)
    kw.setdefault("secret", b"dp")
    return LibraStack(device_pool=device_pool, **kw)


def _run_proxy(*, device_pool=True, batched=True, impl="host", tls=None,
               n_chans=4, n_msgs=3, payload=72, seed=7,
               protos=("length-prefixed", "delimiter", "chunked")):
    stack = _stack(device_pool=device_pool, pages_per_shard=128)
    rt = ProxyRuntime(stack, tick_every=8, batched=batched, batch_impl=impl)
    rng = np.random.default_rng(seed)
    dsts = []
    for i in range(n_chans):
        proto = protos[i % len(protos)]
        if tls and proto == "chunked":
            proto = "length-prefixed"
        src, dst = stack.socket_pair(proto, tls=tls)
        rt.channel(src, dst, name=f"{proto}-{i}")
        dsts.append(dst)
        frames = [BUILDERS[proto](rng.integers(100, 200, 6),
                                  rng.integers(1000, 2000, payload))
                  for _ in range(n_msgs)]
        if tls:
            src.deliver(src.tls.seal_frames(frames, src.parser.inner))
        else:
            for f in frames:
                src.deliver(f)
    rt.run()
    if tls:
        wires = [open_stream(d.tls.tx_key, d.tx_wire()) for d in dsts]
    else:
        wires = [d.tx_wire() for d in dsts]
    msgs = rt.messages_forwarded()
    snap = stack.counters.snapshot()
    rt.shutdown()
    assert stack.alloc.free_pages == stack.alloc.total_pages
    return stack, wires, msgs, snap


# ---------------------------------------------------------------------------
# the acceptance property: zero O(pool) boundary crossings per round
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_resident_rounds_cross_no_pool_sized_data(impl):
    """recv_batch + forward_batch through the device data plane: every
    per-round transfer is O(batch); the only O(pool) crossing is the
    one-time residency snapshot. Asserted from the byte instrumentation
    every transfer in DevicePool is routed through."""
    stack, _, msgs, _ = _run_proxy(impl=impl)
    pool = stack.pool
    assert isinstance(pool, DevicePool)
    assert msgs == 21          # chunked flows forward one frame per chunk
    x = pool.xfer
    pool_tokens = pool.flat_with_scratch.size
    assert x["device_rounds"] > 0
    assert x["pool_syncs"] == 0                      # NO whole-pool bounce
    assert x["resident_init_tokens"] == pool_tokens  # exactly one snapshot
    # per-round traffic is O(batch): far below one pool crossing per round
    per_round = (x["h2d_tokens"] + x["d2h_tokens"]) / x["device_rounds"]
    assert per_round < pool_tokens / 4
    # and in total the resident path moved less than ONE pool's worth of
    # data across all rounds combined (the legacy path moves 2/round)
    assert x["h2d_tokens"] + x["d2h_tokens"] < pool_tokens


def test_legacy_host_pool_pays_pool_syncs():
    """Contrast gate: the pre-residency pool bounces the whole pool across
    the boundary once per device-impl round — the exact cost DevicePool
    deletes. Keeps the zero-sync assertion above honest."""
    stack, _, msgs, _ = _run_proxy(device_pool=False, impl="ref")
    pool = stack.pool
    assert not isinstance(pool, DevicePool)
    assert msgs == 21
    x = pool.xfer
    pool_tokens = pool.flat_with_scratch.size
    assert x["pool_syncs"] > 0
    assert x["pool_syncs"] == x["device_rounds"]
    # each sync moved at least a whole pool of tokens up
    assert x["h2d_tokens"] >= x["pool_syncs"] * pool_tokens


# ---------------------------------------------------------------------------
# end-to-end parity: device plane == host plane == scalar, bytes + counters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["host", "ref", "interpret"])
def test_resident_batched_matches_scalar_end_to_end(impl):
    s_stack, s_wires, s_msgs, s_snap = _run_proxy(batched=False, impl="host")
    b_stack, b_wires, b_msgs, b_snap = _run_proxy(batched=True, impl=impl)
    assert s_msgs == b_msgs
    assert s_snap == b_snap
    assert b_stack.counters.device_fallbacks == 0
    for a, b in zip(s_wires, b_wires):
        assert np.array_equal(a, b)


@pytest.mark.parametrize("impl", ["host", "interpret"])
def test_resident_hw_ktls_matches_scalar(impl):
    """Encrypted hw-mode rounds ride the resident plane end to end: the RX
    keystream fused into the anchoring kernel, the TX keystream fused into
    the gather kernel — decrypted wires byte-identical to scalar."""
    s_stack, s_wires, s_msgs, s_snap = _run_proxy(batched=False, impl="host",
                                                  tls="hw")
    b_stack, b_wires, b_msgs, b_snap = _run_proxy(batched=True, impl=impl,
                                                  tls="hw")
    assert s_msgs == b_msgs
    assert s_snap == b_snap
    for a, b in zip(s_wires, b_wires):
        assert np.array_equal(a, b)
    if impl != "host":
        assert b_stack.pool.xfer["pool_syncs"] == 0
        assert b_stack.pool.xfer["device_rounds"] > 0


def test_forward_batch_device_gather_matches_host_gather():
    """The fused egress gather must hand each transmit the exact bytes
    read_payload would compose — wires identical between impl='host' and
    the kernel path, same stack. The round must exceed the small-gather
    threshold (tiny rounds intentionally stay host-side: per-launch
    overhead beats a few rows' copy cost)."""
    for impl in ("ref", "interpret"):
        stack = _stack()
        srcs, sends = [], []
        rng = np.random.default_rng(3)
        payloads = []
        for _ in range(6):
            src, dst = stack.socket_pair("length-prefixed")
            p = rng.integers(1000, 2000, 56)
            payloads.append(p)
            src.deliver(build_message(np.arange(4), p))
            buf, _ = src.recv(1 << 20)
            sends.append((src, dst, buf, None))
            srcs.append((src, dst))
        out = stack.forward_batch(sends, impl=impl)
        assert all(st == "ok" for st, _ in out)
        for (src, dst), p in zip(srcs, payloads):
            assert np.array_equal(dst.tx_wire()[-56:], p), impl
        assert stack.pool.xfer["device_rounds"] > 0
        assert stack.pool.xfer["pool_syncs"] == 0


# ---------------------------------------------------------------------------
# dirty-row tracking: lazy host views, host<->device interleaving
# ---------------------------------------------------------------------------

def test_device_rounds_materialize_lazily_for_host_views():
    stack = _stack()
    socks = [stack.socket("length-prefixed") for _ in range(3)]
    rng = np.random.default_rng(9)
    payloads = [rng.integers(1000, 2000, 40) for _ in socks]
    for s, p in zip(socks, payloads):
        s.deliver(build_message(np.arange(4), p))
    res = stack.recv_batch(socks, impl="ref")
    assert len(res) == 3
    pool = stack.pool
    # truth lives on the device until somebody asks
    assert len(pool.dirty_rows()) > 0
    d2h_before = pool.xfer["d2h_tokens"]
    # scalar read materializes exactly the rows it needs
    (pages, ln), = socks[0].connection.anchored.values()
    assert np.array_equal(pool.read_payload(pages, ln), payloads[0])
    assert pool.xfer["d2h_tokens"] > d2h_before
    assert len(pool.dirty_rows()) > 0          # others still device-truth
    # whole-pool view pulls the rest; afterwards nothing is dirty
    _ = pool.data
    assert len(pool.dirty_rows()) == 0
    # and the materialized pool equals a host-impl run byte-for-byte
    stack_h = _stack()
    socks_h = [stack_h.socket("length-prefixed") for _ in range(3)]
    for s, p in zip(socks_h, payloads):
        s.deliver(build_message(np.arange(4), p))
    stack_h.recv_batch(socks_h, impl="host")
    assert np.array_equal(pool.data, stack_h.pool.data)


def test_host_writes_interleave_with_device_rounds(monkeypatch):
    """Scalar (host-path) anchoring between device rounds: host-dirty rows
    upload lazily when a later device gather needs them; payloads stay
    byte-exact in both directions. (The small-gather shortcut is pinned
    off: this test drives single-row rounds at the device plane on
    purpose.)"""
    monkeypatch.setattr("repro.core.stack._SMALL_GATHER_ROWS", 0)
    stack = _stack(n_shards=1, pages_per_shard=8)
    rng = np.random.default_rng(13)
    # round 1: device round anchors + forwards (pool becomes resident)
    s1, d1 = stack.socket_pair("length-prefixed")
    p1 = rng.integers(1000, 2000, 64)
    s1.deliver(build_message(np.arange(3), p1))
    r = stack.recv_batch([s1], impl="ref")
    buf, _ = r[s1.fileno()]
    s1.forward(d1, buf)
    assert np.array_equal(d1.tx_wire()[-64:], p1)
    # round 2: scalar recv anchors via the host scatter (host-dirty rows)
    s2, d2 = stack.socket_pair("length-prefixed")
    p2 = rng.integers(3000, 4000, 64)
    s2.deliver(build_message(np.arange(3), p2))
    buf2, _ = s2.recv(1 << 20)
    h2d_before = stack.pool.xfer["h2d_tokens"]
    # round 3: the device gather serves those host-dirty rows — they are
    # uploaded lazily (O(rows)) and the wire bytes come out exact
    out = stack.forward_batch([(s2, d2, buf2, None)], impl="ref")
    assert out[0][0] == "ok"
    assert np.array_equal(d2.tx_wire()[-64:], p2)
    assert stack.pool.xfer["h2d_tokens"] > h2d_before   # lazy upload ran
    assert stack.pool.xfer["pool_syncs"] == 0
    assert stack.counters.device_fallbacks == 0


def test_out_of_range_rows_bounce_round_to_host(monkeypatch):
    """Rows holding int64 tokens outside int32 stay host-truth; a device
    round that would overwrite or gather them bounces to the int64-exact
    host path and counts the fallback — values survive exactly. (Small-
    gather shortcut pinned off: the bounce is the behavior under test.)"""
    monkeypatch.setattr("repro.core.stack._SMALL_GATHER_ROWS", 0)
    stack = _stack(n_shards=1, pages_per_shard=6)
    huge = np.array([2 ** 40 + 5, -(2 ** 35), 2 ** 31, 7] * 8, np.int64)
    big = stack.socket("length-prefixed")
    big.deliver(build_message(np.arange(3), huge))
    big.recv(1 << 20)                        # host-path anchor (huge rows)
    # make the pool resident via an unrelated device round
    other = stack.socket("length-prefixed")
    other.deliver(build_message(np.arange(3), RNG.integers(0, 9, 16)))
    assert len(stack.recv_batch([other], impl="ref")) == 1
    assert stack.counters.device_fallbacks == 0
    # device gather of the huge payload must bounce, not truncate
    (vpi, (pages, ln)), = big.connection.anchored.items()
    from repro.core.vpi import VpiRegistry
    dst = stack.socket("length-prefixed")
    buf = np.concatenate([np.array([17, 3, len(huge)], np.int64),
                          np.arange(3),
                          np.array([VpiRegistry.to_token(vpi)], np.int64)])
    out = stack.forward_batch([(big, dst, buf, None)], impl="ref")
    assert out[0][0] == "ok"
    assert stack.counters.device_fallbacks == 1
    assert np.array_equal(dst.tx_wire()[-len(huge):], huge)


def test_int64_rows_survive_device_round_reusing_them():
    """A freed huge-token row re-allocated by a device round: the round
    must bounce (host-dirty upload would truncate) and the new payload
    anchors int64-exact via the host scatter."""
    stack = _stack(n_shards=1, pages_per_shard=2)   # tiny: force row reuse
    # resident device round first
    a = stack.socket("length-prefixed")
    a.deliver(build_message(np.arange(3), RNG.integers(0, 9, 16)))
    ra = stack.recv_batch([a], impl="ref")
    assert len(ra) == 1
    dst = stack.socket("length-prefixed")
    buf, _ = ra[a.fileno()]
    a.forward(dst, buf)                      # frees row for reuse
    # huge scalar anchor into the freed row, then free it again
    big = stack.socket("length-prefixed")
    huge = np.array([2 ** 40 + 1] * 16, np.int64)
    big.deliver(build_message(np.arange(3), huge))
    bbuf, _ = big.recv(1 << 20)
    big.forward(dst, bbuf)
    assert np.array_equal(dst.tx_wire()[-16:], huge)
    # device round re-using that row: upload would truncate -> bounce
    c = stack.socket("length-prefixed")
    pc = RNG.integers(0, 9, 16)
    c.deliver(build_message(np.arange(3), pc))
    rc = stack.recv_batch([c], impl="ref")
    assert len(rc) == 1
    assert stack.counters.device_fallbacks >= 1
    (pages, ln), = c.connection.anchored.values()
    assert np.array_equal(stack.pool.read_payload(pages, ln), pc)


def test_whole_pool_view_writes_stay_coherent_with_device_rounds():
    """Regression: ``pool.data``/``flat_with_scratch`` keep TokenPool's
    write-through contract, and a write through the view cannot be
    observed — handing one out must conservatively mark the pool
    host-truth so a later device gather re-uploads and emits the NEW
    bytes instead of the stale resident row."""
    stack = _stack()
    src, dst = stack.socket_pair("length-prefixed")
    p = RNG.integers(1000, 2000, 32)
    src.deliver(build_message(np.arange(3), p))
    r = stack.recv_batch([src], impl="ref")   # device round: rows device-truth
    buf, _ = r[src.fileno()]
    (pages, ln), = src.connection.anchored.values()
    row = stack.alloc.flat_pid(pages[0])
    patched = np.array([9001, 9002, 9003, 9004], np.int64)
    view = stack.pool.data                    # whole-pool write-through view
    view.reshape(-1, stack.alloc.page_size)[row, :4] = patched
    out = stack.forward_batch([(src, dst, buf, None)], impl="ref")
    assert out[0][0] == "ok"
    assert np.array_equal(dst.tx_wire()[-ln:][:4], patched)
    assert stack.pool.xfer["pool_syncs"] == 0


def test_residency_is_lazy_for_host_only_workloads():
    """A stack that never runs a device-impl round must never create the
    device array (no jax dispatch, no snapshot upload)."""
    stack, _, msgs, _ = _run_proxy(batched=True, impl="host")
    assert msgs == 21
    assert isinstance(stack.pool, DevicePool)
    assert not stack.pool.resident
    assert stack.pool.xfer["resident_init_tokens"] == 0
    assert stack.pool.xfer["h2d_tokens"] == 0


# ---------------------------------------------------------------------------
# strict batch admission: recv_batch only returns complete messages
# ---------------------------------------------------------------------------

def test_recv_batch_requires_full_logical_room():
    """Regression (truncated-buffer accounting): a buf_len in
    [meta_len+1, meta_len+payload_len) used to let the batch anchor the
    payload and advance the ring while handing back a capped logical
    length, leaving a FAST_PATH continuation straddling the batch/scalar
    boundary. The batch now services only messages with room for the full
    logical length — truncated delivery stays a scalar-recv concern."""
    stack = _stack()
    sock = stack.socket("length-prefixed")
    payload = RNG.integers(1000, 2000, 40)
    sock.deliver(build_message(np.arange(3), payload))
    # meta_len = 6, message logical = 46: the gap range must not batch
    for bl in (7, 10, 45):
        assert stack.recv_batch([sock], bl) == {}
        assert sock.connection.rx_machine.payload_consumed == 0
    res = stack.recv_batch([sock], 46)
    buf, logical = res[sock.fileno()]
    assert logical == 46                      # never a capped logical
    assert sock.connection.rx_machine.complete()


# ---------------------------------------------------------------------------
# outer-jit donation of the resident pool
# ---------------------------------------------------------------------------

def test_resident_anchor_rounds_donate_the_pool_buffer():
    """The resident pool is donated through the outer jit on every
    anchoring round: XLA consumes (deletes) the input pool buffer, so
    exactly ONE pool allocation stays live per round instead of an input
    plus an output copy. CPU XLA honours donation, so every anchor round
    must verify as donated."""
    stack, _, msgs, _ = _run_proxy(impl="ref")
    x = stack.pool.xfer
    assert msgs == 21
    assert x["anchor_rounds"] > 0
    assert x["donated_rounds"] == x["anchor_rounds"]
    assert x["pool_syncs"] == 0


def test_donation_composes_with_hw_ktls_keystream_rounds():
    stack, _, _, _ = _run_proxy(impl="ref", tls="hw")
    x = stack.pool.xfer
    assert x["anchor_rounds"] > 0
    assert x["donated_rounds"] == x["anchor_rounds"]
