"""Hostile-header regressions: corrupt (negative) length tokens in the
wire metadata must never reach the RX machine as a parsed frame.

Before the fix, ``DelimiterParser``/``ChunkedParser`` accepted negative
payload lengths (unlike ``LengthPrefixedParser``): the state machine's
``0 <= payload_len < min_payload`` short-payload guard passes negatives
straight through to METADATA_PARSED → WRITE_VPI, producing a negative
``skip_payload`` whose ``rx_advance`` REWINDS ``RxRing.consumed`` and
re-delivers stream bytes (and drives ``CopyCounters.anchored`` negative).
"""
import numpy as np

from repro.core import (
    ChunkedParser,
    DelimiterParser,
    LengthPrefixedParser,
    LibraStack,
)
from repro.core.parser import CHUNK_MAGIC, DELIM, MAGIC
from repro.core.state_machine import RxStateMachine, St

RNG = np.random.default_rng(77)


def _delim_frame(payload_len):
    return np.concatenate([np.array([7, 7], np.int64),
                           np.array(DELIM, np.int64),
                           np.array([payload_len], np.int64)])


def _chunk_frame(chunk_len):
    return np.array([CHUNK_MAGIC, chunk_len], np.int64)


# ---------------------------------------------------------------------------
# parser level: negative lengths are unparseable, not frames
# ---------------------------------------------------------------------------

def test_parsers_reject_negative_payload_lengths():
    for parser, window in [
        (DelimiterParser(), _delim_frame(-5)),
        (ChunkedParser(), _chunk_frame(-9)),
        (LengthPrefixedParser(), np.array([MAGIC, 2, -3, 9, 9], np.int64)),
        (LengthPrefixedParser(), np.array([MAGIC, -2, 3, 9, 9], np.int64)),
    ]:
        res = parser.parse(window)
        assert not res.ok, (parser.name, window)
        assert not res.need_more, (parser.name, window)

    # sanity: the same frames with sane lengths still parse
    assert DelimiterParser().parse(
        np.concatenate([_delim_frame(2), np.array([1, 2])])).ok
    assert ChunkedParser().parse(
        np.concatenate([_chunk_frame(2), np.array([1, 2])])).ok


# ---------------------------------------------------------------------------
# state machine with hostile headers: full-copy fallback, no negative skip
# ---------------------------------------------------------------------------

def test_rx_machine_full_copies_hostile_headers():
    for parser, frame in [(DelimiterParser(), _delim_frame(-5)),
                          (ChunkedParser(), _chunk_frame(-9))]:
        sm = RxStateMachine(parser)
        window = np.concatenate([frame, np.array([101, 102, 103], np.int64)])
        decision = sm.on_recv(window, 1 << 20)
        assert decision.state is St.DEFAULT, parser.name
        assert decision.skip_payload == 0, parser.name
        assert decision.full_copy == len(window), parser.name
        assert sm.payload_len >= 0, parser.name


# ---------------------------------------------------------------------------
# end to end: the ring never rewinds, counters never go negative
# ---------------------------------------------------------------------------

def _hostile_stream_case(proto, hostile, follow_builder):
    stack = LibraStack(n_shards=1, pages_per_shard=8, page_size=16,
                       secret=b"hh")
    sock = stack.socket(proto)
    sock.deliver(hostile)
    follow = follow_builder()
    sock.deliver(follow)
    seen = []
    for _ in range(16):
        buf, n = sock.recv(1 << 20)
        ring = sock.connection.rx_ring
        # the invariant the old code broke: consumed is monotonic and the
        # anchoring telemetry never goes negative
        assert ring.consumed >= 0
        assert stack.counters.anchored >= 0
        assert sock.connection.rx_machine.payload_len >= 0
        if n == 0 and len(buf) == 0:
            break
        seen.append(np.asarray(buf))
    stream = np.concatenate(seen) if seen else np.zeros(0, np.int64)
    return stack, sock, stream, follow


def test_hostile_delimiter_header_never_rewinds_ring():
    hostile = _delim_frame(-5)
    stack, sock, stream, follow = _hostile_stream_case(
        "delimiter", hostile,
        lambda: np.concatenate([np.array([8, 8], np.int64),
                                np.array(DELIM, np.int64),
                                np.array([4], np.int64),
                                RNG.integers(100, 200, 4)]))
    # every delivered byte surfaced exactly once (no re-delivery): the
    # hostile header went down the full-copy path, the sane frame parsed
    assert len(stream) == len(hostile) + len(follow)
    assert np.array_equal(stream[: len(hostile)], hostile)
    assert sock.connection.rx_ring.consumed == len(stream)
    assert stack.counters.anchored == 0       # nothing hostile anchored


def test_hostile_chunk_length_never_rewinds_ring():
    hostile = _chunk_frame(-9)
    stack, sock, stream, follow = _hostile_stream_case(
        "chunked", hostile,
        lambda: np.concatenate([_chunk_frame(3),
                                RNG.integers(100, 200, 3),
                                _chunk_frame(0)]))
    assert len(stream) == len(hostile) + len(follow)
    assert np.array_equal(stream[: len(hostile)], hostile)
    assert sock.connection.rx_ring.consumed == len(stream)
    assert stack.counters.anchored == 0


def test_hostile_headers_not_admitted_to_recv_batch():
    stack = LibraStack(n_shards=1, pages_per_shard=8, page_size=16,
                       secret=b"hh")
    d = stack.socket("delimiter")
    d.deliver(_delim_frame(-5))
    c = stack.socket("chunked")
    c.deliver(_chunk_frame(-9))
    assert stack.recv_batch([d, c]) == {}
    assert stack.counters.anchored == 0
