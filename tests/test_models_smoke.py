"""Per-architecture smoke tests (deliverable f).

Each of the ten assigned architectures instantiates a REDUCED same-family
config and runs one forward + one train-style loss/grad step on CPU,
asserting output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models.registry import build_model


def _batch_for(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "labels": jnp.array(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.array(
            rng.standard_normal((b, cfg.img_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.array(
            rng.standard_normal((b, cfg.enc_frames, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    loss, metrics = model.loss_fn(params, batch, remat="none")
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss is not finite"
    assert float(loss) > 0

    # gradients exist and are finite for every parameter
    grads = jax.grad(lambda p: model.loss_fn(p, batch, remat="none")[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in flat)
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCHS)
def test_remat_matches_no_remat(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch_for(cfg)
    l1, _ = model.loss_fn(params, batch, remat="none")
    l2, _ = model.loss_fn(params, batch, remat="full")
    assert abs(float(l1) - float(l2)) < 1e-3


def test_param_counts_full_configs():
    """Full configs should land near their published sizes (name sanity)."""
    from repro.configs import get_config

    expected = {
        "phi3-mini-3.8b": (3.3e9, 4.5e9),
        "phi4-mini-3.8b": (3.3e9, 4.8e9),
        "minicpm-2b": (2.0e9, 3.2e9),
        "mistral-nemo-12b": (11.0e9, 13.5e9),
        "hymba-1.5b": (1.2e9, 2.1e9),
        "xlstm-350m": (0.25e9, 0.56e9),
        "whisper-medium": (0.6e9, 1.1e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "qwen2-moe-a2.7b": (13e9, 17e9),
        "internvl2-76b": (68e9, 82e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"
