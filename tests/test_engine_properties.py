"""Hypothesis property tests on serving-engine invariants."""
import jax
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_reduced
from repro.core.parser import TokenStreamParser
from repro.models.registry import build_model
from repro.serving.engine import LibraEngine


@pytest.fixture(scope="module")
def mp():
    cfg = get_reduced("libra-proxy-125m")
    model = build_model(cfg, page_size=8)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@settings(max_examples=8, deadline=None)
@given(st.data())
def test_engine_invariants_random_workloads(mp, data):
    """For arbitrary request mixes: every request completes with exactly
    max_new_tokens outputs; all pool pages return; VPI registry drains;
    host-boundary download stays metadata-sized."""
    cfg, model, params = mp
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    n_req = data.draw(st.integers(1, 6))
    max_batch = data.draw(st.integers(1, 4))
    eng = LibraEngine(model, params, max_batch=max_batch, max_len=64,
                      page_size=8, parser=TokenStreamParser(header_len=2))
    reqs = []
    for _ in range(n_req):
        plen = data.draw(st.integers(3, 30))
        gen = data.draw(st.integers(1, 6))
        reqs.append((eng.submit(rng.integers(1, cfg.vocab_size - 1, plen),
                                max_new_tokens=gen), gen))
    eng.run(max_steps=500)

    assert len(eng.completed) == n_req
    for r, gen in reqs:
        assert len(r.output) == gen
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    # pool fully reclaimed except the parking page
    assert eng.pool.alloc.free_pages == eng.pool.alloc.total_pages - 1
    assert len(eng.pool.registry) == 0
    # selective copy: downloads are token-id sized (4B per active request
    # per step + prefill batches), never payload/logit sized
    steps = eng.stats.steps + eng.stats.prefills
    assert eng.stats.d2h_bytes <= 4 * eng.max_batch * max(steps, 1)


def test_pool_pressure_admission(mp):
    """When the pool cannot admit, requests wait (no crash, no starvation
    once pages free up)."""
    cfg, model, params = mp
    rng = np.random.default_rng(0)
    eng = LibraEngine(model, params, max_batch=4, max_len=64, page_size=8,
                      pool_pages=14)  # tiny pool: ~2 requests' worth
    reqs = [eng.submit(rng.integers(1, 250, 24), max_new_tokens=3)
            for _ in range(5)]
    eng.run(max_steps=300)
    assert len(eng.completed) == 5  # all served despite pressure
    assert eng.pool.alloc.free_pages == eng.pool.alloc.total_pages - 1
